"""MAX-CUT by simulated annealing on the CIM sampler engine.

Combinatorial optimisation is the flagship use of probabilistic hardware
beyond posterior sampling (the p-bit coprocessor benchmarks, PAPERS.md):
encode the problem as a spin glass, cool the sampler, read off the best
configuration it ever visited.  This example runs the full reduction:

  1. MAX-CUT instance  — the periodic lattice graph with random *signed*
     integer edge weights (the unsigned lattice is bipartite, where
     MAX-CUT is trivially the checkerboard; signs frustrate it).
     Examples stay exhaustively checkable: 4x4 = 16 nodes
  2. spin-glass encoding — J = -w, so the spin-glass ground state *is*
     the maximum cut
  3. simulated annealing — a geometric beta schedule on the unified
     engine (CIM randomness), best-state tracker streaming alongside
  4. verification — exhaustive enumeration of all 2^16 partitions

Run:  PYTHONPATH=src python examples/anneal_maxcut.py
"""

import jax
import numpy as np

from repro import samplers, tempering
from repro.workloads.spin_glass import SpinGlass, exhaustive_ground_state


def main():
    key = jax.random.PRNGKey(0)
    k_model, k_init, k_run = jax.random.split(key, 3)

    print("== signed MAX-CUT -> spin glass (J = -w) ==")
    model = SpinGlass.maxcut(k_model, 4, 4, max_weight=3)
    w_abs = float(np.abs(model.j_right).sum() + np.abs(model.j_down).sum())
    print(f"  lattice graph    : 4x4 periodic, {2 * 16} signed edges")
    print(f"  total |weight|   : {w_abs:.0f}")

    ground_e, ground_state = exhaustive_ground_state(model)
    opt_cut = float(np.asarray(model.cut_value(ground_state)))
    print(f"  exhaustive optimum (2^16 partitions): cut = {opt_cut:.0f}")

    print("\n== anneal: 10 stages, beta 0.4 -> 4.0, CIM randomness ==")
    engine = samplers.MHEngine(
        samplers.EngineConfig(update="gibbs", randomness="cim")
    )
    annealer = tempering.Annealer.geometric(
        10, 32, beta_min=0.4, beta_max=4.0
    )
    init = model.random_init(k_init, batch=4)  # 4 independent restarts
    result = annealer.run(k_run, model, init, engine=engine)

    cuts = np.asarray(model.cut_value(result.best_words))
    energies = np.asarray(result.best_energy)
    for b in range(init.shape[0]):
        mark = "  <- optimal" if cuts[b] == opt_cut else ""
        print(
            f"  restart {b}: best energy {energies[b]:6.1f}   "
            f"cut {cuts[b]:.0f}/{opt_cut:.0f}{mark}"
        )
    best = float(cuts.max())
    print(f"\n  best cut found   : {best:.0f} / {opt_cut:.0f} "
          f"({100.0 * best / opt_cut:.0f}% of optimum)")
    print(f"  flip rate        : {float(result.acceptance_rate):.3f} "
          f"(cooling drives it toward 0)")
    print(f"  steps            : {result.n_steps} half-sweeps x "
          f"{init.shape[0]} restarts")
    partition = np.asarray(result.best_words[int(cuts.argmax())])
    print("  best partition (one side of the cut marked #):")
    for row in partition:
        print("    " + " ".join("#" if s else "." for s in row))


if __name__ == "__main__":
    main()
