"""Quickstart: the paper's CIM-MCMC sampler end to end in five minutes.

Reproduces the core loop of the paper on the Fig. 17(a) Gaussian-mixture
workload:
  1. pseudo-read bit-flip proposals       (§3.1 — the randomness source)
  2. MSXOR-debiased accurate [0,1] RNG    (§4.2)
  3. symmetric-q Metropolis-Hastings      (§3.2 — alpha = p(x*)/p(x))
  4. compartment-parallel macro + 28 nm energy/timing ledger (§5, §6)

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core import msxor, targets
from repro.core.macro import CIMMacro, MacroConfig


def main():
    key = jax.random.PRNGKey(0)

    # --- the randomness pipeline, numerically --------------------------------
    print("== MSXOR debias (paper §4.2) ==")
    for stages in range(4):
        lam = msxor.lambda_recursion(0.4, stages)
        print(f"  stages={stages}  lambda={lam:.8f}  error={0.5 - lam:.2e}")
    print("  paper: lambda_3(0.4) = 0.49999872  -> error 1.3e-6 < 1e-5\n")

    # --- sample the paper's GMM through the macro twin -----------------------
    print("== GMM sampling on the 64-compartment macro (Fig. 17a/c) ==")
    gmm = targets.GaussianMixture.paper_gmm()
    codec = targets.GridCodec(nbits=8, dim=1, lo=(-10.0,), hi=(10.0,))
    macro = CIMMacro(MacroConfig(nbits=8, burn_in=500))
    points, stats = macro.sample_points(key, gmm, codec, n_samples=50_000)

    hist, edges = np.histogram(points[:, 0], bins=40, range=(-10, 10))
    ref = targets.reference_grid_probs(gmm, codec)
    peak = hist.max()
    print("  sampled density (ascii):")
    for i in range(40):
        bar = "#" * int(40 * hist[i] / peak)
        print(f"  {edges[i]:6.1f} |{bar}")
    print(f"\n  samples          : {stats.n_samples}")
    print(f"  acceptance       : {stats.acceptance_rate:.3f}")
    print(f"  energy/sample    : {stats.energy_per_sample_pj:.4f} pJ "
          f"(kept samples; amortizes burn-in)")
    print(f"  energy/step      : {stats.energy_pj / stats.n_steps:.4f} pJ "
          f"(paper: 0.533-0.540 pJ at 4-bit; scales with width)")
    print(f"  modeled time     : {stats.modeled_time_s * 1e6:.1f} us "
          f"for {stats.n_steps} chain steps")
    print(f"  throughput       : {stats.throughput_samples_per_s:.3g} samples/s")


if __name__ == "__main__":
    main()
