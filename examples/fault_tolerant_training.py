"""Fault-tolerance demo: preempt a training run mid-flight and resume.

Trains a small model, injects a simulated preemption (the SIGTERM path a
cluster scheduler takes), restarts from the checkpoint, and verifies the
combined loss trajectory is bit-exact vs an uninterrupted run — the
property that makes 1000-node training restartable.

Run:  PYTHONPATH=src python examples/fault_tolerant_training.py
"""

import shutil
import tempfile

import numpy as np

from repro import configs
from repro.distributed.fault import PreemptionHandler
from repro.launch.train import TrainRun, run_training
import repro.launch.train as train_mod


def main():
    cfg = configs.get_smoke_config("granite3_8b")
    workdir = tempfile.mkdtemp(prefix="repro_ft_")
    base = dict(
        cfg=cfg, global_batch=8, seq_len=32, lr=1e-3, warmup=5,
        ckpt_every=5, log_every=5,
    )

    print("== reference: 20 uninterrupted steps ==")
    _, _, ref_losses = run_training(
        TrainRun(steps=20, ckpt_dir=f"{workdir}/ref", **base)
    )

    print("\n== run A: preempted after step 9 (checkpoint at 10) ==")
    handler = PreemptionHandler()
    orig = train_mod.SyntheticTokenPipeline.host_batch
    calls = {"n": 0}

    def counting(self, step):
        calls["n"] += 1
        if calls["n"] == 10:
            print("  [fault-injection] simulating SIGTERM (scheduler eviction)")
            handler.simulate_preemption()
        return orig(self, step)

    train_mod.SyntheticTokenPipeline.host_batch = counting
    try:
        _, _, losses_a = run_training(
            TrainRun(steps=20, ckpt_dir=f"{workdir}/ab", **base),
            preemption=handler,
        )
    finally:
        train_mod.SyntheticTokenPipeline.host_batch = orig
    print(f"  stopped after {len(losses_a)} steps, checkpoint committed")

    print("\n== run B: restart, auto-resume from the checkpoint ==")
    _, _, losses_b = run_training(TrainRun(steps=20, ckpt_dir=f"{workdir}/ab", **base))

    combined = losses_a + losses_b
    drift = float(np.max(np.abs(np.array(combined) - np.array(ref_losses))))
    print(f"\ncombined-vs-reference max |loss drift| = {drift:.3e}")
    assert drift < 1e-5, "resume is not bit-exact!"
    print("resume is bit-exact — preemption is recoverable.")
    shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
