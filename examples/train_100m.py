"""End-to-end driver: train a ~100M-param granite-style LM for a few
hundred steps on the deterministic Markov-chain corpus.

Exercises the full production path on CPU: data pipeline -> microbatched
train step -> AdamW + cosine -> periodic checkpoints -> auto-resume —
the identical code the dry-run lowers for the 256/512-chip meshes.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
(~100M params is deliberately heavy for CPU: expect a few seconds/step.
Pass --tiny for a 30-second sanity run.)
"""

import argparse
import dataclasses

import numpy as np

from repro.data import DataConfig, MarkovSource
from repro.launch.train import TrainRun, run_training
from repro.models.config import ModelConfig


def model_100m() -> ModelConfig:
    # 12 x (d=512, 8H GQA kv=4, ff=2048) + 32k vocab ~ 104M params
    return ModelConfig(
        name="granite-100m",
        family="dense",
        n_layers=12,
        d_model=512,
        n_heads=8,
        n_kv_heads=4,
        d_head=64,
        d_ff=2048,
        vocab_size=32000,
        dtype="float32",
        param_dtype_str="float32",
        attn_block_q=128,
        attn_block_kv=128,
        logits_chunk=256,
        remat_policy="none",
    )


def model_tiny() -> ModelConfig:
    return dataclasses.replace(
        model_100m(), name="granite-8m", n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=2, d_head=32, d_ff=512, vocab_size=2048,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    cfg = model_tiny() if args.tiny else model_100m()
    from repro.models import lm

    shapes, _ = lm.abstract_params(cfg)
    import jax

    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    print(f"[example] {cfg.name}: {n_params / 1e6:.1f}M params")

    floor = MarkovSource(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch)
    ).entropy_per_token()
    print(f"[example] markov corpus entropy floor: {floor:.3f} nats/token")

    run = TrainRun(
        cfg=cfg,
        steps=args.steps,
        global_batch=args.batch,
        seq_len=args.seq,
        lr=1e-3,
        warmup=min(50, args.steps // 5),
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100,
        n_micro=2,
        log_every=10,
    )
    _, _, losses = run_training(run)
    print(
        f"[example] loss: {np.mean(losses[:5]):.3f} -> {np.mean(losses[-5:]):.3f} "
        f"(floor {floor:.3f})"
    )


if __name__ == "__main__":
    main()
