"""Serving example: batched decode with the paper's MCMC token sampler.

Spins up the slot-based batched server on a small dense LM and serves a
burst of requests twice — once with standard categorical sampling, once
with the CIM-MCMC softmax-free sampler — and compares throughput and the
sampler's acceptance statistics (paper §6.4 reports 30-40 % acceptance on
its workloads; LLM logits are peakier, so acceptance is lower and is the
knob the MSXOR uniform precision has to cover).

Run:  PYTHONPATH=src python examples/serve_mcmc_decode.py
"""

import time

import numpy as np

from repro import configs
from repro.launch.serve import BatchedServer, Request, ServeConfig


def serve_burst(sampler: str, n_requests=4, prompt_len=12, gen=24, seed=0):
    cfg = configs.get_smoke_config("granite3_8b")
    scfg = ServeConfig(
        n_slots=n_requests,
        max_len=prompt_len + gen + 8,
        gen_tokens=gen,
        sampler=sampler,
        mcmc_steps=48,
        seed=seed,
    )
    server = BatchedServer(cfg, scfg)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for rid in range(n_requests):
        server.submit(
            rid, Request(rid=rid, prompt=rng.integers(0, cfg.vocab_size, prompt_len))
        )
    while server.active():
        server.step()
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in server.slot_req if r)
    acc = float(np.mean(server.acceptance)) if server.acceptance else float("nan")
    return total, dt, acc, server


def main():
    print("== categorical baseline ==")
    total, dt, _, _ = serve_burst("categorical")
    print(f"  {total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s)\n")

    print("== CIM-MCMC sampler (paper technique; softmax-free) ==")
    total, dt, acc, server = serve_burst("mcmc")
    print(f"  {total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s)")
    print(f"  MH acceptance rate: {acc:.3f}")
    for r in server.slot_req:
        print(f"  req {r.rid}: tokens {r.out_tokens}")


if __name__ == "__main__":
    main()
