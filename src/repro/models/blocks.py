"""Transformer / SSM / hybrid / MoE blocks, scan-over-layers compatible.

Every block family exposes ``init_block`` / ``apply_block`` with a uniform
signature so the stacked-layer scan in ``lm.py`` stays family-agnostic.
Per-layer heterogeneity (Hymba's global-vs-sliding attention layers) rides
through the scanned ``meta`` array as traced scalars.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import activation, layer_norm, param, rms_norm, val


def _norm_params(key, cfg, name=""):
    if cfg.norm == "layernorm":
        return {
            "w": param(key, (cfg.d_model,), ("embed",), cfg.param_dtype, mode="ones"),
            "b": param(key, (cfg.d_model,), ("embed",), cfg.param_dtype, mode="zeros"),
        }
    return {
        "w": param(key, (cfg.d_model,), ("embed",), cfg.param_dtype, mode="ones")
    }


def apply_norm(p, x, cfg):
    if cfg.norm == "layernorm":
        return layer_norm(x, val(p["w"]), val(p["b"]), cfg.norm_eps)
    return rms_norm(x, val(p["w"]), cfg.norm_eps)


def init_mlp(key, cfg):
    keys = jax.random.split(key, 3)
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.param_dtype
    if cfg.mlp_gated:
        return {
            "w_gate": param(keys[0], (d, f), ("embed", "ffn"), dt),
            "w_up": param(keys[1], (d, f), ("embed", "ffn"), dt),
            "w_down": param(keys[2], (f, d), ("ffn", "embed"), dt),
        }
    return {
        "w_in": param(keys[0], (d, f), ("embed", "ffn"), dt),
        "w_out": param(keys[1], (f, d), ("ffn", "embed"), dt),
    }


def apply_mlp(p, x, cfg):
    act = activation(cfg.act)
    if cfg.mlp_gated:
        h = act(x @ val(p["w_gate"]).astype(x.dtype)) * (
            x @ val(p["w_up"]).astype(x.dtype)
        )
        h = shard(h, ("batch", "seq", "ffn"))
        return h @ val(p["w_down"]).astype(x.dtype)
    h = act(x @ val(p["w_in"]).astype(x.dtype))
    h = shard(h, ("batch", "seq", "ffn"))
    return h @ val(p["w_out"]).astype(x.dtype)


# --- block init -------------------------------------------------------------


def init_block(key, cfg, *, kind: str | None = None):
    """kind overrides cfg.family (used for whisper encoder/decoder blocks)."""
    kind = kind or cfg.family
    keys = jax.random.split(key, 8)
    p: dict = {"ln1": _norm_params(keys[0], cfg)}

    if kind == "ssm":
        p["mamba"] = ssm_mod.init_mamba2(keys[1], cfg)
        return p

    if kind == "hybrid":
        p["attn"] = attn_mod.init_attention(keys[1], cfg)
        p["mamba"] = ssm_mod.init_mamba2(keys[2], cfg)
        p["branch_scale"] = param(
            keys[3], (2,), (None,), jnp.float32, mode="ones"
        )
        p["ln2"] = _norm_params(keys[4], cfg)
        p["mlp"] = init_mlp(keys[5], cfg)
        return p

    # attention families
    p["attn"] = attn_mod.init_attention(keys[1], cfg)
    if kind == "encoder_cross":  # whisper decoder block
        p["ln_cross"] = _norm_params(keys[2], cfg)
        p["cross"] = attn_mod.init_attention(keys[3], cfg, cross=True)
    p["ln2"] = _norm_params(keys[4], cfg)
    if kind == "moe":
        p["moe"] = moe_mod.init_moe(keys[5], cfg)
    else:
        p["mlp"] = init_mlp(keys[5], cfg)
    return p


# --- block apply ------------------------------------------------------------


def apply_block(
    p,
    x,
    cfg,
    *,
    mode: str,
    positions,
    cache=None,
    cache_index=None,
    meta=None,
    enc_out=None,
    kind: str | None = None,
):
    """Returns (x, new_cache, aux_loss).

    Cache contract (per layer; the stacked index lives at the LM level):
      dense/moe/vlm : {"k", "v"}
      ssm           : {"state", "conv_x", "conv_B", "conv_C"}
      hybrid        : {"attn": {...}, "ssm": {...}}
      encoder_cross : {"self": {...}, "cross": {"k", "v"}}
    """
    kind = kind or cfg.family
    aux = jnp.zeros((), jnp.float32)
    seq_axis = "seq_sp" if getattr(cfg, "seq_shard", False) else "seq"
    x = shard(x, ("batch", seq_axis, "embed"))

    window = None
    causal = kind != "encoder"
    if cfg.sliding_window > 0 and kind not in ("encoder",):
        w = jnp.int32(cfg.sliding_window)
        if meta is not None and "is_global" in meta:
            window = jnp.where(meta["is_global"], attn_mod.GLOBAL_WINDOW, w)
        else:
            window = w

    if kind == "ssm":
        h = apply_norm(p["ln1"], x, cfg)
        if mode == "decode":
            out, nc = ssm_mod.mamba2_decode(p["mamba"], h, cfg, cache)
        else:
            out, nc = ssm_mod.mamba2_full(p["mamba"], h, cfg, cache)
        return x + out, nc, aux

    if kind == "hybrid":
        h = apply_norm(p["ln1"], x, cfg)
        attn_cache = None if cache is None else cache["attn"]
        ssm_cache = None if cache is None else cache["ssm"]
        if mode == "decode":
            a_out, a_cache = attn_mod.attention(
                p["attn"], h, cfg, positions=positions, mode="decode",
                cache=attn_cache, cache_index=cache_index, window=window,
            )
            s_out, s_cache = ssm_mod.mamba2_decode(p["mamba"], h, cfg, ssm_cache)
        else:
            a_out, a_cache = attn_mod.attention(
                p["attn"], h, cfg, positions=positions, mode="full",
                cache=attn_cache, cache_index=cache_index, window=window,
            )
            s_out, s_cache = ssm_mod.mamba2_full(p["mamba"], h, cfg, ssm_cache)
        scale = val(p["branch_scale"]).astype(x.dtype)
        x = x + scale[0] * a_out + scale[1] * s_out
        h2 = apply_norm(p["ln2"], x, cfg)
        x = x + apply_mlp(p["mlp"], h2, cfg)
        new_cache = (
            None if cache is None else {"attn": a_cache, "ssm": s_cache}
        )
        return x, new_cache, aux

    # attention families (dense / moe / vlm / encoder / encoder_cross)
    self_cache = cache
    if kind == "encoder_cross" and cache is not None:
        self_cache = cache["self"]
    h = apply_norm(p["ln1"], x, cfg)
    a_out, new_self_cache = attn_mod.attention(
        p["attn"],
        h,
        cfg,
        positions=positions,
        mode="decode" if mode == "decode" else "full",
        cache=None if kind == "encoder" else self_cache,
        cache_index=cache_index,
        window=window,
        causal=causal,
        use_rope=kind != "encoder",
    )
    x = x + a_out
    new_cache = new_self_cache

    if kind == "encoder_cross":
        hc = apply_norm(p["ln_cross"], x, cfg)
        cross_cache = None if cache is None else cache["cross"]
        c_out, new_cross_cache = attn_mod.attention(
            p["cross"], hc, cfg, positions=positions,
            mode="decode" if mode == "decode" else "full",
            cache=cross_cache, causal=False, kv_input=enc_out,
            use_rope=False, cross=True,
        )
        x = x + c_out
        if cache is not None:
            new_cache = {"self": new_self_cache, "cross": new_cross_cache}

    h2 = apply_norm(p["ln2"], x, cfg)
    if kind == "moe":
        m_out, aux = moe_mod.moe_ffn(p["moe"], h2, cfg, activation(cfg.act))
        x = x + m_out
    else:
        x = x + apply_mlp(p["mlp"], h2, cfg)
    return x, new_cache, aux
