"""Basic layers + the annotated-parameter machinery.

Params are plain pytrees; every leaf is created through ``param(...)`` which
records its *logical sharding axes* in a parallel tree.  ``split_annotated``
separates (values, axes) so launchers can derive in/out shardings, and
``jax.eval_shape`` over an init function yields an allocation-free skeleton
for the multi-pod dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LogicalAxes:
    """Opaque pytree *leaf* holding a param's logical axis names."""

    names: tuple

    def __len__(self):
        return len(self.names)


class Annotated(NamedTuple):
    value: Any          # jnp array (or ShapeDtypeStruct under eval_shape)
    axes: LogicalAxes   # logical axis names, len == value.ndim


def param(key, shape, axes, dtype=jnp.float32, scale: float | None = None, mode="normal"):
    """Create an annotated parameter leaf."""
    if len(axes) != len(shape):
        raise ValueError(f"axes {axes} do not match shape {shape}")
    if mode == "zeros":
        value = jnp.zeros(shape, dtype)
    elif mode == "ones":
        value = jnp.ones(shape, dtype)
    else:
        if scale is None:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = 1.0 / np.sqrt(max(1, fan_in))
        value = (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    return Annotated(value=value, axes=LogicalAxes(tuple(axes)))


def is_annotated(x) -> bool:
    return isinstance(x, Annotated)


def val(x):
    """Unwrap an Annotated leaf; pass raw arrays through.

    Apply-functions use this so they run both on freshly-initialised
    Annotated trees and on the plain value trees used under scan/jit.
    """
    return x.value if isinstance(x, Annotated) else x


def split_annotated(tree):
    """pytree of Annotated -> (values pytree, axes pytree of LogicalAxes)."""
    values = jax.tree.map(lambda a: a.value, tree, is_leaf=is_annotated)
    axes = jax.tree.map(lambda a: a.axes, tree, is_leaf=is_annotated)
    return values, axes


def is_axes(x) -> bool:
    return isinstance(x, LogicalAxes)


# --- numerics --------------------------------------------------------------


def rms_norm(x, weight, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mean) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
    }[name]


# --- rotary position embedding --------------------------------------------


def rope_frequencies(d_head: int, theta: float = 10000.0):
    inv = 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float64) / d_head))
    return jnp.asarray(inv, dtype=jnp.float32)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, dh) with positions (..., S) -> rotated x, f32 math."""
    dh = x.shape[-1]
    inv = rope_frequencies(dh, theta)
    angles = positions[..., None].astype(jnp.float32) * inv        # (..., S, dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- embedding -------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32):
    return param(
        key, (vocab, d_model), ("vocab", "embed"), dtype=dtype, scale=1.0
    )


def embed_lookup(table, tokens):
    return jnp.take(table, tokens, axis=0)
