"""Grouped-query attention with RoPE, sliding windows, and a blockwise
(flash-style) softmax for long sequences.

The blockwise path never materialises the full (Sq, Sk) score matrix: it
scans query blocks (outer) and key/value blocks (inner) carrying the running
max / normaliser / accumulator, bounding activation memory at
O(block_q x block_kv) per head — required for the 32k prefill shapes to fit
HBM at compile time.

The sliding window is a *traced* per-layer scalar so heterogeneous layer
stacks (e.g. Hymba's 3 global + 29 SWA layers) stay scan-over-layers
compatible; masking is elementwise.  Baseline computes all KV blocks with
masking (the familiar 2x causal overhead — see EXPERIMENTS.md §Perf for the
block-skipping variant).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.layers import param, rms_norm, apply_rope, val

NEG_INF = -1e30
GLOBAL_WINDOW = jnp.int32(2**30)  # "no window" sentinel


def init_attention(key, cfg, *, cross: bool = False):
    """cfg needs: d_model, n_heads, n_kv_heads, d_head, dtype, qk_norm."""
    keys = jax.random.split(key, 6)
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dtype = cfg.param_dtype
    p = {
        "wq": param(keys[0], (d, h, dh), ("embed", "heads", "head_dim"), dtype),
        "wk": param(keys[1], (d, kv, dh), ("embed", "kv_heads", "head_dim"), dtype),
        "wv": param(keys[2], (d, kv, dh), ("embed", "kv_heads", "head_dim"), dtype),
        "wo": param(keys[3], (h, dh, d), ("heads", "head_dim", "embed"), dtype),
    }
    if getattr(cfg, "qk_norm", False):
        p["q_norm"] = param(keys[4], (dh,), ("head_dim",), dtype, mode="ones")
        p["k_norm"] = param(keys[5], (dh,), ("head_dim",), dtype, mode="ones")
    return p


def _mask(q_pos, k_pos, window, causal: bool, sk_valid=None):
    """q_pos: (bq,), k_pos: (bk,) -> (bq, bk) bool validity mask."""
    valid = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if sk_valid is not None:
        valid &= k_pos[None, :] < sk_valid  # key-side padding
    if causal:
        valid &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            valid &= (q_pos[:, None] - k_pos[None, :]) < window
    return valid


def _attend_block(q, k, v, mask, scale):
    """q: (B,KV,R,bq,dh) k/v: (B,KV,bk,dh) mask: (bq,bk) -> (scores-free flash piece)."""
    s = jnp.einsum(
        "bkrqd,bksd->bkrqs", q, k, preferred_element_type=jnp.float32
    ) * scale
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    return s


def flash_attention(
    q, k, v, *, causal: bool, window, q_offset, block_q: int, block_kv: int,
    unroll_causal_skip: bool = False,
):
    """Blockwise softmax attention.

    q: (B, Sq, KV, R, dh); k, v: (B, Sk, KV, dh).  window may be None, a
    python int, or a traced scalar.  q_offset is the absolute position of
    q[.,0] (for decode/chunked prefill).  Returns (B, Sq, KV, R, dh).
    """
    b, sq, kvh, r, dh = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_kv = min(block_kv, sk)
    sq_orig, sk_orig = sq, sk
    # pad seq dims to block multiples; padded keys are masked, padded query
    # rows are sliced off the output
    if sq % block_q:
        pad = block_q - sq % block_q
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        sq += pad
    if sk % block_kv:
        pad = block_kv - sk % block_kv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        sk += pad
    sk_valid = sk_orig if sk != sk_orig else None
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    qb = jnp.moveaxis(
        q.reshape(b, sq // block_q, block_q, kvh, r, dh), 1, 0
    )  # (nq, B, bq, KV, R, dh)
    kb = jnp.moveaxis(k.reshape(b, sk // block_kv, block_kv, kvh, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, sk // block_kv, block_kv, kvh, dh), 1, 0)

    nq, nk = sq // block_q, sk // block_kv

    def q_block(qi, q_i):
        # q_i: (B, bq, KV, R, dh) -> transpose for einsum
        qt = jnp.moveaxis(q_i, 1, 3)  # (B, KV, R, bq, dh)
        q_pos = q_offset + qi * block_q + jnp.arange(block_q)

        def kv_step(carry, inputs):
            m_run, l_run, acc = carry
            kj, k_j, v_j = inputs
            kt = jnp.moveaxis(k_j, 1, 2)  # (B, KV, bk, dh)
            vt = jnp.moveaxis(v_j, 1, 2)
            k_pos = kj * block_kv + jnp.arange(block_kv)
            mask = _mask(q_pos, k_pos, window, causal, sk_valid)
            s = _attend_block(qt, kt, vt, mask, scale)  # (B,KV,R,bq,bk) f32
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            correction = jnp.exp(m_run - m_new)
            l_new = l_run * correction + p.sum(axis=-1)
            acc = acc * correction[..., None] + jnp.einsum(
                "bkrqs,bksd->bkrqd", p.astype(vt.dtype), vt,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc), None

        init = (
            jnp.full((b, kvh, r, block_q), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, r, block_q), jnp.float32),
            jnp.zeros((b, kvh, r, block_q, dh), jnp.float32),
        )
        (m_run, l_run, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), kb, vb)
        )
        out = acc / jnp.maximum(l_run, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)  # (B, bq, KV, R, dh)

    if unroll_causal_skip and causal and window is None:
        # beyond-paper §Perf lever: python-unrolled q blocks with *static*
        # per-block KV extent — true causal FLOP skipping (~2x on attention).
        outs = []
        for qi in range(nq):
            hi = min(nk, (qi * block_q + block_q + block_kv - 1) // block_kv)
            sub_k, sub_v = kb[:hi], vb[:hi]

            def q_block_static(qi=qi, sub_k=sub_k, sub_v=sub_v):
                qt = jnp.moveaxis(qb[qi], 1, 3)
                q_pos = q_offset + qi * block_q + jnp.arange(block_q)

                def kv_step(carry, inputs):
                    m_run, l_run, acc = carry
                    kj, k_j, v_j = inputs
                    kt = jnp.moveaxis(k_j, 1, 2)
                    vt = jnp.moveaxis(v_j, 1, 2)
                    k_pos = kj * block_kv + jnp.arange(block_kv)
                    mask = _mask(q_pos, k_pos, None, True)
                    s = _attend_block(qt, kt, vt, mask, scale)
                    m_new = jnp.maximum(m_run, s.max(axis=-1))
                    p = jnp.exp(s - m_new[..., None])
                    corr = jnp.exp(m_run - m_new)
                    l_new = l_run * corr + p.sum(axis=-1)
                    acc2 = acc * corr[..., None] + jnp.einsum(
                        "bkrqs,bksd->bkrqd", p.astype(vt.dtype), vt,
                        preferred_element_type=jnp.float32,
                    )
                    return (m_new, l_new, acc2), None

                init = (
                    jnp.full((b, kvh, r, block_q), NEG_INF, jnp.float32),
                    jnp.zeros((b, kvh, r, block_q), jnp.float32),
                    jnp.zeros((b, kvh, r, block_q, dh), jnp.float32),
                )
                (m_run, l_run, acc), _ = jax.lax.scan(
                    kv_step, init, (jnp.arange(hi), sub_k, sub_v)
                )
                out = acc / jnp.maximum(l_run, 1e-30)[..., None]
                return jnp.moveaxis(out, 3, 1)

            outs.append(q_block_static())
        out = jnp.concatenate(outs, axis=1)
        return out[:, :sq_orig].astype(q.dtype)

    out = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qb))
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, kvh, r, dh)
    return out[:, :sq_orig].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, index, window):
    """Single-token attention against a (B, Smax, KV, dh) cache.

    q: (B, 1, KV, R, dh); index = number of valid cache entries (q is at
    position index - 1 ... the cache already contains this step's k/v).
    ``index`` is a scalar (all rows at the same position) or a (B,)
    per-row index — the slot-local positions a continuous-batching
    server needs when sequences of different lengths share the cache.
    """
    b, _, kvh, r, dh = q.shape
    smax = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qt = q[:, 0]  # (B, KV, R, dh)
    pos = jnp.arange(smax)
    idx = jnp.broadcast_to(jnp.asarray(index), (b,))  # scalar -> per-row
    q_pos = idx - 1
    valid = pos[None, :] < idx[:, None]  # (B, Smax)
    if window is not None:
        valid &= (q_pos[:, None] - pos[None, :]) < window
    s = jnp.einsum(
        "bkrd,bskd->bkrs", qt, k_cache, preferred_element_type=jnp.float32
    ) * scale
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkrs,bskd->bkrd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out[:, None].astype(q.dtype)  # (B, 1, KV, R, dh)


def _gqa_layout(kv: int, r: int):
    """Pick (kv_eff, r_eff, repeat) so the sharded head axis divides "model".

    Layout A: kv divides |model|  -> shard the kv axis, keep GQA grouping.
    Layout B: only h = kv*r does  -> repeat K/V to h heads, shard flat heads.
    Layout C: neither divides     -> keep GQA grouping, weights replicate
                                     (divisibility filter in sharding rules).
    """
    from repro.distributed.sharding import active_mesh

    mesh = active_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return kv, r, False
    m = dict(zip(mesh.axis_names, mesh.axis_sizes))["model"]
    if m <= 1 or kv % m == 0:
        return kv, r, False
    if (kv * r) % m == 0:
        return kv * r, 1, True
    return kv, r, False


def attention(
    params,
    x,
    cfg,
    *,
    positions,
    mode: str,
    cache=None,
    cache_index=None,
    window=None,
    causal: bool = True,
    kv_input=None,
    use_rope: bool = True,
    cross: bool = False,
):
    """Full attention layer.  Returns (out, new_cache).

    mode: "full" (train / prefill over the whole sequence) or "decode".
    Self-attention cache: dict(k, v) of (B, Smax, KV, dh); ``cache_index``
    is the number of valid entries *before* this call (traced scalar).
    Cross-attention (``cross=True``): K/V come from ``kv_input`` in full
    mode (and are returned as the new cache), or from ``cache`` in decode.
    """
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    r = h // kv

    q = jnp.einsum("bsd,dhk->bshk", x, val(params["wq"]).astype(x.dtype))
    kv_src = kv_input if cross else x
    if not (cross and mode == "decode"):
        k = jnp.einsum("bsd,dhk->bshk", kv_src, val(params["wk"]).astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", kv_src, val(params["wv"]).astype(x.dtype))
    else:
        k = v = None  # cross-attn decode reads the prefilled cache

    if "q_norm" in params:
        q = rms_norm(q, val(params["q_norm"]))
        if k is not None:
            k = rms_norm(k, val(params["k_norm"]))

    if use_rope and not cross:
        q = _rope_heads(q, positions, cfg.rope_theta)
        if k is not None:
            k = _rope_heads(k, positions, cfg.rope_theta)

    kv_eff, r_eff, repeat_kv = _gqa_layout(kv, r)
    q = q.reshape(b, s, kv_eff, r_eff, dh)
    q = shard(q, ("batch", "seq", "kv_heads", None, "head_dim"))

    def widen(t):
        """(B, S, KV, dh) -> effective layout (repeat to h heads if needed)."""
        if repeat_kv and t.shape[2] != kv_eff:
            t = jnp.repeat(t, r, axis=2)
        return shard(t, ("batch", "cache_seq", "kv_heads", "head_dim"))

    new_cache = cache
    if mode == "decode":
        # decode keeps the native GQA grouping: repeating K/V to the flat
        # head layout would materialise an r-times-larger cache read (the
        # decode workload is cache-bandwidth-bound; measured 4x traffic on
        # granite-3-8b) — the cache seq dim supplies the model-axis
        # parallelism instead (cache_seq sharding rules)
        q_dec = q.reshape(b, s, kv, r, dh)

        def cache_shard(t):
            return shard(t, ("batch", "cache_seq", "kv_heads", "head_dim"))

        if not cross:
            # append this step's k/v at cache index; a (B,) per-row index
            # writes each row at its own position (heterogeneous prompt
            # lengths sharing one slot cache)
            idx = cache_index
            if getattr(idx, "ndim", 0) == 1:

                def row_update(c, u, i):
                    return jax.vmap(
                        lambda cr, ur, ir: jax.lax.dynamic_update_slice(
                            cr, ur.astype(cr.dtype), (ir, 0, 0)
                        )
                    )(c, u, i)

                k_cache = row_update(cache["k"], k, idx)
                v_cache = row_update(cache["v"], v, idx)
            else:
                k_cache = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0)
                )
                v_cache = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0)
                )
            new_cache = {"k": k_cache, "v": v_cache}
            out = decode_attention(
                q_dec, cache_shard(k_cache), cache_shard(v_cache),
                index=idx + s, window=window,
            )
        else:
            out = decode_attention(
                q_dec,
                cache_shard(cache["k"]),
                cache_shard(cache["v"]),
                index=cache["k"].shape[1],
                window=None,
            )
        out = out.reshape(b, s, h, dh)
    else:
        if k is None:
            raise ValueError("full mode requires computed k/v")
        if cache is not None and not cross:
            # prefill: write the whole sequence into the cache
            k_cache = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)
            )
            v_cache = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)
            )
            new_cache = {"k": k_cache, "v": v_cache}
        elif cross and cache is not None:
            # whisper prefill: stash encoder K/V for decode steps
            new_cache = {
                "k": k.astype(cache["k"].dtype),
                "v": v.astype(cache["v"].dtype),
            }
        out = flash_attention(
            q,
            k if not repeat_kv else jnp.repeat(k, r, axis=2),
            v if not repeat_kv else jnp.repeat(v, r, axis=2),
            causal=causal,
            window=window,
            q_offset=0,
            block_q=cfg.attn_block_q,
            block_kv=cfg.attn_block_kv,
            unroll_causal_skip=getattr(cfg, "attn_causal_skip", False),
        ).reshape(b, s, h, dh)

    out = jnp.einsum("bshk,hkd->bsd", out, val(params["wo"]).astype(x.dtype))
    return out, new_cache


def _rope_heads(x, positions, theta):
    """x: (B, S, H, dh), positions: (B, S) or (S,)."""
    if positions.ndim == 1:
        positions = positions[None, :]
    return apply_rope(
        x.swapaxes(1, 2), positions[:, None, :], theta
    ).swapaxes(1, 2)


def init_kv_cache(cfg, batch: int, max_len: int, n_layers=None, dtype=jnp.bfloat16):
    """KV cache; stacked (L-major) when n_layers is given (scan decode).

    Logical axes (for sharding): ("layers", "batch", "cache_seq",
    "kv_heads", "head_dim") — "cache_seq" lets MQA-ish archs shard the
    cache over "model" instead of heads (config rule override).
    """
    kv, dh = cfg.n_kv_heads, cfg.d_head
    lead = () if n_layers is None else (n_layers,)
    return {
        "k": jnp.zeros((*lead, batch, max_len, kv, dh), dtype),
        "v": jnp.zeros((*lead, batch, max_len, kv, dh), dtype),
    }


KV_CACHE_AXES = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
