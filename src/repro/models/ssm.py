"""Mamba-2 (SSD, state-space duality) layer — arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm as a single `lax.scan` over
sequence chunks (carrying the inter-chunk state), which bounds the intra-
chunk (L x L) attention-like matrix to one chunk at a time; decode is the
O(1) recurrent step on a (B, H, P, N) state.  A naive step-by-step
recurrence reference is provided for equivalence tests.

TP note: heads shard over "model" ("ssm_heads") when divisible (mamba2-1.3b:
64 heads / 16 = 4); Hymba's 50 SSM heads are not divisible by 16 and fall
back to replication per the sharding rules' divisibility filter (attention
still shards; recorded in DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import param, rms_norm, val


def init_mamba2(key, cfg):
    """cfg: d_model, ssm_heads H, ssm_head_dim P, ssm_state N, ssm_groups G,
    ssm_conv K, param_dtype."""
    keys = jax.random.split(key, 12)
    d = cfg.d_model
    h, p, n, g, k = (
        cfg.ssm_heads,
        cfg.ssm_head_dim,
        cfg.ssm_state,
        cfg.ssm_groups,
        cfg.ssm_conv,
    )
    dt_ = cfg.param_dtype
    params = {
        "wx": param(keys[0], (d, h, p), ("embed", "ssm_heads", "head_dim"), dt_),
        "wz": param(keys[1], (d, h, p), ("embed", "ssm_heads", "head_dim"), dt_),
        "wB": param(keys[2], (d, g, n), ("embed", None, "ssm_state"), dt_),
        "wC": param(keys[3], (d, g, n), ("embed", None, "ssm_state"), dt_),
        "wdt": param(keys[4], (d, h), ("embed", "ssm_heads"), dt_),
        "conv_x": param(keys[5], (k, h, p), ("conv", "ssm_heads", "head_dim"), dt_, scale=0.5),
        "conv_B": param(keys[6], (k, g, n), ("conv", None, "ssm_state"), dt_, scale=0.5),
        "conv_C": param(keys[7], (k, g, n), ("conv", None, "ssm_state"), dt_, scale=0.5),
        "A_log": param(keys[8], (h,), ("ssm_heads",), jnp.float32, mode="zeros"),
        "D": param(keys[9], (h,), ("ssm_heads",), jnp.float32, mode="ones"),
        "dt_bias": param(keys[10], (h,), ("ssm_heads",), jnp.float32, mode="zeros"),
        "norm": param(keys[11], (h, p), ("ssm_heads", "head_dim"), dt_, mode="ones"),
        "out": param(
            jax.random.fold_in(key, 99), (h, p, d),
            ("ssm_heads", "head_dim", "embed"), dt_,
        ),
    }
    return params


def _causal_conv_full(x, w, cache=None):
    """Depthwise causal conv over time. x: (B,S,...ch), w: (K,...ch)."""
    k = w.shape[0]
    pad = [(0, 0)] * x.ndim
    if cache is None:
        pad[1] = (k - 1, 0)
        xp = jnp.pad(x, pad)
    else:
        xp = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None] for i in range(k)
    )
    new_cache = xp[:, -(k - 1) :] if k > 1 else None
    return out, new_cache


def _project(params, x):
    """x: (B,S,d) -> xs (B,S,H,P), z, B (B,S,G,N), C, dt (B,S,H)."""
    dtv = x.dtype
    xs = jnp.einsum("bsd,dhp->bshp", x, val(params["wx"]).astype(dtv))
    z = jnp.einsum("bsd,dhp->bshp", x, val(params["wz"]).astype(dtv))
    bmat = jnp.einsum("bsd,dgn->bsgn", x, val(params["wB"]).astype(dtv))
    cmat = jnp.einsum("bsd,dgn->bsgn", x, val(params["wC"]).astype(dtv))
    dt = jnp.einsum("bsd,dh->bsh", x, val(params["wdt"]).astype(dtv))
    return xs, z, bmat, cmat, dt


def mamba2_full(params, x, cfg, cache=None):
    """Training / prefill path. x: (B, S, d) -> (y (B,S,d), new_cache).

    Sequences that don't divide the chunk size are padded with *identity
    transitions*: padded steps get dt = 0, i.e. exp(dt*A) = 1 and zero
    input, so the carried state after step s is exact and the padded
    outputs are sliced off.
    """
    b, s, d = x.shape
    h, p, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    chunk = min(cfg.ssm_chunk, s)
    s_pad = ((s + chunk - 1) // chunk) * chunk
    nc = s_pad // chunk

    xs, z, bmat, cmat, dt = _project(params, x)
    conv_caches = {}
    xs, conv_caches["conv_x"] = _causal_conv_full(
        xs, val(params["conv_x"]), None if cache is None else cache["conv_x"]
    )
    bmat, conv_caches["conv_B"] = _causal_conv_full(
        bmat, val(params["conv_B"]), None if cache is None else cache["conv_B"]
    )
    cmat, conv_caches["conv_C"] = _causal_conv_full(
        cmat, val(params["conv_C"]), None if cache is None else cache["conv_C"]
    )
    xs, bmat, cmat = jax.nn.silu(xs), jax.nn.silu(bmat), jax.nn.silu(cmat)

    a_vec = -jnp.exp(val(params["A_log"]))                      # (H,) negative
    dt = jax.nn.softplus(dt.astype(jnp.float32) + val(params["dt_bias"]))  # (B,S,H)

    if s_pad != s:
        pad2 = [(0, 0), (0, s_pad - s)]
        xs_p = jnp.pad(xs, pad2 + [(0, 0)] * (xs.ndim - 2))
        bmat = jnp.pad(bmat, pad2 + [(0, 0)] * (bmat.ndim - 2))
        cmat = jnp.pad(cmat, pad2 + [(0, 0)] * (cmat.ndim - 2))
        dt = jnp.pad(dt, pad2 + [(0, 0)])   # dt = 0 -> identity transition
    else:
        xs_p = xs

    rep = h // g
    bmat_h = jnp.repeat(bmat, rep, axis=2).astype(jnp.float32)   # (B,S,H,N)
    cmat_h = jnp.repeat(cmat, rep, axis=2).astype(jnp.float32)
    xdt = xs_p.astype(jnp.float32) * dt[..., None]               # (B,S,H,P)
    loga = dt * a_vec                                            # (B,S,H) <= 0

    # chunked views: (nc, B, L, ...)
    def chunked(t):
        return jnp.moveaxis(t.reshape(b, nc, chunk, *t.shape[2:]), 1, 0)

    xdt_c, b_c, c_c, loga_c = map(chunked, (xdt, bmat_h, cmat_h, loga))

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))               # l >= s

    h0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if cache is None
        else cache["state"].astype(jnp.float32)
    )

    def chunk_step(h_prev, inputs):
        xdt_i, b_i, c_i, la_i = inputs                           # (B,L,H,*)
        ca = jnp.cumsum(la_i, axis=1)                            # (B,L,H)
        a_tot = ca[:, -1]                                        # (B,H)
        # intra-chunk (diagonal) term
        att = jnp.einsum("blhn,bshn->blsh", c_i, b_i)
        decay = jnp.exp(ca[:, :, None] - ca[:, None, :])         # (B,L,S,H)
        att = att * decay * tri[None, :, :, None]
        y = jnp.einsum("blsh,bshp->blhp", att, xdt_i)
        # contribution of the carried state
        y += jnp.einsum("blhn,bhpn,blh->blhp", c_i, h_prev, jnp.exp(ca))
        # new carried state
        decay_in = jnp.exp(a_tot[:, None] - ca)                  # (B,L,H)
        h_new = h_prev * jnp.exp(a_tot)[:, :, None, None] + jnp.einsum(
            "blhn,blh,blhp->bhpn", b_i, decay_in, xdt_i
        )
        return h_new, y

    h_last, y_c = jax.lax.scan(chunk_step, h0, (xdt_c, b_c, c_c, loga_c))
    y = jnp.moveaxis(y_c, 0, 1).reshape(b, s_pad, h, p)[:, :s]
    y = y + val(params["D"])[None, None, :, None] * xs.astype(jnp.float32)

    y = y * jax.nn.silu(z.astype(jnp.float32))                   # gated
    y = rms_norm(y, val(params["norm"]))                        # per-head RMS
    out = jnp.einsum("bshp,hpd->bsd", y.astype(x.dtype), val(params["out"]).astype(x.dtype))

    new_cache = None
    if cache is not None:
        new_cache = {
            "state": h_last.astype(cache["state"].dtype),
            "conv_x": conv_caches["conv_x"].astype(cache["conv_x"].dtype),
            "conv_B": conv_caches["conv_B"].astype(cache["conv_B"].dtype),
            "conv_C": conv_caches["conv_C"].astype(cache["conv_C"].dtype),
        }
    return out, new_cache


def mamba2_decode(params, x, cfg, cache):
    """Single-step recurrence. x: (B, 1, d)."""
    b = x.shape[0]
    h, p, n, g, k = (
        cfg.ssm_heads,
        cfg.ssm_head_dim,
        cfg.ssm_state,
        cfg.ssm_groups,
        cfg.ssm_conv,
    )
    xs, z, bmat, cmat, dt = _project(params, x)

    def conv_step(t, w, cbuf):
        buf = jnp.concatenate([cbuf.astype(t.dtype), t], axis=1)   # (B, K, ...)
        out = jnp.einsum("bk...,k...->b...", buf, w.astype(t.dtype))[:, None]
        return out, buf[:, 1:]

    xs, conv_x = conv_step(xs, val(params["conv_x"]), cache["conv_x"])
    bmat, conv_B = conv_step(bmat, val(params["conv_B"]), cache["conv_B"])
    cmat, conv_C = conv_step(cmat, val(params["conv_C"]), cache["conv_C"])
    xs, bmat, cmat = jax.nn.silu(xs), jax.nn.silu(bmat), jax.nn.silu(cmat)

    a_vec = -jnp.exp(val(params["A_log"]))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + val(params["dt_bias"]))[:, 0]  # (B,H)
    rep = h // g
    b_h = jnp.repeat(bmat[:, 0], rep, axis=1).astype(jnp.float32)  # (B,H,N)
    c_h = jnp.repeat(cmat[:, 0], rep, axis=1).astype(jnp.float32)
    x_h = xs[:, 0].astype(jnp.float32)                              # (B,H,P)

    da = jnp.exp(dt * a_vec)                                        # (B,H)
    state = cache["state"].astype(jnp.float32)
    state = state * da[:, :, None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", x_h, b_h, dt
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, c_h)
    y = y + val(params["D"])[None, :, None] * x_h
    y = y[:, None] * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y, val(params["norm"]))
    out = jnp.einsum("bshp,hpd->bsd", y.astype(x.dtype), val(params["out"]).astype(x.dtype))
    new_cache = {
        "state": state.astype(cache["state"].dtype),
        "conv_x": conv_x.astype(cache["conv_x"].dtype),
        "conv_B": conv_B.astype(cache["conv_B"].dtype),
        "conv_C": conv_C.astype(cache["conv_C"].dtype),
    }
    return out, new_cache


def mamba2_reference(params, x, cfg):
    """Naive step-by-step recurrence (oracle for the chunked path)."""
    b, s, d = x.shape
    cache = init_ssm_cache(cfg, b, n_layers=None, dtype=jnp.float32)
    outs = []
    for t in range(s):
        o, cache = mamba2_decode(params, x[:, t : t + 1], cfg, cache)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


def init_ssm_cache(cfg, batch: int, n_layers=None, dtype=jnp.bfloat16):
    h, p, n, g, k = (
        cfg.ssm_heads,
        cfg.ssm_head_dim,
        cfg.ssm_state,
        cfg.ssm_groups,
        cfg.ssm_conv,
    )
    lead = () if n_layers is None else (n_layers,)
    return {
        "state": jnp.zeros((*lead, batch, h, p, n), jnp.float32),
        "conv_x": jnp.zeros((*lead, batch, k - 1, h, p), dtype),
        "conv_B": jnp.zeros((*lead, batch, k - 1, g, n), dtype),
        "conv_C": jnp.zeros((*lead, batch, k - 1, g, n), dtype),
    }
