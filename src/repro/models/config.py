"""Model configuration shared by all 10 assigned architectures."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    act: str = "silu"
    mlp_gated: bool = True
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    qk_norm: bool = False
    tie_embeddings: bool = False
    # attention windowing (hybrid long-context archs)
    sliding_window: int = 0          # 0 = all layers global
    global_layers: tuple = ()        # global layer ids when sliding_window > 0
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_renormalize: bool = True
    aux_loss_weight: float = 0.01
    # SSM (Mamba-2)
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_state: int = 0
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # encoder-decoder (whisper)
    n_encoder_layers: int = 0
    encoder_len: int = 1500
    frame_dim: int = 128             # stub mel-frame feature width
    # VLM
    n_image_tokens: int = 0
    image_embed_dim: int = 1024      # stub CLIP patch feature width
    # numerics / implementation
    dtype: str = "bfloat16"
    param_dtype_str: str = "bfloat16"
    cache_dtype_str: str = "bfloat16"
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    attn_causal_skip: bool = False   # §Perf lever: static causal block skip
    remat_policy: str = "nothing"    # nothing | dots | none
    scan_layers: bool = True
    logits_chunk: int = 2048
    z_loss: float = 0.0
    # distribution levers
    seq_shard: bool = False          # SP: residual stream sharded over "model"
    vocab_pad_to: int = 256          # TP-friendly vocab padding (MaxText-style)
    sharding_overrides: tuple = ()   # ((logical_axis, mesh_axes), ...) rules patch
    train_microbatches: int = 4      # gradient-accumulation splits for train_4k

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def param_dtype(self):
        return jnp.dtype(self.param_dtype_str)

    @property
    def cache_dtype(self):
        return jnp.dtype(self.cache_dtype_str)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_mlp(self) -> bool:
        return self.d_ff > 0 and self.family != "moe"

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid with sliding windows)."""
        return self.family == "ssm" or (
            self.family == "hybrid" and self.sliding_window > 0
        )

    # ---- parameter counting (for MODEL_FLOPS = 6 N D) ----------------------

    def _attn_params(self) -> int:
        d, h, kv, dh = self.d_model, self.n_heads, self.n_kv_heads, self.d_head
        return d * h * dh + 2 * d * kv * dh + h * dh * d

    def _mlp_params(self) -> int:
        if self.d_ff == 0:
            return 0
        mult = 3 if self.mlp_gated else 2
        return mult * self.d_model * self.d_ff

    def _ssm_params(self) -> int:
        if self.ssm_heads == 0:
            return 0
        d, h, p, n, g = (
            self.d_model,
            self.ssm_heads,
            self.ssm_head_dim,
            self.ssm_state,
            self.ssm_groups,
        )
        return 3 * d * h * p + 2 * d * g * n + d * h  # wx, wz, out, wB, wC, wdt

    def _moe_params(self) -> int:
        if self.n_experts == 0:
            return 0
        return self.n_experts * 3 * self.d_model * self.d_ff + self.d_model * self.n_experts

    def layer_params(self, active_only: bool = False) -> int:
        total = 0
        if self.has_attention:
            total += self._attn_params()
        if self.family in ("ssm", "hybrid"):
            total += self._ssm_params()
        if self.family == "moe":
            if active_only:
                total += self.moe_top_k * 3 * self.d_model * self.d_ff
            else:
                total += self._moe_params()
        else:
            total += self._mlp_params()
        return total

    def param_count(self, active_only: bool = False) -> int:
        """Total (or active, for MoE) parameter count incl. embeddings."""
        n = self.n_layers * self.layer_params(active_only)
        n += self.n_encoder_layers * (self._attn_params() + self._mlp_params())
        if self.is_encdec:
            n += self.n_layers * self._attn_params()  # cross-attention
        embed = self.vocab_size * self.d_model
        n += embed if self.tie_embeddings else 2 * embed
        return n
