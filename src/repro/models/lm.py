"""Unified language-model assembly for all 10 assigned architectures.

One ``init_lm`` / ``lm_forward`` pair covers dense, MoE, SSM (Mamba-2),
hybrid (Hymba), VLM (patch-embed stub frontend), and audio enc-dec
(Whisper, frame-embed stub frontend).  Layers are *scanned* with stacked
parameters so compile time and HLO size are O(1) in depth (88-layer
granite-34b under 512 fake devices compiles on one CPU).

Param trees carry logical sharding axes (``Annotated`` leaves from
``repro.models.layers``); ``abstract_params`` yields the allocation-free
(ShapeDtypeStruct, axes) pair the multi-pod dry-run lowers against.

Cache contract: ``{"index": int32 scalar or (B,) per-row, "layers":
<stacked per-layer>}`` (+ audio keeps cross K/V inside the per-layer
tree).  The stacked leaves lead with the layer axis so decode scans
slice them per layer.  A per-row index lets rows sit at different cache
depths — the slot-local positions continuous-batching serving needs for
heterogeneous prompt lengths (launch/serve.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard
from repro.models import attention as attn_mod
from repro.models import blocks
from repro.models import ssm as ssm_mod
from repro.models.blocks import apply_norm
from repro.models.layers import (
    Annotated,
    LogicalAxes,
    param,
    split_annotated,
    val,
)

Array = jnp.ndarray


# --- init --------------------------------------------------------------------


def _init_stack(key, cfg, n_layers: int, kind: str | None = None):
    """Stacked block params: every leaf gains a leading "layers" axis."""
    keys = jax.random.split(key, n_layers)

    captured = {}

    def one_values(k):
        tree = blocks.init_block(k, cfg, kind=kind)
        vals, axes = split_annotated(tree)
        captured["axes"] = axes
        return vals

    jax.eval_shape(one_values, keys[0])  # capture axes without allocating
    stacked = jax.vmap(one_values)(keys)
    return jax.tree.map(
        lambda v, a: Annotated(v, LogicalAxes(("layers",) + a.names)),
        stacked,
        captured["axes"],
    )


def init_lm(key, cfg):
    """Full parameter tree (Annotated leaves) for one architecture."""
    keys = jax.random.split(key, 10)
    d, vp = cfg.d_model, cfg.padded_vocab
    dt = cfg.param_dtype
    p = {
        # input table is vocab-sharded like the head: the masked local gather
        # + psum the partitioner emits is cheaper than a replicated table's
        # gradient traffic, and d-sharding the table trips an XLA SPMD bug
        # when the gather is hoisted into the microbatch loop (see DESIGN.md)
        "embed": param(keys[0], (vp, d), ("vocab", "embed"), dt, scale=1.0),
        "layers": _init_stack(
            keys[1], cfg, cfg.n_layers,
            kind="encoder_cross" if cfg.is_encdec else None,
        ),
        "final_norm": blocks._norm_params(keys[2], cfg),
        "lm_head": param(keys[3], (d, vp), ("embed", "vocab"), dt),
    }
    if cfg.family == "vlm":
        p["img_proj"] = {
            "w": param(keys[4], (cfg.image_embed_dim, d), (None, "embed_tp"), dt),
            "b": param(keys[5], (d,), ("embed",), dt, mode="zeros"),
        }
    if cfg.is_encdec:  # audio / whisper
        p["audio_proj"] = {
            "w": param(keys[4], (cfg.frame_dim, d), (None, "embed_tp"), dt),
            "b": param(keys[5], (d,), ("embed",), dt, mode="zeros"),
        }
        p["enc_pos"] = param(
            keys[6], (cfg.encoder_len, d), ("seq", "embed_tp"), dt, scale=0.02
        )
        p["encoder"] = _init_stack(keys[7], cfg, cfg.n_encoder_layers, kind="encoder")
        p["enc_norm"] = blocks._norm_params(keys[8], cfg)
    return p


def abstract_params(cfg, seed: int = 0):
    """(ShapeDtypeStruct values tree, axes tree) — no device allocation."""
    captured = {}

    def fn(k):
        tree = init_lm(k, cfg)
        vals, axes = split_annotated(tree)
        captured["axes"] = axes
        return vals

    shapes = jax.eval_shape(fn, jax.random.PRNGKey(seed))
    return shapes, captured["axes"]


def init_lm_values(key, cfg):
    """Concrete (values, axes) trees."""
    tree = init_lm(key, cfg)
    return split_annotated(tree)


# --- caches ------------------------------------------------------------------


def _layer_cache(cfg, batch: int, max_len: int):
    L = cfg.n_layers
    dt = cfg.cache_dtype
    fam = cfg.family
    if fam == "ssm":
        return ssm_mod.init_ssm_cache(cfg, batch, n_layers=L, dtype=dt)
    if fam == "hybrid":
        return {
            "attn": attn_mod.init_kv_cache(cfg, batch, max_len, L, dt),
            "ssm": ssm_mod.init_ssm_cache(cfg, batch, n_layers=L, dtype=dt),
        }
    if cfg.is_encdec:
        kv, dh = cfg.n_kv_heads, cfg.d_head
        return {
            "self": attn_mod.init_kv_cache(cfg, batch, max_len, L, dt),
            "cross": {
                "k": jnp.zeros((L, batch, cfg.encoder_len, kv, dh), dt),
                "v": jnp.zeros((L, batch, cfg.encoder_len, kv, dh), dt),
            },
        }
    return attn_mod.init_kv_cache(cfg, batch, max_len, L, dt)


def init_cache(cfg, batch: int, max_len: int):
    return {
        "index": jnp.zeros((), jnp.int32),
        "layers": _layer_cache(cfg, batch, max_len),
    }


def abstract_cache(cfg, batch: int, max_len: int):
    """ShapeDtypeStruct cache skeleton for dry-run decode inputs."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


_KV_AXES = {"k": LogicalAxes(attn_mod.KV_CACHE_AXES), "v": LogicalAxes(attn_mod.KV_CACHE_AXES)}
_SSM_AXES = {
    "state": LogicalAxes(("layers", "batch", "ssm_heads", "head_dim", "ssm_state")),
    "conv_x": LogicalAxes(("layers", "batch", "conv", "ssm_heads", "head_dim")),
    "conv_B": LogicalAxes(("layers", "batch", "conv", None, "ssm_state")),
    "conv_C": LogicalAxes(("layers", "batch", "conv", None, "ssm_state")),
}


def cache_axes(cfg):
    fam = cfg.family
    if fam == "ssm":
        layers = dict(_SSM_AXES)
    elif fam == "hybrid":
        layers = {"attn": dict(_KV_AXES), "ssm": dict(_SSM_AXES)}
    elif cfg.is_encdec:
        layers = {"self": dict(_KV_AXES), "cross": dict(_KV_AXES)}
    else:
        layers = dict(_KV_AXES)
    return {"index": LogicalAxes(()), "layers": layers}


# --- layer metadata (per-layer heterogeneity through scan) --------------------


def layer_metas(cfg):
    """(L,)-leading arrays of per-layer flags, or None if homogeneous."""
    if cfg.sliding_window > 0 and cfg.global_layers:
        is_global = np.zeros((cfg.n_layers,), dtype=bool)
        for g in cfg.global_layers:
            is_global[g] = True
        return {"is_global": jnp.asarray(is_global)}
    return None


# --- forward -------------------------------------------------------------------


def _remat(fn, cfg):
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    if cfg.remat_policy == "nothing":
        return jax.checkpoint(fn)
    return fn


def _stack_apply(
    layer_vals,
    x,
    cfg,
    *,
    mode: str,
    positions,
    cache_layers=None,
    cache_index=None,
    metas=None,
    enc_out=None,
    kind: str | None = None,
):
    """Scan (or unrolled loop) over the stacked layer params."""

    def layer_fn(x, lp, cl, meta):
        return blocks.apply_block(
            lp,
            x,
            cfg,
            mode=mode,
            positions=positions,
            cache=cl,
            cache_index=cache_index,
            meta=meta,
            enc_out=enc_out,
            kind=kind,
        )

    layer_fn = _remat(layer_fn, cfg)

    if cfg.scan_layers:

        def body(x, xs):
            lp, cl, meta = xs
            out, ncl, aux = layer_fn(x, lp, cl, meta)
            return out, (ncl, aux)

        x, (new_layers, auxs) = jax.lax.scan(
            body, x, (layer_vals, cache_layers, metas)
        )
        return x, new_layers, jnp.sum(auxs)

    # unrolled path (debugging / tiny configs)
    n = jax.tree.leaves(layer_vals)[0].shape[0]
    new_layers, aux_total = [], jnp.zeros((), jnp.float32)
    for i in range(n):
        lp = jax.tree.map(lambda v: v[i], layer_vals)
        cl = None if cache_layers is None else jax.tree.map(
            lambda v: v[i], cache_layers
        )
        meta = None if metas is None else jax.tree.map(lambda v: v[i], metas)
        x, ncl, aux = layer_fn(x, lp, cl, meta)
        new_layers.append(ncl)
        aux_total = aux_total + aux
    if cache_layers is not None:
        new_layers = jax.tree.map(lambda *ls: jnp.stack(ls), *new_layers)
    else:
        new_layers = None
    return x, new_layers, aux_total


def _embed_tokens(vals, cfg, tokens):
    table = val(vals["embed"])
    x = jnp.take(table, tokens, axis=0).astype(cfg.compute_dtype)
    seq_axis = "seq_sp" if cfg.seq_shard else "seq"
    return shard(x, ("batch", seq_axis, "embed"))


def _encode_audio(vals, cfg, frames):
    """Stub frontend: precomputed mel-frame features -> encoder stack."""
    w, b = val(vals["audio_proj"]["w"]), val(vals["audio_proj"]["b"])
    x = frames.astype(cfg.compute_dtype) @ w.astype(cfg.compute_dtype) + b
    x = x + val(vals["enc_pos"]).astype(cfg.compute_dtype)[None]
    pos = jnp.arange(cfg.encoder_len)
    x, _, _ = _stack_apply(
        vals["encoder"], x, cfg, mode="full", positions=pos, kind="encoder"
    )
    return apply_norm(vals["enc_norm"], x, cfg)


def lm_forward(vals, cfg, batch, *, mode: str, cache=None):
    """Backbone forward: returns (hidden (B,S,d), new_cache, aux_loss).

    batch: {"tokens": (B, S) int32} plus family extras
    ("image_embeds" for vlm, "frames" for audio).
    mode: "train" | "prefill" | "decode".
    """
    tokens = batch["tokens"]
    b, s_tok = tokens.shape
    index = None if cache is None else cache["index"]

    enc_out = None
    kind = None
    if cfg.is_encdec:
        kind = "encoder_cross"
        if mode != "decode":
            enc_out = _encode_audio(vals, cfg, batch["frames"])

    x = _embed_tokens(vals, cfg, tokens)
    if cfg.family == "vlm" and mode != "decode":
        w, bb = val(vals["img_proj"]["w"]), val(vals["img_proj"]["b"])
        img = batch["image_embeds"].astype(cfg.compute_dtype) @ w.astype(
            cfg.compute_dtype
        ) + bb
        x = jnp.concatenate([img, x], axis=1)

    s_total = x.shape[1]
    if mode == "decode":
        # scalar index -> (s_tok,) positions; per-row (B,) index -> (B,
        # s_tok), so rows at different cache depths decode in one batch
        positions = jnp.asarray(index)[..., None] + jnp.arange(s_tok)
    else:
        positions = jnp.arange(s_total)

    cache_layers = None if cache is None else cache["layers"]
    x, new_layers, aux = _stack_apply(
        vals["layers"],
        x,
        cfg,
        mode="decode" if mode == "decode" else "full",
        positions=positions,
        cache_layers=cache_layers,
        cache_index=index,
        metas=layer_metas(cfg),
        enc_out=enc_out,
        kind=kind,
    )
    x = apply_norm(vals["final_norm"], x, cfg)

    new_cache = None
    if cache is not None:
        new_index = index + (s_tok if mode == "decode" else s_total)
        new_cache = {"index": new_index, "layers": new_layers}
    return x, new_cache, aux


# --- logits & loss -------------------------------------------------------------


def head_logits(vals, cfg, hidden):
    """hidden (..., d) -> masked float32 logits (..., padded_vocab)."""
    w = val(vals["lm_head"]).astype(cfg.compute_dtype)
    logits = jnp.einsum(
        "...d,dv->...v", hidden, w, preferred_element_type=jnp.float32
    )
    logits = shard(logits, ("batch", "seq", "vocab"))
    if cfg.padded_vocab != cfg.vocab_size:
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits


def chunked_ce_loss(vals, cfg, hidden, labels):
    """Cross-entropy over seq chunks; logits never fully materialised.

    labels: (B, S) int32 with negative values masked out.  The per-chunk
    computation is rematerialised in the backward pass (jax.checkpoint), so
    peak memory holds a single (B, chunk, V) logits block.
    """
    b, s, d = hidden.shape
    c = min(cfg.logits_chunk, s)
    while s % c:
        c -= 1
    nc = s // c
    hs = jnp.moveaxis(hidden.reshape(b, nc, c, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)

    def chunk_fn(carry, xs):
        h, lab = xs
        logits = head_logits(vals, cfg, h)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.clip(lab, 0)[..., None].astype(jnp.int32), axis=-1
        )[..., 0]
        mask = (lab >= 0).astype(jnp.float32)
        nll = jnp.sum((logz - ll) * mask)
        zl = jnp.sum(jnp.square(logz) * mask) if cfg.z_loss > 0 else 0.0
        loss_sum, z_sum, count = carry
        return (loss_sum + nll, z_sum + zl, count + jnp.sum(mask)), None

    init = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    (loss_sum, z_sum, count), _ = jax.lax.scan(
        jax.checkpoint(chunk_fn), init, (hs, ls)
    )
    denom = jnp.maximum(count, 1.0)
    return loss_sum / denom + cfg.z_loss * z_sum / denom, count


def train_loss(vals, cfg, batch):
    """Scalar training loss (+ metrics dict)."""
    hidden, _, aux = lm_forward(vals, cfg, batch, mode="train")
    labels = batch["labels"]
    if cfg.family == "vlm":
        # image positions carry no labels
        pad = -jnp.ones((labels.shape[0], cfg.n_image_tokens), labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    loss, count = chunked_ce_loss(vals, cfg, hidden, labels)
    total = loss
    if cfg.family == "moe":
        total = total + cfg.aux_loss_weight * aux
    metrics = {"ce_loss": loss, "aux_loss": aux, "tokens": count}
    return total, metrics


# --- serving steps -------------------------------------------------------------


def prefill(vals, cfg, batch, cache):
    """Run the prompt through the stack, fill the cache, return last logits."""
    hidden, new_cache, _ = lm_forward(vals, cfg, batch, mode="prefill", cache=cache)
    logits = head_logits(vals, cfg, hidden[:, -1:, :])[:, 0]
    return logits, new_cache


def decode_step(vals, cfg, tokens, cache):
    """One decode step: tokens (B, 1) + cache -> (logits (B, V), cache')."""
    hidden, new_cache, _ = lm_forward(
        vals, cfg, {"tokens": tokens}, mode="decode", cache=cache
    )
    logits = head_logits(vals, cfg, hidden[:, -1:, :])[:, 0]
    return logits, new_cache
