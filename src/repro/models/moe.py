"""Mixture-of-Experts FFN with top-k routing, capacity dispatch, and EP.

Two execution paths share one dispatch discipline:

* ``moe_ffn_local`` — single-program reference: per-batch-row sort-based
  capacity dispatch, dense expert einsums over the full expert stack.
* ``moe_ffn_ep`` — expert parallelism via *partial-auto* ``jax.shard_map``:
  the expert-stacked weights are manual over the "model" mesh axis
  (E_local = E / |model| experts per rank), activations stay replicated over
  "model" and auto-sharded over "data"/"pod".  Each rank dispatches its own
  experts' tokens locally and the combine is a single ``psum`` over
  "model" — the same collective schedule as a TP FFN (one all-reduce of the
  activation per MoE layer, no all-to-all), see DESIGN.md §6.

Dispatch is per *batch row* so the sort never crosses the data-parallel
sharding: within a row the (S*k) assignments are sorted by expert id,
positions within each expert come from segment arithmetic, and tokens past
the static per-expert capacity are dropped (combine weight zero) — the
GShard/Switch discipline that keeps every shape static for pjit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import active_mesh, shard
from repro.models.layers import param, val


def init_moe(key, cfg):
    """cfg: d_model, n_experts E, d_ff (per-expert hidden), param_dtype."""
    keys = jax.random.split(key, 4)
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff
    dt = cfg.param_dtype
    return {
        "router": param(keys[0], (d, e), ("embed", None), jnp.float32),
        "w_gate": param(keys[1], (e, d, f), ("experts", "embed", "ffn"), dt),
        "w_up": param(keys[2], (e, d, f), ("experts", "embed", "ffn"), dt),
        "w_down": param(keys[3], (e, f, d), ("experts", "ffn", "embed"), dt),
    }


def capacity(tokens_per_row: int, n_experts: int, top_k: int, factor: float) -> int:
    cap = int(tokens_per_row * top_k / n_experts * factor)
    return max(8, ((cap + 7) // 8) * 8)  # 8-aligned for TPU sublanes


def route(router_w, x, top_k: int, renormalize: bool = True):
    """x: (..., d) -> (probs (..., k), experts (..., k) int32, aux scalar)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), router_w)
    probs_full = jax.nn.softmax(logits, axis=-1)
    probs, experts = jax.lax.top_k(probs_full, top_k)
    if renormalize:
        probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch-style), over all leading dims
    e = router_w.shape[-1]
    flat_probs = probs_full.reshape(-1, e)
    me = jnp.mean(flat_probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(experts.reshape(-1, top_k)[:, 0], e, dtype=jnp.float32),
        axis=0,
    )
    aux_loss = e * jnp.sum(me * ce)
    return probs, experts, aux_loss


def _dispatch_row(xr, pr, er, n_experts: int, top_k: int, cap: int, offset):
    """One batch row: (S,d),(S,k),(S,k) -> (E,cap,d) buffer + combine info.

    ``offset``/``n_experts`` select a contiguous local expert range
    [offset, offset+n_experts) — 0/E for the local path, rank slice for EP.
    """
    s, d = xr.shape
    flat_e = er.reshape(-1).astype(jnp.int32) - offset          # (S*k,)
    flat_p = pr.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(s), top_k)
    is_local = (flat_e >= 0) & (flat_e < n_experts)
    sort_key = jnp.where(is_local, flat_e, n_experts)           # non-local last
    order = jnp.argsort(sort_key, stable=True)
    se = sort_key[order]
    pos = jnp.arange(s * top_k) - jnp.searchsorted(se, se, side="left")
    keep = (se < n_experts) & (pos < cap)
    slot = jnp.where(keep, se * cap + pos, n_experts * cap)     # overflow slot

    buf = jnp.zeros((n_experts * cap + 1, d), xr.dtype)
    buf = buf.at[slot].set(xr[flat_tok[order]], mode="drop")
    buf = buf[: n_experts * cap].reshape(n_experts, cap, d)
    weights = jnp.where(keep, flat_p[order], 0.0)
    return buf, (slot, flat_tok[order], weights)


def _combine_row(out_buf, info, s: int):
    """(E,cap,d) expert outputs -> (S,d) weighted scatter-add."""
    slot, tok, weights = info
    e, cap, d = out_buf.shape
    flat = out_buf.reshape(e * cap, d)
    contrib = flat[jnp.minimum(slot, e * cap - 1)] * weights[:, None].astype(
        flat.dtype
    )
    return jnp.zeros((s, d), out_buf.dtype).at[tok].add(contrib)


def _expert_ffn(buf, wg, wu, wd, act):
    """buf: (B, E, cap, d) x stacked weights (E, d, f) -> (B, E, cap, d)."""
    hg = jnp.einsum("becd,edf->becf", buf, wg.astype(buf.dtype))
    hu = jnp.einsum("becd,edf->becf", buf, wu.astype(buf.dtype))
    hidden = act(hg) * hu
    return jnp.einsum("becf,efd->becd", hidden, wd.astype(buf.dtype))


def _moe_body(x, probs, experts, wg, wu, wd, cfg, act, offset, constrain=False):
    """Shared body: dispatch/compute/combine for a local expert slice.

    With ``constrain`` (the auto-GSPMD path), sharding constraints pin the
    dispatch buffers to ("batch" x "experts") so the partitioner keeps the
    expert einsums EP-local and lowers the combine scatter-add into local
    partial sums + one activation all-reduce — the same schedule an
    explicit shard_map EP would produce.
    """
    b, s, d = x.shape
    e_local = wg.shape[0]
    cap = capacity(s, cfg.n_experts, cfg.moe_top_k, cfg.moe_capacity_factor)
    bufs, infos = jax.vmap(
        lambda xr, pr, er: _dispatch_row(
            xr, pr, er, e_local, cfg.moe_top_k, cap, offset
        )
    )(x, probs, experts)
    if constrain:
        # dispatch buffer stays REPLICATED over "model": the row-local
        # scatter then needs no cross-rank merge (an experts-sharded
        # constraint here makes GSPMD lower the scatter as full-size
        # partial + all-reduce — measured 206 GB/step on qwen3-moe).
        # The expert einsum below reads each rank's slice of it locally.
        bufs = shard(bufs, ("batch", None, "expert_cap", "embed"))
    out_bufs = _expert_ffn(bufs, wg, wu, wd, act)
    if constrain:
        out_bufs = shard(out_bufs, ("batch", "experts", "expert_cap", "embed"))
    return jax.vmap(lambda ob, info: _combine_row(ob, info, s))(out_bufs, infos)


def moe_ffn_local(params, x, cfg, act, constrain=False):
    """Single-program / auto-GSPMD path. x: (B,S,d) -> (out, aux)."""
    probs, experts, aux = route(
        val(params["router"]), x, cfg.moe_top_k, renormalize=cfg.moe_renormalize
    )
    out = _moe_body(
        x,
        probs,
        experts,
        val(params["w_gate"]),
        val(params["w_up"]),
        val(params["w_down"]),
        cfg,
        act,
        offset=0,
        constrain=constrain,
    )
    return out, aux


def moe_ffn_ep(params, x, cfg, act, mesh, axis: str = "model"):
    """Expert-parallel path: experts manual over ``axis``, rest auto.

    AD never differentiates *through* the shard_map: a ``jax.custom_vjp``
    wraps it, and the backward pass is its own shard_map that replays the
    local dispatch under ``jax.vjp`` (recompute-style; dispatch is cheap
    relative to the expert matmuls).  This sidesteps an XLA SPMD crash when
    transposing a partial-auto shard_map inside scan+remat, and matches the
    schedule a hand-written EP backward would use anyway: dW stays
    rank-local and dx/drouter take the same single all-reduce as the
    forward combine.

    Routing runs inside the manual region (replicated compute — the router
    matmul is tiny), so only float tensors cross the custom_vjp boundary.
    """
    w_spec = P(axis, None, None)

    def local_fwd(x_, rw_, wg_, wu_, wd_):
        e_local = wg_.shape[0]
        offset = jax.lax.axis_index(axis) * e_local
        probs, experts, _ = route(
            rw_, x_, cfg.moe_top_k, renormalize=cfg.moe_renormalize
        )
        return _moe_body(x_, probs, experts, wg_, wu_, wd_, cfg, act, offset)

    @jax.custom_vjp
    def ep(x_, rw_, wg_, wu_, wd_):
        def body(*args):
            return jax.lax.psum(local_fwd(*args), axis)

        return jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), P(), w_spec, w_spec, w_spec),
            out_specs=P(),
            axis_names={axis},
            check_vma=False,
        )(x_, rw_, wg_, wu_, wd_)

    def ep_fwd(x_, rw_, wg_, wu_, wd_):
        return ep(x_, rw_, wg_, wu_, wd_), (x_, rw_, wg_, wu_, wd_)

    def ep_bwd(res, dout):
        def body(x_, rw_, wg_, wu_, wd_, dout_):
            _, vjp = jax.vjp(local_fwd, x_, rw_, wg_, wu_, wd_)
            dx, drw, dwg, dwu, dwd = vjp(dout_)
            return (
                jax.lax.psum(dx, axis),
                jax.lax.psum(drw, axis),
                dwg,
                dwu,
                dwd,
            )

        return jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), P(), w_spec, w_spec, w_spec, P()),
            out_specs=(P(), P(), w_spec, w_spec, w_spec),
            axis_names={axis},
            check_vma=False,
        )(*res, dout)

    ep.defvjp(ep_fwd, ep_bwd)

    out = ep(
        x,
        val(params["router"]),
        val(params["w_gate"]),
        val(params["w_up"]),
        val(params["w_down"]),
    )
    # aux load-balancing loss: differentiable routing stats, auto-sharded
    _, _, aux = route(
        val(params["router"]), x, cfg.moe_top_k, renormalize=cfg.moe_renormalize
    )
    return out, aux


import os as _os  # noqa: E402  (kept beside the env-var escape hatch below)

# The explicit shard_map EP path trips an XLA SPMD CHECK-crash ("Invalid
# binary instruction opcode copy") when a partial-auto shard_map sits inside
# the layer scan in this XLA build.  The default is therefore the
# constraint-steered auto path (identical collective schedule, see
# _moe_body); flip this env var to exercise the shard_map path on a
# toolchain where the bug is fixed.
USE_SHARD_MAP_EP = _os.environ.get("REPRO_MOE_SHARD_MAP_EP", "0") == "1"


def moe_ffn(params, x, cfg, act):
    """Dispatching entry: EP-constrained when a mesh is active, else local."""
    mesh = active_mesh()
    ep_capable = (
        mesh is not None
        and "model" in mesh.axis_names
        and cfg.n_experts % dict(zip(mesh.axis_names, mesh.axis_sizes))["model"] == 0
        and dict(zip(mesh.axis_names, mesh.axis_sizes))["model"] > 1
    )
    if ep_capable and USE_SHARD_MAP_EP:
        return moe_ffn_ep(params, x, cfg, act, mesh)
    out, aux = moe_ffn_local(params, x, cfg, act, constrain=ep_capable)
    return shard(out, ("batch", "seq", "embed")), aux


def moe_dense_reference(params, x, cfg, act):
    """All-experts dense evaluation (oracle for routing/combine tests).

    No capacity limit: equals the capacity path exactly whenever no token
    overflows (tests use high capacity_factor to guarantee that).
    """
    b, s, d = x.shape
    n = b * s
    xf = x.reshape(n, d)
    probs, experts, _ = route(
        val(params["router"]), xf, cfg.moe_top_k, renormalize=cfg.moe_renormalize
    )
    hg = jnp.einsum("nd,edf->nef", xf, val(params["w_gate"]).astype(x.dtype))
    hu = jnp.einsum("nd,edf->nef", xf, val(params["w_up"]).astype(x.dtype))
    hidden = act(hg) * hu
    all_out = jnp.einsum(
        "nef,efd->ned", hidden, val(params["w_down"]).astype(x.dtype)
    )
    sel = jnp.take_along_axis(all_out, experts[..., None], axis=1)  # (N, k, d)
    out = jnp.sum(sel * probs[..., None].astype(x.dtype), axis=1)
    return out.reshape(b, s, d)
