"""Bit-exact resumable runs: the checkpoint-backed segment driver.

``run_resumable`` executes a ``RunPlan`` as a sequence of checkpointed
segments.  After each segment it saves the engine's resume carry
``(words, logp, accept_count)`` plus the accumulated sample stream via
the atomic checkpoint subsystem (checkpoint.py); on the next invocation
with the same ``directory`` it restores the newest checkpoint and
continues.  The result is bit-identical to one unsegmented run —
tests/test_checkpoint.py asserts it across {mh, gibbs} x {host, cim,
fused}:

  * operands for step ``t`` depend only on ``(key, step0 + t)``, so the
    restarted segment continues the exact randomness stream (the engine's
    ``step0`` segment-invariance, DESIGN.md §Tempering);
  * ``accept_count`` sums exactly (int32 per-site counts);
  * ``acceptance_rate`` is recomputed with the engine's own float32
    expression over the summed counts;
  * ``final_logp`` either rides the solo-MH-scan carry or is re-derived
    from the restored state by a pure deterministic ``log_prob`` — the
    same bits either way;
  * ``thin:<k>`` keeps *absolute* steps, so per-segment kept sets
    concatenate into the unsegmented kept set (DESIGN.md §Collection).

A checkpoint records the plan's :meth:`RunPlan.fingerprint` (engine
axes, stream key, state layout — but NOT chunk_steps/block_c/execution,
which never change the stream), and restore refuses a mismatch: a
resumed run is the *same* chain or an error, never silently a different
one.  ``on_segment`` is a post-save hook — tests use it to simulate
preemption by raising mid-run.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.checkpoint.checkpoint import (
    checkpoint_nbytes,
    latest_step,
    load_checkpoint_tree,
    save_checkpoint,
)
from repro.samplers.engine import EngineResult, MHEngine, parse_collect
from repro.samplers.plan import (
    RunHandle,
    RunPlan,
    carries_logp,
    fingerprint_digest,
)


def _time_axis(engine: MHEngine) -> int:
    """Axis of the kept-step dimension in ``EngineResult.samples``:
    multi-chain runs are chain-major (C, T, *state), solo runs (T, *state)
    — segment streams concatenate along it (DESIGN.md §Chains-axis)."""
    return 1 if engine.config.num_chains > 1 else 0


def _empty_samples(words, axis: int):
    """The engine's ``collect='last'`` placeholder: a 0-length time axis
    in the chain-major layout."""
    shape = list(np.shape(words))
    shape.insert(axis, 0)
    return tuple(shape)


def _assemble(plan, acc, samples_pieces, words, logp, mode, axis):
    """The stitched EngineResult — the engine's own output expressions
    applied to the segment union (engine.py keeps them in one place;
    mirror them exactly or bit-parity dies)."""
    if mode == "last":
        samples = jnp.zeros(_empty_samples(words, axis), jnp.uint32)
    elif len(samples_pieces) == 1:
        samples = jnp.asarray(samples_pieces[0])
    else:
        samples = jnp.concatenate(
            [jnp.asarray(p) for p in samples_pieces], axis=axis
        )
    acc = jnp.asarray(acc)
    total = jnp.float32(plan.n_steps) * jnp.float32(
        max(1, int(np.asarray(plan.init_words).size))
    )
    return EngineResult(
        samples=samples,
        accept_count=acc,
        acceptance_rate=jnp.sum(acc).astype(jnp.float32) / total,
        final_words=jnp.asarray(words),
        final_logp=jnp.asarray(logp),
        n_steps=jnp.int32(plan.n_steps),
    )


def run_resumable(
    engine: MHEngine,
    plan: RunPlan,
    *,
    directory: str,
    every: int | None = None,
    on_segment=None,
    verify: bool = True,
) -> RunHandle:
    """Run ``plan`` in checkpointed segments of ``every`` steps
    (default: the engine's ``chunk_steps``); restart from the newest
    checkpoint in ``directory`` when one exists.

    Returns a ``RunHandle`` whose result is bit-identical to
    ``engine.submit(plan)`` run unsegmented, however many times the
    process died in between.  ``on_segment(done, total, handle)`` fires
    after each segment's checkpoint commits (``handle`` is the segment's
    RunHandle); raising from it abandons the run *after* the save — the
    preemption point tests exploit.
    """
    n_total = int(plan.n_steps)
    base = plan.concrete_step0  # raises on traced offsets — resume is a
    # host-side driver, not a traceable program
    every = int(every) if every else engine.config.chunk_steps
    if every < 1:
        raise ValueError(f"every must be >= 1, got {every}")
    mode, _k = parse_collect(
        plan.collect if plan.collect is not None else engine.config.collect
    )
    axis = _time_axis(engine)
    fingerprint = plan.fingerprint(engine)
    fp = fingerprint_digest(fingerprint)

    # -- restore ------------------------------------------------------------
    done = 0
    acc = np.zeros(np.shape(plan.init_words), np.int32)
    pieces: list = []
    words = plan.init_words
    logp = None
    step = latest_step(directory)
    if step is not None:
        tree, manifest = load_checkpoint_tree(directory, step, verify=verify)
        saved_fp = manifest.get("extra", {}).get("fingerprint")
        if saved_fp != fingerprint:
            raise ValueError(
                f"checkpoint {directory} step {step} was written by a "
                "different run (engine axes / stream key / state layout "
                "differ) — refusing to resume a different chain; "
                f"saved fingerprint {saved_fp!r} != plan {fingerprint!r}"
            )
        done = step - base
        if not 0 < done <= n_total:
            raise ValueError(
                f"checkpoint step {step} is outside this plan's span "
                f"[{base}, {base + n_total}] — wrong directory?"
            )
        acc = tree["acc"]
        words = tree["words"]
        logp = tree["logp"]
        if mode != "last":
            pieces = [tree["samples"]]
        telemetry.log(
            "run_resumable.restore",
            fingerprint=fp, step=int(step), done=int(done),
            total=n_total, directory=directory,
        )

    handle = None
    segment = 0
    while done < n_total:
        seg = min(every, n_total - done)
        if handle is None:
            sub = plan.replace(
                n_steps=seg,
                step0=base + done,
                init_words=words,
                # first segment of a fresh run keeps the plan's own carry;
                # a restored segment re-seeds it from the checkpoint when
                # the engine takes the carry at all
                init_logp=(
                    jnp.asarray(logp)
                    if done and carries_logp(engine, plan.target)
                    else (plan.init_logp if done == 0 else None)
                ),
            )
            handle = engine.submit(sub)
        else:
            handle = handle.resume(seg)
        acc = acc + np.asarray(handle.accept_count)
        if mode != "last":
            pieces.append(np.asarray(handle.samples))
        words = handle.final_words
        logp = handle.final_logp
        done += seg
        ckpt_path = save_checkpoint(
            directory,
            base + done,
            {
                "acc": np.asarray(acc),
                "logp": np.asarray(logp),
                "samples": (
                    np.concatenate(pieces, axis=axis)
                    if len(pieces) > 1
                    else np.asarray(pieces[0])
                )
                if mode != "last"
                else np.zeros(_empty_samples(words, axis), np.uint32),
                "words": np.asarray(words),
            },
            extra={
                "fingerprint": fingerprint,
                "base_step": base,
                "total_steps": n_total,
            },
        )
        telemetry.log(
            "run_resumable.segment",
            fingerprint=fp, segment=segment, step=base + done,
            done=done, total=n_total,
            bytes=checkpoint_nbytes(ckpt_path),
        )
        telemetry.counter(
            "resume_segments_total", "checkpointed segments committed"
        ).inc()
        segment += 1
        if len(pieces) > 1:  # keep the accumulated stream as one block
            pieces = [np.concatenate(pieces, axis=axis)]
        if on_segment is not None:
            on_segment(done, n_total, handle)

    result = _assemble(plan, acc, pieces, words, logp, mode, axis)
    return RunHandle(plan=plan, result=result, engine=engine)
