"""Fault-tolerant, mesh-elastic checkpointing.

Checkpoints are **logical**: every leaf is saved host-resident with its full
logical shape + dtype under a flattened key path, with a JSON manifest
carrying tree structure, shapes, sha256 integrity hashes, and the training
step.  Restore is therefore independent of the mesh the checkpoint was
written under — an elastic restart onto a different pod count / mesh shape
re-shards via ``jax.device_put`` with the *new* shardings (ZeRO-1 state
included, since it is just another pytree).

Durability discipline:
  * writes go to ``<dir>/step_<N>.tmp/`` then a single atomic
    ``os.rename`` to ``step_<N>/`` — a crash mid-write never corrupts an
    existing checkpoint and never leaves a readable-but-partial one.
  * every array file is sha256-hashed into the manifest; ``load`` verifies
    before deserialising (detects torn/bit-rotted files across restarts).
  * ``retention`` keeps the newest K checkpoints (never the one being
    written), deleting older ones only after the rename commits.
  * optional async mode hands the (host-resident) arrays to a writer
    thread so the train loop only blocks on device->host transfer.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading

import jax
import numpy as np

from repro import telemetry


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    directory: str
    retention: int = 3
    async_save: bool = True


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        items.append((key, leaf))
    return items, treedef


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def checkpoint_nbytes(path: str) -> int:
    """Total on-disk bytes of a committed checkpoint (leaf files +
    manifest) — what the save/restore telemetry reports."""
    total = 0
    for name in os.listdir(path):
        try:
            total += os.path.getsize(os.path.join(path, name))
        except OSError:
            pass
    return total


def save_checkpoint(directory: str, step: int, tree, extra: dict | None = None):
    """Atomic, integrity-hashed save of an arbitrary pytree.

    Idempotent per step: a committed checkpoint for ``step`` is left
    untouched (re-saving the same boundary, e.g. periodic + final save
    coinciding, is a no-op rather than a torn rewrite).
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(os.path.join(final, "manifest.json")):
        return final
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    with telemetry.span("checkpoint.save", step=step) as sp:
        items, treedef = _flatten_with_paths(tree)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "extra": extra or {},
            "leaves": [],
        }
        nbytes = 0
        for i, (key, leaf) in enumerate(items):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"leaf_{i:05d}.npy"
            fpath = os.path.join(tmp, fname)
            np.save(fpath, arr, allow_pickle=False)
            nbytes += os.path.getsize(fpath)
            manifest["leaves"].append(
                {
                    "key": key,
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sha256": _sha256(fpath),
                }
            )
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)
        nbytes += os.path.getsize(mpath)
        os.replace(tmp, final)  # atomic commit
        sp.set(leaves=len(items), bytes=nbytes)
    telemetry.counter(
        "checkpoint_bytes_written_total", "committed checkpoint bytes"
    ).inc(nbytes)
    telemetry.counter(
        "checkpoint_saves_total", "committed checkpoint saves"
    ).inc()
    telemetry.log(
        "checkpoint.saved",
        step=step, leaves=len(items), bytes=nbytes, path=final,
    )
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            manifest = os.path.join(directory, name, "manifest.json")
            if os.path.exists(manifest):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint(
    directory: str,
    step: int,
    like_tree,
    shardings=None,
    verify: bool = True,
):
    """Restore into the structure of ``like_tree``; reshard via shardings.

    ``shardings`` may be a pytree of NamedSharding (elastic restore onto the
    *current* mesh) or None (host/SingleDevice arrays).
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with telemetry.span("checkpoint.restore", step=step, verify=verify) as sp:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)

        items, treedef = _flatten_with_paths(like_tree)
        by_key = {e["key"]: e for e in manifest["leaves"]}
        leaves = []
        shard_list = (
            jax.tree.leaves(shardings) if shardings is not None else [None] * len(items)
        )
        for (key, like), sh in zip(items, shard_list):
            entry = by_key.get(key)
            if entry is None:
                raise KeyError(f"checkpoint {path} is missing leaf {key!r}")
            fpath = os.path.join(path, entry["file"])
            if verify and _sha256(fpath) != entry["sha256"]:
                raise IOError(f"integrity check failed for {fpath}")
            arr = np.load(fpath, allow_pickle=False)
            if list(arr.shape) != list(np.shape(like)):
                raise ValueError(
                    f"leaf {key}: checkpoint shape {arr.shape} != expected "
                    f"{np.shape(like)} — config/checkpoint mismatch"
                )
            leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
        sp.set(leaves=len(items), bytes=checkpoint_nbytes(path))
    return jax.tree.unflatten(jax.tree.structure(like_tree), leaves), manifest


def load_checkpoint_tree(directory: str, step: int, verify: bool = True):
    """Restore a checkpoint as a flat ``{key: np.ndarray}`` dict, shapes
    taken from the manifest rather than a ``like_tree``.

    The resume driver (checkpoint/resume.py) needs this because one of
    its leaves — the accumulated sample stream — grows with every
    segment, so the caller cannot know its shape before reading the
    manifest.  Only flat dict trees round-trip here (each manifest key
    is one dict key); ``load_checkpoint`` remains the structured,
    reshardable restore.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with telemetry.span("checkpoint.restore", step=step, verify=verify) as sp:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        tree = {}
        for entry in manifest["leaves"]:
            fpath = os.path.join(path, entry["file"])
            if verify and _sha256(fpath) != entry["sha256"]:
                raise IOError(f"integrity check failed for {fpath}")
            tree[entry["key"]] = np.load(fpath, allow_pickle=False)
        sp.set(leaves=len(tree), bytes=checkpoint_nbytes(path))
    return tree, manifest


class CheckpointManager:
    """Retention + async writes + auto-resume."""

    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        os.makedirs(cfg.directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, extra: dict | None = None):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.cfg.async_save:
            self.wait()  # one outstanding write at a time

            def work():
                try:
                    save_checkpoint(self.cfg.directory, step, host_tree, extra)
                    self._apply_retention()
                except BaseException as e:  # surfaced on next wait()
                    self._error = e

            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            save_checkpoint(self.cfg.directory, step, host_tree, extra)
            self._apply_retention()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _apply_retention(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.cfg.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.cfg.retention] if self.cfg.retention > 0 else []:
            shutil.rmtree(
                os.path.join(self.cfg.directory, f"step_{s:08d}"),
                ignore_errors=True,
            )

    # -- restore --------------------------------------------------------------

    def restore_latest(self, like_tree, shardings=None):
        """(tree, step) from the newest valid checkpoint, or (None, None)."""
        self.wait()
        step = latest_step(self.cfg.directory)
        if step is None:
            return None, None
        tree, _ = load_checkpoint(
            self.cfg.directory, step, like_tree, shardings=shardings
        )
        return tree, step
