from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointManager,
    CheckpointConfig,
    latest_step,
    load_checkpoint,
    load_checkpoint_tree,
    save_checkpoint,
)
from repro.checkpoint.resume import run_resumable  # noqa: F401
