"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2 [hf:microsoft/Phi-3.5-MoE].

32L d_model=4096 32H (GQA kv=8, d_head=128) per-expert d_ff=6400,
MoE 16e top-2, vocab=32064.

EP: 16 experts / 16 model ranks = exactly 1 expert per rank — the cleanest
expert-parallel layout (shard_map manual over "model", combine = one
all-reduce per layer).  Attention: 32 heads shard (layout B for K/V).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=6400,
        vocab_size=32064,
        n_experts=16,
        moe_top_k=2,
        moe_capacity_factor=1.25,
        sharding_overrides=(("cache_seq", ("pod", "data", "model")),),
        train_microbatches=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi35moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=32,
        vocab_size=257,
        n_experts=4,
        moe_top_k=2,
        moe_capacity_factor=2.0,
        dtype="float32",
        param_dtype_str="float32",
        cache_dtype_str="float32",
        attn_block_q=8,
        attn_block_kv=8,
        logits_chunk=16,
        remat_policy="none",
    )
