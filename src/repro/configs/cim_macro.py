"""The paper's own artefact: the 256 kb CIM MCMC macro configuration.

Not an LM architecture — this config parameterises ``repro.core.macro``
exactly as §6.1/Fig. 13(a) of the paper describe the taped-out design.
"""

from repro.core.macro import MacroConfig


def config() -> MacroConfig:
    return MacroConfig(
        n_compartments=64,   # §5.2
        rows=64,
        cols=64,
        nbits=4,             # base precision; expandable to 64 (§5.1)
        cvdd_pseudo_read=0.5,  # V — p_BFR ~ 45 % (§3.1)
        temp_c=25.0,
        rng_bit_width=8,     # accurate [0,1] RNG output width (§4.2)
        rng_stages=3,        # MSXOR stages (§4.2)
        burn_in=500,         # §2.1
    )


def smoke_config() -> MacroConfig:
    return MacroConfig(
        n_compartments=8, rows=16, cols=16, nbits=4, burn_in=50
    )
