"""qwen3-moe-30b-a3b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4, d_head=128, QK-norm) per-expert d_ff=768,
MoE 128e top-8, vocab=151936.  Note h*d_head = 4096 != d_model — correct
per the real model (attention inner dim is wider than the residual).

EP: 128 experts / 16 ranks = 8 experts per rank.  The top-8 routing makes
this the most dispatch-intensive assigned arch — the natural
collective-bound hillclimb candidate.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_head=128,
        d_ff=768,
        vocab_size=151936,
        qk_norm=True,
        n_experts=128,
        moe_top_k=8,
        moe_capacity_factor=1.25,
        sharding_overrides=(("cache_seq", ("pod", "data", "model")),),
        train_microbatches=4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=16,
        vocab_size=260,
        qk_norm=True,
        n_experts=8,
        moe_top_k=2,
        moe_capacity_factor=2.0,
        dtype="float32",
        param_dtype_str="float32",
        cache_dtype_str="float32",
        attn_block_q=8,
        attn_block_kv=8,
        logits_chunk=16,
        remat_policy="none",
    )
