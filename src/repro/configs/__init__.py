"""Architecture registry + assigned input shapes + dry-run input specs.

Each ``<arch>.py`` exports ``config()`` (the exact assigned configuration)
and ``smoke_config()`` (a reduced same-family variant for CPU smoke tests).
``input_specs(cfg, shape)`` builds the allocation-free ShapeDtypeStruct
stand-ins the multi-pod dry-run lowers against.
"""

from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

ARCH_IDS = (
    "hymba_1p5b",
    "phi3_vision_4p2b",
    "mamba2_1p3b",
    "phi3_medium_14b",
    "granite3_8b",
    "minitron_4b",
    "granite_34b",
    "whisper_large_v3",
    "phi35_moe_42b",
    "qwen3_moe_30b",
)

# canonical assignment names -> module ids
ARCH_ALIASES = {
    "hymba-1.5b": "hymba_1p5b",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "mamba2-1.3b": "mamba2_1p3b",
    "phi3-medium-14b": "phi3_medium_14b",
    "granite-3-8b": "granite3_8b",
    "minitron-4b": "minitron_4b",
    "granite-34b": "granite_34b",
    "whisper-large-v3": "whisper_large_v3",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b",
}


def _module(name: str):
    mod_id = ARCH_ALIASES.get(name, name.replace("-", "_").replace(".", "p"))
    return importlib.import_module(f"repro.configs.{mod_id}")


def get_config(name: str):
    return _module(name).config()


def get_smoke_config(name: str):
    return _module(name).smoke_config()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}


# --- assigned shapes -----------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """(applicable, reason).  long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k requires sub-quadratic attention (SSM/hybrid-SWA); "
            f"{cfg.name} is pure full-attention — skipped per the assignment"
        )
    return True, ""


def assigned_cells():
    """All (arch, shape) baseline cells, with applicability flags."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, reason = shape_applicable(cfg, shape)
            cells.append((arch, shape.name, ok, reason))
    return cells


# --- dry-run input specs ---------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg, shape: ShapeSpec):
    """ShapeDtypeStructs for the data batch of one step."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.family == "vlm":
            s_text = s - cfg.n_image_tokens
            return {
                "tokens": _sds((b, s_text), jnp.int32),
                "labels": _sds((b, s_text), jnp.int32),
                "image_embeds": _sds(
                    (b, cfg.n_image_tokens, cfg.image_embed_dim), jnp.bfloat16
                ),
            }
        batch = {
            "tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
        }
        if cfg.is_encdec:
            batch["frames"] = _sds(
                (b, cfg.encoder_len, cfg.frame_dim), jnp.bfloat16
            )
        return batch
    if shape.kind == "prefill":
        if cfg.family == "vlm":
            s_text = s - cfg.n_image_tokens
            return {
                "tokens": _sds((b, s_text), jnp.int32),
                "image_embeds": _sds(
                    (b, cfg.n_image_tokens, cfg.image_embed_dim), jnp.bfloat16
                ),
            }
        batch = {"tokens": _sds((b, s), jnp.int32)}
        if cfg.is_encdec:
            batch["frames"] = _sds(
                (b, cfg.encoder_len, cfg.frame_dim), jnp.bfloat16
            )
        return batch
    # decode: one new token against a seq_len-deep cache
    return {"tokens": _sds((b, 1), jnp.int32)}


def cache_specs(cfg, shape: ShapeSpec):
    """ShapeDtypeStruct cache skeleton (decode/prefill cells only)."""
    from repro.models import lm

    if shape.kind == "train":
        return None
    return lm.abstract_cache(cfg, shape.global_batch, shape.seq_len)
