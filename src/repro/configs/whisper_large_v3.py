"""whisper-large-v3 [audio] — enc-dec, conv frontend stub [arXiv:2212.04356].

32L (decoder) + 32L (encoder) d_model=1280 20H (MHA kv=20, d_head=64)
d_ff=5120 vocab=51866.  Per the assignment the mel/conv frontend is a
STUB: ``input_specs`` provides precomputed frame embeddings
(encoder_len=1500 x frame_dim=128); the encoder is bidirectional with
learned positions, the decoder has causal self-attn + cross-attn.

Backbone adaptation notes (DESIGN.md): decoder self-attention uses RoPE
(the original uses learned absolute positions — backbone-only spec);
pre-LN layernorm, GELU, ungated MLP as in the original.

TP: 20 heads not 16-divisible -> attention replicates on (16,16)
(a (64,4) mesh restores it: 20 % 4 == 0); d_ff = 5120 = 16 x 320 shards.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="audio",
        n_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_head=64,
        d_ff=5120,
        vocab_size=51866,
        act="gelu",
        mlp_gated=False,
        norm="layernorm",
        n_encoder_layers=32,
        encoder_len=1500,
        frame_dim=128,
        sharding_overrides=(("cache_seq", ("pod", "data", "model")),),
        train_microbatches=4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab_size=258,
        act="gelu",
        mlp_gated=False,
        norm="layernorm",
        n_encoder_layers=2,
        encoder_len=12,
        frame_dim=16,
        dtype="float32",
        param_dtype_str="float32",
        cache_dtype_str="float32",
        attn_block_q=8,
        attn_block_kv=8,
        logits_chunk=16,
        remat_policy="none",
    )
