"""mamba2-1.3b [ssm] — SSD state-space duality [arXiv:2405.21060].

48L d_model=2048 attention-free, ssm_state=128.  Standard Mamba-2 sizing:
expand=2 -> d_inner=4096 = 64 heads x head_dim 64; conv width 4; one
B/C group.  O(1) decode state makes every decode shape (incl. long_500k)
native.

TP: 64 ssm heads / 16 = 4 heads per model rank.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=0,
        n_kv_heads=0,
        d_head=0,
        d_ff=0,
        vocab_size=50280,
        ssm_heads=64,
        ssm_head_dim=64,
        ssm_state=128,
        ssm_groups=1,
        ssm_conv=4,
        ssm_chunk=256,
        train_microbatches=4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        vocab_size=257,
        ssm_heads=4,
        ssm_head_dim=16,
        ssm_state=16,
        ssm_groups=1,
        ssm_chunk=8,
        dtype="float32",
        param_dtype_str="float32",
        cache_dtype_str="float32",
        logits_chunk=16,
        remat_policy="none",
    )
