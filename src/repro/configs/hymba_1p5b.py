"""hymba-1.5b [hybrid] — parallel attn+mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5, d_head=64) d_ff=5504 vocab=32001,
ssm_state=16.  Hymba runs sliding-window attention in all but 3 global
layers (first / middle / last) — which makes it (with mamba2) one of the
two long_500k-eligible architectures.

TP notes (16-wide "model" axis): 25 heads / 5 kv heads / 25 ssm heads are
not 16-divisible -> attention & SSM weights replicate (divisibility filter);
d_ff = 5504 = 16 x 344 shards.  The decode KV cache seq-shards instead
(cache_seq override).  See DESIGN.md §Arch-applicability.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_head=64,
        d_ff=5504,
        vocab_size=32001,
        ssm_heads=25,
        ssm_head_dim=64,
        ssm_state=16,
        ssm_groups=1,
        ssm_chunk=256,
        sliding_window=1024,
        global_layers=(0, 15, 31),
        sharding_overrides=(("cache_seq", ("pod", "data", "model")),),
        train_microbatches=4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke",
        family="hybrid",
        n_layers=2,
        d_model=64,
        n_heads=5,
        n_kv_heads=1,
        d_head=16,
        d_ff=128,
        vocab_size=257,
        ssm_heads=5,
        ssm_head_dim=16,
        ssm_state=8,
        ssm_groups=1,
        ssm_chunk=8,
        sliding_window=8,
        global_layers=(0,),
        dtype="float32",
        param_dtype_str="float32",
        cache_dtype_str="float32",
        attn_block_q=8,
        attn_block_kv=8,
        logits_chunk=16,
        remat_policy="none",
    )
