"""minitron-4b [dense] — pruned nemotron [arXiv:2407.14679; hf].

32L d_model=3072 24H (GQA kv=8, d_head=128) d_ff=9216 vocab=256000.
The 256k vocabulary makes the logits head the dominant memory term —
exactly the workload the chunked-CE path exists for.

TP: 24 heads / 8 kv not 16-divisible -> attention replicates on (16,16)
(the (32,8) mesh restores it: 24 % 8 == 0 — §Perf lever).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_head=128,
        d_ff=9216,
        vocab_size=256000,
        sharding_overrides=(("cache_seq", ("pod", "data", "model")),),
        train_microbatches=4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=6,
        n_kv_heads=2,
        d_head=16,
        d_ff=192,
        vocab_size=512,
        dtype="float32",
        param_dtype_str="float32",
        cache_dtype_str="float32",
        attn_block_q=8,
        attn_block_kv=8,
        logits_chunk=16,
        remat_policy="none",
    )
