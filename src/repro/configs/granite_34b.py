"""granite-34b [dense] — llama-arch code model, MQA [arXiv:2405.04324].

88L d_model=6144 48H (MQA kv=1, d_head=128) d_ff=24576 vocab=49152.
The deepest assigned arch — the scan-over-layers requirement exists for
this config (88 unrolled layers x 512 fake devices would not compile on
one CPU).

TP: 48 heads -> layout B (MQA K/V broadcast to 48 heads); the single kv
head replicates in the cache, which therefore seq-shards.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b",
        family="dense",
        n_layers=88,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_head=128,
        d_ff=24576,
        vocab_size=49152,
        act="gelu",
        mlp_gated=False,   # GPT-BigCode style FFN (2 mats) -> 34B total
        sharding_overrides=(("cache_seq", ("pod", "data", "model")),),
        train_microbatches=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite34-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        dtype="float32",
        param_dtype_str="float32",
        cache_dtype_str="float32",
        attn_block_q=8,
        attn_block_kv=8,
        logits_chunk=16,
        remat_policy="none",
    )
