"""phi3-medium-14b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219].

40L d_model=5120 40H (GQA kv=10, d_head=128) d_ff=17920 vocab=100352.

TP: 40 heads / 10 kv heads are not 16-divisible -> attention weights
replicate on the 16-wide model axis (d_ff = 17920 = 16 x 1120 shards);
an alternative (32,8) mesh restores attention TP — a §Perf lever.
Decode cache seq-shards (cache_seq override).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=10,
        d_head=128,
        d_ff=17920,
        vocab_size=100352,
        sharding_overrides=(("cache_seq", ("pod", "data", "model")),),
        train_microbatches=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3m-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=160,
        vocab_size=257,
        dtype="float32",
        param_dtype_str="float32",
        cache_dtype_str="float32",
        attn_block_q=8,
        attn_block_kv=8,
        logits_chunk=16,
        remat_policy="none",
    )
