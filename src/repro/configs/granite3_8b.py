"""granite-3-8b [dense] — GQA [hf:ibm-granite/granite-3.0].

40L d_model=4096 32H (GQA kv=8, d_head=128) d_ff=12800 vocab=49155.

TP: 32 heads divide 16 but kv=8 does not -> GQA layout B (K/V repeated to
32 heads inside attention, flat head axis shards).  Decode cache keeps the
8 kv heads and seq-shards over "model".
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=12800,
        vocab_size=49155,
        sharding_overrides=(("cache_seq", ("pod", "data", "model")),),
        train_microbatches=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite3-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_head=16,
        d_ff=128,
        vocab_size=259,
        dtype="float32",
        param_dtype_str="float32",
        cache_dtype_str="float32",
        attn_block_q=8,
        attn_block_kv=8,
        logits_chunk=16,
        remat_policy="none",
    )
