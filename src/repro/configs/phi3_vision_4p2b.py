"""phi-3-vision-4.2b [vlm] — phi3-mini + CLIP [hf:microsoft/Phi-3-vision].

32L d_model=3072 32H (MHA kv=32, d_head=96) d_ff=8192 vocab=32064.
The CLIP frontend is a stub per the assignment: ``input_specs`` provides
precomputed patch embeddings (n_image_tokens x image_embed_dim), spliced
in front of the text tokens; the MCMC sampler drives text decode only.

TP: 32 heads (and 32 kv) divide 16 -> full attention TP (layout A).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_head=96,
        d_ff=8192,
        vocab_size=32064,
        n_image_tokens=576,       # 336px CLIP ViT-L/14 -> 24x24 patches
        image_embed_dim=1024,
        train_microbatches=4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3v-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab_size=257,
        n_image_tokens=4,
        image_embed_dim=32,
        dtype="float32",
        param_dtype_str="float32",
        cache_dtype_str="float32",
        attn_block_q=8,
        attn_block_kv=8,
        logits_chunk=16,
        remat_policy="none",
    )
