"""Randomness axis of the sampler engine — where the MH random bits come from.

One MH step consumes two random operands per chain (paper Fig. 14):

  * a *flip word* whose low ``nbits`` bit-planes are i.i.d.
    Bernoulli(p_BFR) — the block-wise pseudo-read proposal, and
  * a uniform ``u`` in [0, 1) — the accurate-[0,1]-RNG accept threshold.

Three backends implement the same ``RandomnessBackend`` protocol
(DESIGN.md §Randomness):

  * ``HostRandomness``  — plain ``jax.random``: ideal float32 uniforms and
    directly-drawn Bernoulli bit-planes.  The software baseline.
  * ``CIMRandomness``   — the paper's circuit pipeline: biased pseudo-read
    bit-planes (``bitcell.raw_random_words``) for the proposal, and
    reset -> pseudo-read -> MSXOR-fold -> pack for ``u``
    (``uniform_rng.uniform``), including the residual debias error.
  * ``FusedRandomness`` — the paper's *placement*: the random bits are
    generated inside the thing doing the sampling.  Under pallas
    execution the fused kernels derive every operand in-kernel from a
    counter cipher (kernels/rng) keyed on ``(chain key, absolute step,
    site)`` — zero per-step operand traffic; this backend's ``chunk`` is
    the scan-side *reference* that draws the identical stream through
    the same shared functions, so {scan, pallas} stay bit-exact.

Chunked streaming contract (DESIGN.md §2): the operands for step ``t``
depend only on ``(key, t)`` — host/cim derive per-step keys via
``jax.random.fold_in(key, t)``, fused folds ``t`` into the counter
cipher — so a chain may be generated in chunks of any size and the
resulting stream is *bit-identical* to the monolithic (K, B, C)
materialisation.  Long chains are therefore memory-bounded by the chunk
size, not the chain length.

Operand-lean mode (DESIGN.md §Collection): consumers that never read the
flip words — the Gibbs update rule draws no proposal, and the tempering
swap test needs only a uniform — pass ``need_flips=False`` and the
backend skips flip-plane generation entirely.  The u stream stays
*bit-identical* because every backend separates the operand streams
before drawing: host/cim split the step key into ``(k_flip, k_u)``,
fused salts the counter per operand — neither depends on whether the
flip stream was ever consumed (asserted in tests/test_collection.py).
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import bitcell, uniform_rng
from repro.kernels import rng

Array = jnp.ndarray


def chain_key(key, chain_id) -> Array:
    """Counter-based per-chain key (DESIGN.md §Chains-axis).

    Every engine run — solo or multi-chain — derives its stream from
    ``fold_in(key, chain_id)`` and then per-step ``fold_in(·, t)``, so
    the operands for (chain c, step t) are a pure function of
    ``(key, c, t)``.  Chain c of a C-chain run is therefore bit-identical
    to a solo run launched with ``chain_id=c``, for any C.
    """
    return jax.random.fold_in(key, chain_id)


def chain_keys(key, num_chains: int, base: int = 0) -> Array:
    """Stacked per-chain keys for chains [base, base + num_chains)."""
    ids = base + jnp.arange(num_chains, dtype=jnp.int32)
    return jax.vmap(lambda c: chain_key(key, c))(ids)


def step_keys(key, start, n_steps: int) -> Array:
    """Per-step keys for absolute steps [start, start + n_steps)."""
    ts = jnp.asarray(start, jnp.int32) + jnp.arange(n_steps, dtype=jnp.int32)
    return jax.vmap(lambda t: jax.random.fold_in(key, t))(ts)


@runtime_checkable
class RandomnessBackend(Protocol):
    """Produces the (flips, u) operand stream for a span of MH steps."""

    name: str

    def chunk(
        self, key, start, n_steps: int, shape: tuple, nbits: int,
        need_flips: bool = True,
    ) -> tuple[Array | None, Array]:
        """Operands for steps [start, start+n_steps).

        Returns (flips (n_steps, *shape) uint32, u (n_steps, *shape)
        float32).  ``start`` may be a traced integer.
        ``need_flips=False`` skips flip-plane generation and returns
        ``(None, u)`` with a bit-identical u stream (the step key is
        split before either operand is drawn).
        """
        ...


@dataclasses.dataclass(frozen=True)
class HostRandomness:
    """Ideal software randomness — the baseline the CIM pipeline replaces."""

    p_bfr: float = 0.45

    name = "host"

    def chunk(self, key, start, n_steps, shape, nbits, need_flips=True):
        def one(k):
            k_flip, k_u = jax.random.split(k)
            u = jax.random.uniform(k_u, shape, jnp.float32)
            if not need_flips:
                return u
            planes = jax.random.bernoulli(k_flip, self.p_bfr, (*shape, nbits))
            weights = (
                jnp.uint32(1) << jnp.arange(nbits, dtype=jnp.uint32)
            ).astype(jnp.uint32)
            flips = jnp.sum(
                planes.astype(jnp.uint32) * weights, axis=-1
            ).astype(jnp.uint32)
            return flips, u

        out = jax.vmap(one)(step_keys(key, start, n_steps))
        return out if need_flips else (None, out)


@dataclasses.dataclass(frozen=True)
class CIMRandomness:
    """Paper-faithful randomness: pseudo-read bit-planes + MSXOR uniforms."""

    p_bfr: float = 0.45            # proposal pseudo-read flip rate
    rng_p_bfr: float = 0.45        # [0,1]-RNG sub-array raw-bit bias
    rng_bit_width: int = 16        # packed debiased bits per uniform
    rng_stages: int = 3            # MSXOR fold stages

    name = "cim"

    def chunk(self, key, start, n_steps, shape, nbits, need_flips=True):
        def one(k):
            k_flip, k_u = jax.random.split(k)
            u = uniform_rng.uniform(
                k_u, shape, self.rng_p_bfr, self.rng_bit_width, self.rng_stages
            )
            if not need_flips:
                return u
            flips = bitcell.raw_random_words(
                k_flip, self.p_bfr, shape, nbits=nbits
            )
            return flips, u

        out = jax.vmap(one)(step_keys(key, start, n_steps))
        return out if need_flips else (None, out)


@dataclasses.dataclass(frozen=True)
class FusedRandomness:
    """In-kernel counter RNG — the scan-side reference stream.

    The stream contract (kernels/rng): operand for (chain, step t, site
    s) = Threefry-2x32 of the ``(t, s)`` counter under the chain key's
    two uint32 words, salted per operand.  Under pallas execution the
    fused kernels make exactly these draws *inside* the kernel — no
    operand tensors exist; this ``chunk`` materialises the identical
    values through the same shared functions for the scan executors
    (and for the tempering swap test), keeping the engine's bit-parity
    contract alive across {scan, pallas} (tests/test_fused_rng.py).
    """

    p_bfr: float = 0.45

    name = "fused"

    def chunk(self, key, start, n_steps, shape, nbits, need_flips=True):
        k0, k1 = rng.key_words(key)
        site = rng.site_index(shape)
        p_u32 = rng.threshold_u32(self.p_bfr)

        def one(t):
            s0, s1 = rng.step_key(k0, k1, t)
            u = rng.uniform_at(s0, s1, site)
            if not need_flips:
                return u
            return rng.flips_at(s0, s1, site, nbits, p_u32), u

        ts = jnp.asarray(start, jnp.int32) + jnp.arange(
            n_steps, dtype=jnp.int32
        )
        out = jax.vmap(one)(ts)
        return out if need_flips else (None, out)


def make_randomness_backend(
    name: str,
    p_bfr: float,
    rng_p_bfr: float | None = None,
    rng_bit_width: int = 16,
    rng_stages: int = 3,
) -> RandomnessBackend:
    if name == "host":
        return HostRandomness(p_bfr=p_bfr)
    if name == "cim":
        return CIMRandomness(
            p_bfr=p_bfr,
            rng_p_bfr=p_bfr if rng_p_bfr is None else rng_p_bfr,
            rng_bit_width=rng_bit_width,
            rng_stages=rng_stages,
        )
    if name == "fused":
        return FusedRandomness(p_bfr=p_bfr)
    raise ValueError(
        f"unknown randomness backend {name!r} (host|cim|fused)"
    )
