"""Measured engine constants: the per-(workload, shape, device) autotuner.

``EngineConfig.chunk_steps`` / ``block_c`` / ``execution`` were
hand-chosen constants — right for the machine they were tuned on, wrong
everywhere else.  This module measures them the way the bench harness
does (warm-up compile, then best-of-N wall-clock on a short run —
benchmarks/bench_workloads.py) and caches the winner per

    (update rule, randomness, target kind, state shape/dtype,
     num_chains, collect, platform, device kind, device count)

so a given workload shape pays the measurement once per machine.  The
candidate grid ALWAYS contains the incumbent config, and the winner is
the measured argmax — so a tuned config is never slower than the
hand-chosen constants *under the tuner's own measurement protocol* (the
bench-gate guarantee, benchmarks/bench_autotune.py).

Chunking and executor choice never change the sample stream (DESIGN.md
§2: operands are keyed on absolute step; scan and pallas mirror each
other op-for-op), so tuning is free to move them between runs — even
across a checkpoint/resume boundary (checkpoint/resume.py excludes them
from the resume fingerprint for exactly this reason).

Cache location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune.json``.  Writes are atomic (tmp + rename),
mirroring the checkpoint subsystem's durability idiom.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import jax

from repro import telemetry
from repro.samplers.engine import EngineConfig, MHEngine, resolve_execution
from repro.samplers.plan import RunPlan

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
CACHE_VERSION = 1

# Small by design: each candidate costs one compile.  Callers with
# patience (bench_autotune's full preset) pass a wider grid.
DEFAULT_CHUNK_CANDIDATES = (16, 64, 256)
DEFAULT_BLOCK_C_CANDIDATES = (128, 256)


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """One tuning outcome: the winning constants plus the evidence."""

    chunk_steps: int
    block_c: int
    execution: str
    steps_per_s: float
    # the incumbent (hand-chosen) config measured under the identical
    # protocol — the bench gate reports tuned vs this
    baseline_steps_per_s: float
    source: str  # "measured" | "cache"
    # ((chunk_steps, block_c, execution, steps_per_s), ...) for the report
    candidates: tuple = ()


def default_cache_path() -> str:
    env = os.environ.get(CACHE_ENV)
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "autotune.json"
    )


def tune_key(config: EngineConfig, target, init_words) -> str:
    """The cache identity: what the measurement depends on — workload
    kind + state layout + engine axes + device — and nothing it doesn't
    (the tuned knobs themselves, seeds, step counts)."""
    devices = jax.devices()
    words = jax.numpy.asarray(init_words)
    parts = (
        config.update,
        config.randomness,
        type(target).__name__,
        "x".join(str(int(s)) for s in words.shape) or "scalar",
        str(words.dtype),
        f"C{config.num_chains}",
        config.collect,
        jax.default_backend(),
        devices[0].device_kind.replace(" ", "_"),
        f"D{len(devices)}",
    )
    return "|".join(parts)


def _load_cache(path: str) -> dict:
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return {}
    return data if isinstance(data, dict) else {}


def _store_cache(path: str, cache: dict) -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(cache, f, indent=1, sort_keys=True)
    os.replace(tmp, path)  # atomic: readers never see a torn file


def _eligible_executions(config: EngineConfig, target) -> list[str]:
    """Concrete backends worth measuring: always scan, plus pallas when
    the target/rule can fuse.  An explicit config.execution pin narrows
    the grid to that backend (the user already chose)."""
    if config.execution in ("scan", "pallas"):
        return [config.execution]
    out = ["scan"]
    try:
        resolve_execution("pallas", target, config.update)
        out.append("pallas")
    except ValueError:
        pass
    return out


def measure_config(
    config: EngineConfig, target, init_words, *, key=None,
    n_steps: int = 256, repeats: int = 3,
) -> float:
    """Best-of-N steps/s of one candidate config — the bench harness
    protocol (warm-up pays the compile; the minimum tracks compute on a
    loaded machine).  Raises whatever the engine raises on an ineligible
    candidate (shape/backend) — callers filter."""
    engine = MHEngine(config)
    plan = RunPlan(
        target=target,
        n_steps=n_steps,
        init_words=init_words,
        key=key if key is not None else jax.random.PRNGKey(0),
    )
    jax.block_until_ready(
        engine.submit(plan, compiled=True).result.final_words
    )
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        r = engine.submit(plan, compiled=True).result
        jax.block_until_ready(r.final_words)
        best = min(best, time.perf_counter() - t0)
    size = max(1, int(jax.numpy.asarray(init_words).size))
    return n_steps * size / max(best, 1e-9)


def autotune_config(
    config: EngineConfig,
    target,
    init_words,
    *,
    key=None,
    n_steps: int = 256,
    repeats: int = 3,
    chunk_candidates=DEFAULT_CHUNK_CANDIDATES,
    block_c_candidates=DEFAULT_BLOCK_C_CANDIDATES,
    cache_path: str | None = None,
    refresh: bool = False,
) -> tuple[EngineConfig, TuneResult]:
    """Tuned ``(config, evidence)`` for this (workload, shape, device).

    Cache hit: returns the stored winner without measuring.  Miss (or
    ``refresh=True``): measures the candidate grid — incumbent first, so
    the argmax can never lose to it — stores, and returns.  Candidates
    the engine rejects (pallas on an unfusable target/shape) are
    silently dropped; the incumbent itself failing is an error.
    """
    path = cache_path if cache_path is not None else default_cache_path()
    ckey = tune_key(config, target, init_words)
    cache = _load_cache(path)
    hit = cache.get(ckey)
    if hit and not refresh and hit.get("version") == CACHE_VERSION:
        tuned = dataclasses.replace(
            config,
            chunk_steps=int(hit["chunk_steps"]),
            block_c=int(hit["block_c"]),
            execution=str(hit["execution"]),
        )
        return tuned, TuneResult(
            chunk_steps=int(hit["chunk_steps"]),
            block_c=int(hit["block_c"]),
            execution=str(hit["execution"]),
            steps_per_s=float(hit["steps_per_s"]),
            baseline_steps_per_s=float(hit["baseline_steps_per_s"]),
            source="cache",
            candidates=tuple(
                tuple(c) for c in hit.get("candidates", ())
            ),
        )

    executions = _eligible_executions(config, target)
    incumbent_exec = (
        config.execution
        if config.execution in ("scan", "pallas")
        else resolve_execution(config.execution, target, config.update)
    )
    grid: list[tuple[int, int, str]] = [
        (config.chunk_steps, config.block_c, incumbent_exec)
    ]
    for execution in executions:
        blocks = (
            block_c_candidates
            if (execution == "pallas" and config.update == "mh")
            else (config.block_c,)
        )
        for chunk in chunk_candidates:
            for block_c in blocks:
                cand = (int(chunk), int(block_c), execution)
                if cand not in grid:
                    grid.append(cand)

    measured: list[tuple[int, int, str, float]] = []
    for i, (chunk, block_c, execution) in enumerate(grid):
        cand_cfg = dataclasses.replace(
            config, chunk_steps=chunk, block_c=block_c, execution=execution
        )
        with telemetry.span(
            "autotune.measure",
            chunk_steps=chunk, block_c=block_c, execution=execution,
            incumbent=(i == 0),
        ) as sp:
            try:
                rate = measure_config(
                    cand_cfg, target, init_words, key=key, n_steps=n_steps,
                    repeats=repeats,
                )
            except Exception:
                sp.set(outcome="ineligible")
                if i == 0:  # the incumbent must run — no fallback
                    raise
                continue
            sp.set(outcome="ok", steps_per_s=round(rate, 1))
        measured.append((chunk, block_c, execution, rate))

    baseline_rate = measured[0][3]
    chunk, block_c, execution, rate = max(measured, key=lambda m: m[3])
    telemetry.log(
        "autotune.result",
        chunk_steps=chunk, block_c=block_c, execution=execution,
        steps_per_s=round(rate, 1),
        baseline_steps_per_s=round(baseline_rate, 1),
        candidates=len(measured),
    )
    result = TuneResult(
        chunk_steps=chunk,
        block_c=block_c,
        execution=execution,
        steps_per_s=rate,
        baseline_steps_per_s=baseline_rate,
        source="measured",
        candidates=tuple(measured),
    )
    cache[ckey] = {
        "version": CACHE_VERSION,
        "chunk_steps": chunk,
        "block_c": block_c,
        "execution": execution,
        "steps_per_s": rate,
        "baseline_steps_per_s": baseline_rate,
        "candidates": [list(m) for m in measured],
    }
    _store_cache(path, cache)
    tuned = dataclasses.replace(
        config, chunk_steps=chunk, block_c=block_c, execution=execution
    )
    return tuned, result


def autotune_engine(
    engine: MHEngine, target, init_words, **kwargs
) -> tuple[MHEngine, TuneResult]:
    """``autotune_config`` for an existing engine: returns a fresh engine
    on the tuned config (engines are cheap; the jit caches key on engine
    identity, so a new instance also keeps tuned traces separate)."""
    tuned_cfg, result = autotune_config(
        engine.config, target, init_words, **kwargs
    )
    return MHEngine(tuned_cfg), result
