"""Target axis of the sampler engine — what distribution the chain samples.

Three target kinds (DESIGN.md §2):

  * ``CallableTarget``  — an arbitrary log-prob function over k-bit words
    (GMM/MGD grid targets, user densities).  Scan execution only: the
    fused Pallas kernel needs the distribution materialised as a table.
  * ``TableTarget``     — an explicit (B, V) table of unnormalised
    log-probs (logits); B independent targets, each sampled by C chains
    in lock-step.  Eligible for the fused Pallas kernel.
  * ``TopKTarget``      — a TableTarget restricted to the top-k logits of
    each row (beyond-paper latency knob); ``decode`` maps chain words
    back to vocabulary ids.

Targets are identity-hashed (no dataclass eq) so they can ride through
``jax.jit`` static arguments exactly like the closures they replace.

The table lookup here is bit-exact w.r.t. the Pallas kernel's in-VMEM
lookup (clamp + mask-to--inf), which is what makes scan/pallas parity an
exact array equality rather than a statistical statement.
"""

from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

Array = jnp.ndarray
LogProbFn = Callable[[Array], Array]


class CallableTarget:
    """log p given as a function over integer words; any chain shape."""

    table: Array | None = None

    def __init__(self, log_prob_fn: LogProbFn, nbits: int):
        if not 1 <= nbits <= 32:
            raise ValueError(f"nbits must be in [1,32], got {nbits}")
        self.log_prob_fn = log_prob_fn
        self.nbits = nbits

    def log_prob(self, words: Array) -> Array:
        return self.log_prob_fn(words)

    def decode(self, words: Array) -> Array:
        return words


class TableTarget:
    """log p given as a (B, V) table; chain state has shape (B, C).

    The lookup mirrors the fused kernel's semantics exactly: indices are
    clamped for the gather, then out-of-support words (V is rarely a
    power of two) get log p = -inf so they are always rejected.
    """

    def __init__(self, table: Array, nbits: int | None = None):
        table = jnp.asarray(table, jnp.float32)
        if table.ndim != 2:
            raise ValueError(f"table must be (B, V), got {table.shape}")
        self.table = table
        self.vocab = table.shape[-1]
        self.nbits = nbits or max(1, math.ceil(math.log2(self.vocab)))

    def log_prob(self, words: Array) -> Array:
        safe = jnp.minimum(words, jnp.uint32(self.vocab - 1)).astype(jnp.int32)
        vals = jnp.take_along_axis(self.table, safe, axis=-1)
        return jnp.where(words < self.vocab, vals, -jnp.inf)

    def decode(self, words: Array) -> Array:
        return words.astype(jnp.int32)


class TopKTarget(TableTarget):
    """TableTarget over each row's top-k logits; decode maps back to ids."""

    def __init__(self, logits: Array, top_k: int, temperature: float = 1.0):
        logits = jnp.asarray(logits, jnp.float32)
        if not 0 < top_k <= logits.shape[-1]:
            raise ValueError(
                f"top_k must be in (0, V={logits.shape[-1]}], got {top_k}"
            )
        top_vals, top_idx = jax.lax.top_k(logits, top_k)
        super().__init__(top_vals / temperature)
        self.top_idx = top_idx

    def decode(self, words: Array) -> Array:
        return jnp.take_along_axis(
            self.top_idx, words.astype(jnp.int32), axis=-1
        )


def logits_target(
    logits: Array, temperature: float = 1.0, top_k: int = 0
) -> TableTarget:
    """The token-sampling target: full-vocab table or top-k restriction."""
    if top_k > 0:
        return TopKTarget(logits, top_k, temperature)
    return TableTarget(jnp.asarray(logits, jnp.float32) / temperature)
