# The unified sampler engine (DESIGN.md §2): one chain datapath,
# pluggable on five orthogonal axes —
#
#   targets      what the chain samples (callable log-prob / (B,V) table /
#                top-k-restricted logits / conditional lattice models)
#   update rule  how a step rewrites the state (MH accept test vs Gibbs
#                conditional flip)
#   randomness   where the random operands come from (host jax.random /
#                the CIM pseudo-read + MSXOR pipeline / the in-kernel
#                fused counter cipher), streamed in chunks
#   engine       how steps execute (pure-JAX lax.scan vs the fused Pallas
#                kernel), auto-dispatched by jax.default_backend()
#   collection   how much of the chain leaves the engine (all states /
#                every k-th absolute step / final state only)
#
# core/metropolis.py, core/token_sampler.py, core/macro.py and
# launch/serve.py are all thin layers over this package.

from repro.samplers.engine import (  # noqa: F401
    EngineConfig,
    EngineResult,
    MHEngine,
    SamplerEngine,
    kept_count,
    parse_collect,
    resolve_execution,
    run_engine,
)
from repro.samplers.randomness import (  # noqa: F401
    CIMRandomness,
    FusedRandomness,
    HostRandomness,
    RandomnessBackend,
    chain_key,
    chain_keys,
    make_randomness_backend,
)
from repro.samplers.targets import (  # noqa: F401
    CallableTarget,
    TableTarget,
    TopKTarget,
    logits_target,
)
