# The unified sampler engine (DESIGN.md §2): one chain datapath,
# pluggable on five orthogonal axes —
#
#   targets      what the chain samples (callable log-prob / (B,V) table /
#                top-k-restricted logits / conditional lattice models)
#   update rule  how a step rewrites the state (MH accept test vs Gibbs
#                conditional flip)
#   randomness   where the random operands come from (host jax.random /
#                the CIM pseudo-read + MSXOR pipeline / the in-kernel
#                fused counter cipher), streamed in chunks
#   engine       how steps execute (pure-JAX lax.scan vs the fused Pallas
#                kernel), auto-dispatched by jax.default_backend()
#   collection   how much of the chain leaves the engine (all states /
#                every k-th absolute step / final state only)
#
# The documented way to launch a run is the RunPlan surface (DESIGN.md
# §Run-API): build a RunPlan, call MHEngine.submit, continue from the
# returned RunHandle.  `run_engine` and the core/metropolis.py /
# core/token_sampler.py wrappers are deprecated shims over it (they
# warn, but stay bit-compatible); core/macro.py and launch/serve.py are
# thin layers over this package.

from repro.samplers.autotune import (
    TuneResult,
    autotune_config,
    autotune_engine,
)
from repro.samplers.engine import (
    EngineConfig,
    EngineResult,
    MHEngine,
    SamplerEngine,
    kept_count,
    parse_collect,
    resolve_execution,
    run_engine,
)
from repro.samplers.plan import (
    RunHandle,
    RunPlan,
    submit,
)
from repro.samplers.randomness import (
    CIMRandomness,
    FusedRandomness,
    HostRandomness,
    RandomnessBackend,
    chain_key,
    chain_keys,
    make_randomness_backend,
)
from repro.samplers.targets import (
    CallableTarget,
    TableTarget,
    TopKTarget,
    logits_target,
)

__all__ = [
    # the run surface (DESIGN.md §Run-API)
    "RunPlan",
    "RunHandle",
    "submit",
    "MHEngine",
    "SamplerEngine",
    "EngineConfig",
    "EngineResult",
    # autotuner (measured chunk_steps/block_c/backend)
    "TuneResult",
    "autotune_config",
    "autotune_engine",
    # axis helpers
    "kept_count",
    "parse_collect",
    "resolve_execution",
    # randomness backends
    "RandomnessBackend",
    "HostRandomness",
    "CIMRandomness",
    "FusedRandomness",
    "make_randomness_backend",
    "chain_key",
    "chain_keys",
    # targets
    "CallableTarget",
    "TableTarget",
    "TopKTarget",
    "logits_target",
    # deprecated shims (warn on call; see also core.metropolis.run_chain
    # and core.token_sampler.sample_tokens)
    "run_engine",
]
