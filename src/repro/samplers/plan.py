"""The run surface: ``RunPlan`` in, ``RunHandle`` out (DESIGN.md §Run-API).

``engine.run`` grew organically — seven positional/keyword arguments, a
carried ``(step0, words, logp)`` resume triple that three subsystems
(tempering, serving, checkpointing) each re-threaded by hand, and a
separate jitted twin (``run_engine``).  ``RunPlan`` collapses that into
one validated spec:

  * **what to sample** — ``target``, ``n_steps``, ``collect``;
  * **which stream**  — ``key`` *or* ``seed`` (exactly one), ``chain_id``;
  * **where to run**  — ``mesh`` (the engine's "chains" sharding rule);
  * **the resume carry** — ``step0`` + ``init_words`` + optional
    ``init_logp``: the exact state a previous segment handed back, so a
    plan *is* a resumable description of the remaining work.

``MHEngine.submit(plan)`` validates the spec against the engine's
config and runs it; the returned ``RunHandle`` carries the result plus
the plan that produced it, and ``handle.resume(n)`` derives the
continuation plan (``step0`` advanced, ``init_words``/``init_logp``
carried) whose stream is bit-identical to one unsegmented run — the
engine's segment-invariance contract (DESIGN.md §Tempering) surfaced as
an object instead of a calling convention.

Everything here is traceable: plans may hold traced arrays (the serving
tier builds plans with traced ``step0`` inside its vmapped segment
program), and validation only inspects python-level structure.  The
``compiled=True`` path routes through a cached jitted dispatcher — the
one-dispatch entry that replaced ``run_engine`` (now a deprecated shim).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from functools import partial
from typing import Any

import jax

from repro import telemetry
from repro.samplers.engine import (
    EngineResult,
    MHEngine,
    parse_collect,
    resolve_execution,
)


def fingerprint_digest(fingerprint: dict) -> str:
    """A short stable identity of a :meth:`RunPlan.fingerprint` dict —
    what the telemetry log lines print so killed-run forensics can match
    checkpoints to runs without dumping the whole key."""
    blob = json.dumps(fingerprint, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:12]


def _host_side() -> bool:
    """True outside any jax trace — telemetry spans only make sense (and
    only read python ints safely) at the host level; traced re-entries
    (the serving tier's vmapped advance, tempering's jitted segments)
    skip instrumentation entirely."""
    try:
        return jax.core.trace_state_clean()
    except AttributeError:  # pragma: no cover - newer jax moved it
        return True


def carries_logp(engine: "MHEngine", target) -> bool:
    """Whether ``engine`` accepts a previous segment's ``final_logp`` as
    the next segment's ``init_logp`` — the solo MH scan carry
    (engine.run's contract).  Everywhere else resume passes ``None`` and
    the engine re-derives the log-prob from the state; ``target.log_prob``
    is pure and deterministic, so the re-evaluation is bit-identical and
    resume stays exact either way."""
    cfg = engine.config
    if cfg.update != "mh" or cfg.num_chains != 1:
        return False
    try:
        return resolve_execution(cfg.execution, target) == "scan"
    except ValueError:
        return False


def _is_concrete_int(x) -> bool:
    """True for python ints (and numpy scalars) — not tracers/arrays."""
    if isinstance(x, jax.core.Tracer):
        return False
    try:
        int(x)
        return True
    except (TypeError, ValueError):
        return False


@dataclasses.dataclass(frozen=True)
class RunPlan:
    """One validated run spec (DESIGN.md §Run-API).

    ``key`` and ``seed`` are mutually exclusive ways to name the
    randomness stream: pass a PRNG key directly, or a python int seed
    that resolves to ``jax.random.PRNGKey(seed)`` at submit time (the
    serving tier's request convention).  ``init_words`` is required —
    the engine never guesses chain state.  ``step0``/``init_logp`` are
    the resume carry; leave them at their defaults for a fresh run.

    Plans are frozen: derive variants with :meth:`replace` (a
    ``dataclasses.replace`` that re-validates).
    """

    target: Any
    n_steps: int
    init_words: Any
    key: Any = None
    seed: int | None = None
    chain_id: int = 0
    step0: Any = 0
    collect: str | None = None
    mesh: Any = None
    init_logp: Any = None

    def __post_init__(self):
        if self.n_steps < 1:
            raise ValueError(f"n_steps must be >= 1, got {self.n_steps}")
        if (self.key is None) == (self.seed is None):
            raise ValueError(
                "a RunPlan names its randomness stream with exactly one of "
                "key= (a PRNG key) or seed= (an int resolved to "
                f"jax.random.PRNGKey at submit); got key={self.key!r}, "
                f"seed={self.seed!r}"
            )
        if self.init_words is None:
            raise ValueError(
                "init_words is required — the engine never guesses chain "
                "state (build it from a workload builder or a previous "
                "handle's final_words)"
            )
        if _is_concrete_int(self.step0) and int(self.step0) < 0:
            raise ValueError(f"step0 must be >= 0, got {self.step0}")
        if self.collect is not None:
            parse_collect(self.collect)

    # -- derivation -----------------------------------------------------
    def replace(self, **updates) -> "RunPlan":
        """A re-validated copy with ``updates`` applied."""
        return dataclasses.replace(self, **updates)

    def resolved_key(self):
        """The PRNG key this plan streams from."""
        if self.key is not None:
            return self.key
        return jax.random.PRNGKey(self.seed)

    @property
    def concrete_step0(self) -> int:
        """``step0`` as a python int (raises on traced offsets)."""
        if not _is_concrete_int(self.step0):
            raise ValueError(
                "this plan carries a traced step0 — only plans with "
                "concrete offsets have a python-level progress"
            )
        return int(self.step0)

    def fingerprint(self, engine: MHEngine) -> dict:
        """A JSON-able identity of (engine axes, stream, state layout) —
        what must match for a checkpointed resume to continue the same
        chain (checkpoint/resume.py).  Deliberately excludes
        ``chunk_steps``/``block_c``/``execution``: chunking and executor
        choice never change the stream (DESIGN.md §2), so a run may be
        resumed under a differently *tuned* engine bit-exactly.
        """
        cfg = engine.config
        key = self.resolved_key()
        try:  # typed key arrays (jax_enable_custom_prng) vs raw uint32
            key = jax.random.key_data(key)
        except (TypeError, ValueError):
            pass
        words = self.init_words
        return {
            "update": cfg.update,
            "randomness": cfg.randomness,
            "p_bfr": cfg.p_bfr,
            "rng_p_bfr": cfg.rng_p_bfr,
            "rng_bit_width": cfg.rng_bit_width,
            "rng_stages": cfg.rng_stages,
            "num_chains": cfg.num_chains,
            "chain_id": int(self.chain_id),
            "collect": self.collect if self.collect is not None else cfg.collect,
            "key": [int(w) for w in list(jax.numpy.ravel(key))],
            "target": type(self.target).__name__,
            "state_shape": [int(s) for s in jax.numpy.shape(words)],
        }


@dataclasses.dataclass
class RunHandle:
    """A finished (segment of a) run: the result, the plan that produced
    it, and the engine it ran on — enough to continue, re-submit, or
    checkpoint it.

    ``resume(n)`` submits the continuation plan: ``step0`` advanced past
    this segment, ``init_words``/``init_logp`` carried from the final
    state, same stream key — so the concatenation of segment sample
    streams is bit-identical to one unsegmented run of the total length.
    """

    plan: RunPlan
    result: EngineResult
    engine: MHEngine

    # result fields, delegated — a handle quacks like an EngineResult
    @property
    def samples(self):
        return self.result.samples

    @property
    def accept_count(self):
        return self.result.accept_count

    @property
    def acceptance_rate(self):
        return self.result.acceptance_rate

    @property
    def final_words(self):
        return self.result.final_words

    @property
    def final_logp(self):
        return self.result.final_logp

    @property
    def n_steps(self):
        return self.result.n_steps

    @property
    def progress(self) -> int:
        """Absolute step after this segment (= the next plan's step0)."""
        return self.plan.concrete_step0 + int(self.plan.n_steps)

    def _carries_logp(self) -> bool:
        """Whether the engine accepts this run's final_logp as the next
        segment's ``init_logp`` (solo MH scan only — engine.run's
        contract)."""
        return carries_logp(self.engine, self.plan.target)

    def resume_plan(self, n_steps: int, **overrides) -> RunPlan:
        """The continuation plan for ``n_steps`` more steps."""
        updates = dict(
            n_steps=n_steps,
            step0=self.progress,
            init_words=self.final_words,
            init_logp=self.final_logp if self._carries_logp() else None,
        )
        updates.update(overrides)
        return self.plan.replace(**updates)

    def resume(self, n_steps: int, **overrides) -> "RunHandle":
        """Run ``n_steps`` more on the same engine (bit-identical to the
        corresponding span of one unsegmented run)."""
        return self.engine.submit(self.resume_plan(n_steps, **overrides))

    def save(self, directory: str) -> str:
        """Checkpoint the resume carry (words/logp/accept) at this
        handle's absolute step via ``repro.checkpoint`` — the durable
        twin of :meth:`resume_plan` (see checkpoint/resume.py for the
        full segment-loop driver).  Emits a structured
        ``run_handle.save`` telemetry log line (fingerprint digest, step,
        path) so killed-run forensics can match the checkpoint to its
        run without re-running anything."""
        from repro.checkpoint import save_checkpoint  # lazy: no cycle

        fingerprint = self.plan.fingerprint(self.engine)
        with telemetry.span("checkpoint.handle_save", step=self.progress):
            path = save_checkpoint(
                directory,
                self.progress,
                {
                    "words": self.final_words,
                    "logp": self.final_logp,
                    "acc": self.accept_count,
                },
                extra={"fingerprint": fingerprint},
            )
        telemetry.log(
            "run_handle.save",
            fingerprint=fingerprint_digest(fingerprint),
            step=self.progress,
            n_steps=int(self.plan.n_steps),
            path=path,
        )
        return path


# --- the one-dispatch compiled entry ---------------------------------------
#
# ``engine``/``target``/``mesh`` are identity-hashed statics (reuse the same
# instances to reuse the trace) — the same contract the deprecated
# ``run_engine`` had, plus mesh support.  Two dispatchers because jit
# operands cannot be optionally-None.


@partial(
    jax.jit,
    static_argnames=(
        "engine", "target", "n_steps", "chain_id", "step0", "collect", "mesh"
    ),
)
def _submit_compiled(
    key, init_words, *, engine, target, n_steps, chain_id, step0, collect,
    mesh,
):
    return engine.run(
        key, target, n_steps, init_words, chain_id=chain_id, mesh=mesh,
        step0=step0, collect=collect,
    )


@partial(
    jax.jit,
    static_argnames=(
        "engine", "target", "n_steps", "chain_id", "step0", "collect", "mesh"
    ),
)
def _submit_compiled_logp(
    key, init_words, init_logp, *, engine, target, n_steps, chain_id, step0,
    collect, mesh,
):
    return engine.run(
        key, target, n_steps, init_words, chain_id=chain_id, mesh=mesh,
        step0=step0, collect=collect, init_logp=init_logp,
    )


def _jit_cache_size(fn) -> int | None:
    """Trace-cache entry count of a jitted callable (None when the jax
    version hides it) — how the submit span tells a compile apart from a
    cached re-dispatch."""
    try:
        return fn._cache_size()
    except Exception:  # pragma: no cover - older/newer jax internals
        return None


def _submit_span(engine: MHEngine, plan: RunPlan, compiled: bool):
    """The ``engine.submit`` telemetry span (DESIGN.md §Telemetry).
    Host-side calls only — inside a jax trace the span would time trace
    construction, not a dispatch, so traced re-entries skip it."""
    cfg = engine.config
    return telemetry.span(
        "engine.submit",
        update=cfg.update,
        randomness=cfg.randomness,
        execution=cfg.execution,
        n_steps=int(plan.n_steps),
        step0=int(plan.step0) if _is_concrete_int(plan.step0) else None,
        collect=plan.collect if plan.collect is not None else cfg.collect,
        num_chains=cfg.num_chains,
        compiled=compiled,
    )


def submit(engine: MHEngine, plan: RunPlan, *, compiled: bool = False):
    """Run ``plan`` on ``engine``; the function behind ``MHEngine.submit``.

    ``compiled=True`` routes through the cached jitted dispatcher (one
    device dispatch; pallas chunk loops collapse in-place — the old
    ``run_engine`` behaviour).  It needs a concrete ``step0``: per-offset
    statics would otherwise recompile per segment, which is exactly the
    trap the serving tier's traced-offset program avoids — so traced
    offsets always take the direct (still traceable) path.

    Telemetry (DESIGN.md §Telemetry): every *host-side* submit runs
    under an ``engine.submit`` span; on the compiled path the span's
    ``jit_cache`` metadata records whether this dispatch compiled
    (``"miss"``) or reused a trace (``"hit"``) — the compile-vs-execute
    split the bench harness aggregates.  Instrumentation is wall-clock
    bookkeeping around the unchanged dispatch calls, so the sampled
    stream is bit-identical with telemetry on or off
    (tests/test_telemetry.py).
    """
    if not isinstance(plan, RunPlan):
        raise TypeError(
            f"submit takes a RunPlan, got {type(plan).__name__} — build one "
            "with samplers.RunPlan(target=..., n_steps=..., init_words=..., "
            "seed=...)"
        )
    key = plan.resolved_key()
    traced = telemetry.enabled() and _host_side()
    span = _submit_span(engine, plan, compiled) if traced else None
    if compiled and _is_concrete_int(plan.step0):
        kw = dict(
            engine=engine,
            target=plan.target,
            n_steps=int(plan.n_steps),
            chain_id=int(plan.chain_id),
            step0=int(plan.step0),
            collect=plan.collect,
            mesh=plan.mesh,
        )
        dispatcher = (
            _submit_compiled if plan.init_logp is None
            else _submit_compiled_logp
        )
        args = (
            (key, plan.init_words) if plan.init_logp is None
            else (key, plan.init_words, plan.init_logp)
        )
        if span is None:
            result = dispatcher(*args, **kw)
        else:
            with span as sp:
                before = _jit_cache_size(dispatcher)
                result = dispatcher(*args, **kw)
                after = _jit_cache_size(dispatcher)
                if before is not None and after is not None:
                    sp.set(jit_cache="miss" if after > before else "hit")
    else:
        run_args = (key, plan.target, plan.n_steps, plan.init_words)
        run_kw = dict(
            chain_id=plan.chain_id,
            mesh=plan.mesh,
            step0=plan.step0,
            collect=plan.collect,
            init_logp=plan.init_logp,
        )
        if span is None:
            result = engine.run(*run_args, **run_kw)
        else:
            with span:
                result = engine.run(*run_args, **run_kw)
    return RunHandle(plan=plan, result=result, engine=engine)
