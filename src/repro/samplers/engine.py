"""The unified sampler engine — one chain datapath, four axes.

Every MCMC workload in this repo is a per-step state update driven by
the macro's randomness (paper Fig. 14): a random operand stream feeds an
update rule, and the chain state is rewritten in place.  ``MHEngine``
implements that loop exactly once and exposes four orthogonal, pluggable
axes (DESIGN.md §2):

  * **target**      — ``CallableTarget`` / ``TableTarget`` / ``TopKTarget``
                      (MH), or a conditional lattice model such as
                      ``workloads.ising.IsingModel`` (Gibbs)
  * **update rule** — ``mh`` (XOR-propose + accept test on the log-prob
                      ratio) vs ``gibbs`` (checkerboard conditional flip:
                      u < sigmoid(conditional logit), no reject)
  * **randomness**  — ``host`` (plain jax.random) vs ``cim`` (pseudo-read
                      bit-planes + MSXOR-debiased uniforms) vs ``fused``
                      (in-kernel counter RNG: pallas executors derive the
                      operands inside the kernel, scan draws the identical
                      stream through the shared cipher — DESIGN.md
                      §Randomness); all rules consume the same
                      accurate-[0,1] uniform stream, so backend
                      comparisons carry across rules
  * **execution**   — ``scan`` (pure-JAX ``lax.scan``) vs ``pallas`` (the
                      fused VMEM-resident kernel), with ``auto`` picking
                      by ``jax.default_backend()``

For each update rule, the two executors consume identical randomness
operands and mirror each other op-for-op, so with the same key they
produce bit-identical sample streams (asserted in
tests/test_sampler_engine.py and tests/test_workloads.py).  Randomness
streams in chunks of ``chunk_steps`` — operands for step ``t`` depend
only on ``(key, step0 + t)`` — so chains of any length run in O(chunk)
operand memory, and a run resumed at ``step0 = s`` continues the exact
stream a longer run would have produced (the segment-invariance the
tempering subsystem builds on, DESIGN.md §Tempering).

A fifth axis, **collection** (DESIGN.md §Collection), decides how much
of the chain leaves the engine: ``collect="all"`` materialises every
post-step state (the historical behaviour and the default),
``"thin:<k>"`` keeps exactly the absolute steps ``(step0 + t) % k == 0``
(so thinned samples are a strided slice of the ``"all"`` stream,
invariant to chunking and segmentation), and ``"last"`` keeps nothing —
only (final_words, final_logp, accept_count) cross chunk boundaries, so
arbitrarily long chains run in O(state) output memory.  The collection
mode never changes the chain itself: operands are generated per absolute
step regardless of what is kept.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.samplers.randomness import (
    RandomnessBackend,
    chain_key,
    chain_keys,
    make_randomness_backend,
)
from repro.samplers.targets import logits_target

Array = jnp.ndarray

_EXECUTION_CHOICES = ("auto", "scan", "pallas")
_UPDATE_CHOICES = ("mh", "gibbs")


def parse_collect(collect: str) -> tuple[str, int]:
    """Validate a collection spec; returns ``(mode, k)``.

    ``"all"`` -> ("all", 1), ``"thin:<k>"`` -> ("thin", k) for k >= 1,
    ``"last"`` -> ("last", 0).  The kept-step set is defined on
    *absolute* step indices (DESIGN.md §Collection): ``thin:k`` keeps
    ``{t : (step0 + t) % k == 0}``, so thinning commutes with chunking
    and with segment resumption.
    """
    if collect == "all":
        return ("all", 1)
    if collect == "last":
        return ("last", 0)
    if isinstance(collect, str) and collect.startswith("thin:"):
        try:
            k = int(collect[len("thin:"):])
        except ValueError:
            k = 0
        if k >= 1:
            return ("thin", k)
    raise ValueError(
        f"collect must be 'all', 'last' or 'thin:<k>' (k >= 1), "
        f"got {collect!r}"
    )


def kept_count(n_steps: int, k: int, step0: int = 0) -> int:
    """Size of the ``thin:k`` kept set {t in [0, n_steps):
    (step0 + t) % k == 0}."""
    if k < 1:
        raise ValueError(f"thin stride k must be >= 1, got {k}")
    i0 = (-int(step0)) % k
    return 0 if i0 >= n_steps else (n_steps - i0 - 1) // k + 1


def _thin_offset(step0: int, k: int) -> int:
    """First kept relative step of a span starting at absolute ``step0``."""
    return (-int(step0)) % k


def _effective_chunk(n_steps: int, chunk: int, thin_k: int | None) -> int:
    """The one chunk-schedule rule shared by every executor: clamp to
    [1, n_steps], and under ``thin:k`` align to a multiple of k so every
    full chunk keeps exactly ``chunk // k`` rows (the per-chunk kept
    slice then has a static shape, which the scan executors' outer
    ``lax.scan`` requires)."""
    chunk = max(1, min(chunk, n_steps))
    if thin_k is not None and thin_k > 1:
        chunk = thin_k * max(1, chunk // thin_k)
    return chunk


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static configuration of the engine's update/randomness/execution axes."""

    p_bfr: float = 0.45              # proposal bit-flip rate (pseudo-read)
    randomness: str = "cim"          # host | cim | fused (§Randomness)
    rng_p_bfr: float | None = None   # [0,1]-RNG raw-bit bias (default p_bfr)
    rng_bit_width: int = 16          # u precision (cim backend)
    rng_stages: int = 3              # MSXOR stages (cim backend)
    update: str = "mh"               # mh | gibbs (DESIGN.md §2 update rule)
    execution: str = "auto"          # auto | scan | pallas
    chunk_steps: int = 64            # randomness streaming granularity
    block_c: int = 256               # pallas chain-axis block size
    num_chains: int = 1              # independent chains (DESIGN.md §Chains)
    collect: str = "all"             # all | thin:<k> | last (§Collection)

    def __post_init__(self):
        if self.execution not in _EXECUTION_CHOICES:
            raise ValueError(
                f"execution must be one of {_EXECUTION_CHOICES}, "
                f"got {self.execution!r}"
            )
        if self.update not in _UPDATE_CHOICES:
            raise ValueError(
                f"update must be one of {_UPDATE_CHOICES}, got {self.update!r}"
            )
        if self.randomness not in ("host", "cim", "fused"):
            raise ValueError(
                f"randomness must be host|cim|fused, got {self.randomness!r}"
            )
        if self.chunk_steps < 1:
            raise ValueError(f"chunk_steps must be >= 1, got {self.chunk_steps}")
        if self.block_c < 1:
            raise ValueError(f"block_c must be >= 1, got {self.block_c}")
        if self.rng_bit_width < 1:
            raise ValueError(
                f"rng_bit_width must be >= 1, got {self.rng_bit_width}"
            )
        if self.rng_stages < 1:
            raise ValueError(f"rng_stages must be >= 1, got {self.rng_stages}")
        if self.num_chains < 1:
            raise ValueError(f"num_chains must be >= 1, got {self.num_chains}")
        parse_collect(self.collect)

    def backend(self) -> RandomnessBackend:
        return make_randomness_backend(
            self.randomness,
            p_bfr=self.p_bfr,
            rng_p_bfr=self.rng_p_bfr,
            rng_bit_width=self.rng_bit_width,
            rng_stages=self.rng_stages,
        )


class EngineResult(NamedTuple):
    samples: Array          # (K_kept, *chain_shape) uint32 post-step states
    #                         K_kept follows config.collect: n_steps under
    #                         "all", kept_count(...) under "thin:k", and 0
    #                         under "last" (final_words IS the sample)
    accept_count: Array     # (*chain_shape,) int32
    acceptance_rate: Array  # scalar float32
    final_words: Array      # (*chain_shape,) uint32
    final_logp: Array       # (*chain_shape,) float32
    n_steps: jnp.int32      # total steps run (not kept)


def resolve_execution(execution: str, target, update: str = "mh") -> str:
    """Backend dispatch rule (DESIGN.md §2): explicit override wins;
    ``auto`` = fused kernel on TPU for fusable targets, scan elsewhere.

    What makes a target fusable depends on the update rule: ``mh`` needs
    the distribution materialised as a table (held in VMEM); ``gibbs``
    needs a lattice model the checkerboard kernel knows how to sweep
    (``supports_fused_gibbs``)."""
    if update == "gibbs":
        if execution == "pallas":
            if not getattr(target, "supports_fused_gibbs", False):
                raise ValueError(
                    "pallas Gibbs execution needs a lattice model with a "
                    "fused checkerboard kernel (supports_fused_gibbs); "
                    "use execution='scan'"
                )
            return "pallas"
        # auto never fuses Gibbs: eligibility depends on the lattice shape
        # (periodic boundaries cannot pad to the 128-lane, DESIGN.md §3),
        # which dispatch cannot see.  Explicit pallas opts in.
        return "scan"
    if execution == "pallas":
        if target.table is None:
            raise ValueError(
                "pallas execution needs a table target (the fused kernel "
                "holds the distribution in VMEM); use a TableTarget or "
                "execution='scan'"
            )
        return "pallas"
    if execution == "scan":
        return "scan"
    if target.table is not None and jax.default_backend() == "tpu":
        return "pallas"
    return "scan"


def _mh_step(target, nbits: int, words, logp, acc, flip, u):
    """THE MH step — the only scan-side implementation in the repo.

    Mirrors the Pallas kernel body (kernels/mh/mh.py:_mh_kernel)
    op-for-op: XOR-propose, table/fn lookup, u < exp(min(dlogp, 0))
    accept, select (in-memory copy).
    """
    mask = jnp.uint32((1 << nbits) - 1)
    cand = jnp.bitwise_xor(words, flip & mask)
    logp_cand = target.log_prob(cand).astype(jnp.float32)
    delta = logp_cand - logp
    accept = jnp.logical_and(
        u < jnp.exp(jnp.minimum(delta, 0.0)), jnp.isfinite(logp_cand)
    )
    words = jnp.where(accept, cand, words)        # in-memory copy
    logp = jnp.where(accept, logp_cand, logp)
    return words, logp, acc + accept.astype(jnp.int32)


def _run_scan_chunked(make_xs, step_fn, carry, n_steps, chunk, step0, collect):
    """THE scan-side chunk scheduler — the full/remainder scaffolding both
    scan executors share (mh and gibbs differ only in their operand maker
    and step body).

    ``make_xs(start, n)`` materialises the operand pytree for absolute
    steps [start, start + n); ``step_fn(carry, x) -> carry`` advances one
    step, with ``carry[0]`` the chain state that feeds the sample stream.
    ``collect`` is a parsed ``(mode, k)`` (see ``parse_collect``): "all"
    emits every post-step state, "thin" emits the per-chunk strided kept
    slice (chunks are k-aligned by ``_effective_chunk``, so every full
    chunk keeps the same row count and the outer scan stays shape-static),
    and "last" emits nothing — the inner scan carries only the state, so
    output memory is O(state) for any chain length.
    """
    mode, k = collect
    chunk = _effective_chunk(n_steps, chunk, k if mode == "thin" else None)
    i0 = _thin_offset(step0, k) if mode == "thin" else 0
    n_full, rem = divmod(n_steps, chunk)

    def span(c, start, n):
        def body(c, x):
            c = step_fn(c, x)
            return c, (None if mode == "last" else c[0])

        c, ys = jax.lax.scan(body, c, make_xs(start, n))
        if mode == "thin":
            # start ≡ step0 (mod k) for every span, so the kept offset is
            # the same static i0 and the slice shape is chunk-invariant
            ys = ys[i0::k]
        return c, ys

    pieces = []
    if n_full:
        starts = step0 + jnp.arange(n_full, dtype=jnp.int32) * chunk
        carry, stacked = jax.lax.scan(
            lambda c, s: span(c, s, chunk), carry, starts
        )
        if mode != "last":
            pieces.append(stacked.reshape(-1, *stacked.shape[2:]))
    if rem:
        carry, tail = span(carry, step0 + n_full * chunk, rem)
        if mode != "last":
            pieces.append(tail)
    if mode == "last":
        samples = jnp.zeros((0, *carry[0].shape), jnp.uint32)
    else:
        samples = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces, 0)
    return samples, carry


def _run_scan(
    key, target, backend, nbits, n_steps, chunk, step0, init_words, collect,
    init_logp=None,
):
    shape = init_words.shape
    words0 = init_words.astype(jnp.uint32)
    logp0 = (
        target.log_prob(words0) if init_logp is None else init_logp
    )
    carry = (
        words0,
        logp0.astype(jnp.float32),
        jnp.zeros(shape, jnp.int32),
    )

    def make_xs(start, n):
        return backend.chunk(key, start, n, shape, nbits)

    def step_fn(c, x):
        flip, u = x
        return _mh_step(target, nbits, *c, flip, u)

    samples, (words, logp, acc) = _run_scan_chunked(
        make_xs, step_fn, carry, n_steps, chunk, step0, collect
    )
    return samples, acc, words, logp


def _step0_base(step0):
    """Best-effort concrete step0 for the pallas executors.  The chunk
    *schedule* (the python loop) is always static, but the absolute-step
    base is a runtime operand of the fused kernels (and of the
    checkerboard-parity argument), so a traced ``step0`` is fine for
    collect="all"/"last" — successive serving segments and packed slots
    reuse one compiled program.  Only thinning still needs a concrete
    offset (the kept-slice stride is resolved at python level), which
    ``_parse_collect`` enforces with a actionable error upstream."""
    try:
        return int(step0)
    except TypeError:
        return step0


@functools.lru_cache(maxsize=None)
def _chunk_writer(ndim: int):
    """Donating jitted chunk-buffer update for the eager pallas driver:
    ``out[pos : pos + rows.shape[0]] = rows`` as one compiled program
    whose output aliases the donated input, so each chunk write touches
    only the written rows.  The historical eager assembly appended to a
    ``pieces`` list and paid a full-stream ``concatenate`` copy at the
    end (plus O(chunks) buffer lifetimes); a bare eager
    ``dynamic_update_slice`` would be worse still — a whole-buffer copy
    per chunk, O(K²/chunk) traffic.  ``pos`` is a traced operand, so one
    compile serves every chunk boundary."""

    def write(out, rows, pos):
        return jax.lax.dynamic_update_slice(out, rows, (pos,) + (0,) * ndim)

    return jax.jit(write, donate_argnums=(0,))


def _drive_pallas_chunks(run_chunk, init_state, n_steps, chunk, step0, collect):
    """THE fused-executor chunk scheduler — the python chunk loop all four
    pallas executors share.

    ``run_chunk(state, start, n)`` launches one fused-kernel program for
    relative steps [start, start + n) and returns (samples (n, *state
    shape) uint32, per-site count (*state shape) int32).  Kept rows are
    written straight into one preallocated output buffer via
    ``lax.dynamic_update_slice``: under a trace (``run_engine`` or any
    caller-side jit — which also collapses the loop into a single
    dispatch) XLA aliases the update in place, and eagerly the write
    goes through the donating jitted ``_chunk_writer`` so the buffer is
    reused in place as well — no per-chunk ``pieces`` list, no final
    full-stream ``concatenate`` copy, O(rows-written) traffic per chunk
    either way.  Under "last" samples are dropped at the chunk boundary
    and only (state, count) survive.  The chunk *schedule* (the python
    loop) is static; ``step0`` may be traced (``_step0_base``) except
    under thinning, whose kept-slice arithmetic is python-level
    (enforced upstream by ``_parse_collect``).
    """
    mode, k = collect
    chunk = _effective_chunk(n_steps, chunk, k if mode == "thin" else None)
    state = init_state
    acc = jnp.zeros(state.shape, jnp.int32)
    if mode == "all":
        n_keep = n_steps
    elif mode == "thin":
        n_keep = kept_count(n_steps, k, step0)
    else:
        n_keep = 0
    traced = isinstance(state, jax.core.Tracer)
    out = jnp.zeros((n_keep, *state.shape), jnp.uint32)
    zeros = (0,) * state.ndim
    pos = 0

    def emit(rows):
        nonlocal out, pos
        if traced:
            out = jax.lax.dynamic_update_slice(out, rows, (pos, *zeros))
        else:
            out = _chunk_writer(state.ndim)(out, rows, pos)
        pos += rows.shape[0]

    for start in range(0, n_steps, chunk):
        n = min(chunk, n_steps - start)
        samples, a = run_chunk(state, start, n)
        state = samples[-1]
        acc = acc + a
        if mode == "all":
            emit(samples)
        elif mode == "thin":
            i0 = _thin_offset(step0 + start, k)
            if i0 < n:
                emit(samples[i0::k])
    return out, acc, state


def _fused_key_cols(keys, repeat: int):
    """Per-column/lattice chain-key words for the fused kernels: the two
    uint32 words of each chain key (kernels/rng), repeated over the
    chain's folded extent — chain-major, matching the executors' fold
    layout.  ``keys`` is one key or a stacked (C, ...) batch; this is
    the ONLY randomness state the fused kernels receive (8 bytes per
    column/lattice per chunk, replacing per-step operand planes)."""
    from repro.kernels import rng  # avoid import cycle

    if getattr(keys, "ndim", 0) and not jnp.issubdtype(
        keys.dtype, jax.dtypes.prng_key
    ):
        batched = keys.ndim > 1  # raw uint32 keys carry a trailing (2,)
    else:
        batched = getattr(keys, "ndim", 0) > 0
    if batched:
        kw = jax.vmap(lambda k: jnp.stack(rng.key_words(k)))(keys)
        return (
            jnp.repeat(kw[:, 0], repeat),
            jnp.repeat(kw[:, 1], repeat),
        )
    k0, k1 = rng.key_words(keys)
    return (
        jnp.broadcast_to(k0, (repeat,)),
        jnp.broadcast_to(k1, (repeat,)),
    )


def _run_pallas(
    key, target, backend, nbits, n_steps, chunk, step0, block_c, init_words,
    collect,
):
    from repro.kernels.mh import ops as mh_ops  # avoid import cycle

    if init_words.ndim != 2:
        raise ValueError(
            f"pallas execution expects (B, C) chain state, got {init_words.shape}"
        )
    step0 = _step0_base(step0)

    if backend.name == "fused":
        c = init_words.shape[1]
        k0c, k1c = _fused_key_cols(key, c)

        def run_chunk(state, start, n):
            return mh_ops.mh_sample_fused(
                target.table, state, k0c, k1c, n_steps=n, t0=step0 + start,
                nbits=nbits, p_bfr=backend.p_bfr, cc=c, block_c=block_c,
            )
    else:

        def run_chunk(state, start, n):
            flips, u = backend.chunk(key, step0 + start, n, state.shape, nbits)
            return mh_ops.mh_sample(
                target.table, state, flips, u, nbits=nbits, block_c=block_c
            )

    samples, acc, state = _drive_pallas_chunks(
        run_chunk, init_words.astype(jnp.uint32), n_steps, chunk, step0,
        collect,
    )
    logp = target.log_prob(state).astype(jnp.float32)
    return samples, acc, state, logp


def _gibbs_step(target, state, acc, u, parity):
    """THE Gibbs half-sweep — the only scan-side implementation in the repo.

    Mirrors the Pallas kernel body (kernels/gibbs/gibbs.py:_gibbs_kernel)
    op-for-op: conditional logit from the current neighbours, draw the
    site's new value as u < sigmoid(logit), write it on the active
    checkerboard colour only.  There is no reject — ``acc`` counts sites
    whose value actually changed (the flip count)."""
    logit = target.conditional_logit(state)
    new = (u < jax.nn.sigmoid(logit)).astype(jnp.uint32)
    active = target.update_mask(state.shape, parity)
    nxt = jnp.where(active, new, state)
    return nxt, acc + (nxt != state).astype(jnp.int32)


def _run_scan_gibbs(
    key, target, backend, n_steps, chunk, step0, init_words, collect
):
    shape = init_words.shape
    carry = (init_words.astype(jnp.uint32), jnp.zeros(shape, jnp.int32))

    def make_xs(start, n):
        # gibbs draws no proposal — the operand-lean u-only path
        _, u = backend.chunk(key, start, n, shape, 1, need_flips=False)
        idx = start + jnp.arange(n, dtype=jnp.int32)
        return (u, idx)

    def step_fn(c, x):
        u_t, t = x
        return _gibbs_step(target, *c, u_t, t % 2)

    samples, (state, acc) = _run_scan_chunked(
        make_xs, step_fn, carry, n_steps, chunk, step0, collect
    )
    return samples, acc, state


def _run_pallas_gibbs(
    key, target, backend, n_steps, chunk, step0, init_words, collect
):
    from repro.kernels.gibbs import ops as gibbs_ops  # avoid import cycle

    if init_words.ndim != 3:
        raise ValueError(
            f"pallas Gibbs expects (B, H, W) lattice state, got "
            f"{init_words.shape}"
        )
    step0 = _step0_base(step0)
    logit_fn, consts = _fused_gibbs_logit(target)

    if backend.name == "fused":
        b = init_words.shape[0]
        k0b, k1b = _fused_key_cols(key, b)

        def run_chunk(state, start, n):
            return gibbs_ops.gibbs_sweep_fused(
                state, k0b, k1b, logit_fn, n_steps=n, t0=step0 + start,
                lat_b=b, consts=consts,
            )
    else:

        def run_chunk(state, start, n):
            _, u = backend.chunk(
                key, step0 + start, n, state.shape, 1, need_flips=False
            )
            return gibbs_ops.gibbs_sweep(
                state, u, logit_fn, parity0=(step0 + start) % 2, consts=consts
            )

    return _drive_pallas_chunks(
        run_chunk, init_words.astype(jnp.uint32), n_steps, chunk, step0,
        collect,
    )


# --- chains axis (DESIGN.md §Chains-axis) ----------------------------------
#
# C independent chains run in ONE device program.  Per-chain randomness is
# counter-derived — chain c streams from fold_in(key, c), then per-step
# fold_in(·, t) — so chain c of a C-chain run is bit-identical to a solo
# run with chain_id=c.  The scan executor vmaps over the chain axis; the
# fused Pallas kernels get a *batched grid*: chains fold into the
# compartment axis (mh, grid (B, C·Cc/BLOCK_C)) or the lattice-batch axis
# (gibbs, grid (C·B,)) — both grids block over exactly the folded axis, and
# every op is per-column/per-lattice, so folding preserves bit-parity.


def _chains_fold_mh(x):
    """(C, K, B, Cc) operands -> (K, B, C*Cc): chains ride the compartment
    axis, chain-major blocks so chain c owns columns [c*Cc, (c+1)*Cc)."""
    c, k, b, cc = x.shape
    return jnp.transpose(x, (1, 2, 0, 3)).reshape(k, b, c * cc)


def _run_pallas_chains(
    keys, target, backend, nbits, n_steps, chunk, step0, block_c, init,
    collect,
):
    """Fused MH over C chains: one batched-grid kernel program per chunk."""
    from repro.kernels.mh import ops as mh_ops  # avoid import cycle

    if init.ndim != 3:
        raise ValueError(
            f"multi-chain pallas execution expects (num_chains, B, C) chain "
            f"state, got {init.shape}"
        )
    step0 = _step0_base(step0)
    c_chains, b, cc = init.shape
    state0 = jnp.transpose(init.astype(jnp.uint32), (1, 0, 2)).reshape(
        b, c_chains * cc
    )

    if backend.name == "fused":
        k0c, k1c = _fused_key_cols(keys, cc)  # chain-major: matches fold

        def run_chunk(state, start, n):
            return mh_ops.mh_sample_fused(
                target.table, state, k0c, k1c, n_steps=n, t0=step0 + start,
                nbits=nbits, p_bfr=backend.p_bfr, cc=cc, block_c=block_c,
            )
    else:

        def run_chunk(state, start, n):
            flips, u = jax.vmap(
                lambda k: backend.chunk(k, step0 + start, n, (b, cc), nbits)
            )(keys)
            return mh_ops.mh_sample(
                target.table, state, _chains_fold_mh(flips),
                _chains_fold_mh(u), nbits=nbits, block_c=block_c,
            )

    samples, acc, state = _drive_pallas_chunks(
        run_chunk, state0, n_steps, chunk, step0, collect
    )

    def unfold(x):  # (..., B, C*Cc) -> (C, ..., B, Cc)
        lead = x.shape[:-2]
        x = x.reshape(*lead, b, c_chains, cc)
        return jnp.moveaxis(x, -2, 0)

    logp = target.log_prob(state).astype(jnp.float32)
    return unfold(samples), unfold(acc), unfold(state), unfold(logp)


def _fused_gibbs_logit(target):
    """(logit_fn, consts) for the fused kernel: models whose conditional
    closes over array parameters expose them as ``fused_consts`` plus a
    ``fused_logit(state, *consts)`` sharing the scan-side math body —
    kernel traces cannot capture array closures (DESIGN.md §Tempering)."""
    consts = tuple(getattr(target, "fused_consts", ()) or ())
    if consts:
        return target.fused_logit, consts
    return target.conditional_logit, ()


def _run_pallas_gibbs_chains(
    keys, target, backend, n_steps, chunk, step0, init, collect
):
    """Fused checkerboard Gibbs over C chains: chains fold into the
    lattice-batch grid axis."""
    from repro.kernels.gibbs import ops as gibbs_ops  # avoid import cycle

    if init.ndim != 4:
        raise ValueError(
            f"multi-chain pallas Gibbs expects (num_chains, B, H, W) lattice "
            f"state, got {init.shape}"
        )
    step0 = _step0_base(step0)
    logit_fn, consts = _fused_gibbs_logit(target)
    c_chains, b, h, w = init.shape
    state0 = init.astype(jnp.uint32).reshape(c_chains * b, h, w)

    if backend.name == "fused":
        k0b, k1b = _fused_key_cols(keys, b)  # chain-major: matches fold

        def run_chunk(state, start, n):
            return gibbs_ops.gibbs_sweep_fused(
                state, k0b, k1b, logit_fn, n_steps=n, t0=step0 + start,
                lat_b=b, consts=consts,
            )
    else:

        def run_chunk(state, start, n):
            u = jax.vmap(
                lambda k: backend.chunk(
                    k, step0 + start, n, (b, h, w), 1, need_flips=False
                )[1]
            )(keys)
            u_fold = jnp.transpose(u, (1, 0, 2, 3, 4)).reshape(
                n, c_chains * b, h, w
            )
            return gibbs_ops.gibbs_sweep(
                state, u_fold, logit_fn, parity0=(step0 + start) % 2,
                consts=consts,
            )

    samples, acc, state = _drive_pallas_chunks(
        run_chunk, state0, n_steps, chunk, step0, collect
    )

    def unfold(x):  # (..., C*B, H, W) -> (C, ..., B, H, W)
        lead = x.shape[:-3]
        x = x.reshape(*lead, c_chains, b, h, w)
        return jnp.moveaxis(x, len(lead), 0)

    return unfold(samples), unfold(acc), unfold(state)


def _shard_over_chains(body, mesh, num_chains: int, n_out: int):
    """Wrap ``body(keys, init)`` in shard_map over the mesh's chains axes.

    The "chains" logical axis resolves through the standard sharding-rules
    table (distributed/sharding.py), including the divisibility filter — a
    chain count the mesh doesn't divide runs replicated (unsharded) rather
    than padded, and a mesh-less call is the identity.  Chains never
    communicate, so the sharded program is collective-free and
    bit-identical to the unsharded one.
    """
    if mesh is None:
        return body
    from jax.experimental.shard_map import shard_map

    from repro.distributed import sharding

    spec = sharding.spec_for(("chains",), shape=(num_chains,), mesh=mesh)
    if spec is None or len(spec) == 0 or spec[0] is None:
        return body
    p = jax.sharding.PartitionSpec(spec[0])
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(p, p),
        out_specs=tuple(p for _ in range(n_out)),
        check_rep=False,
    )


class MHEngine:
    """One sampler engine, pluggable on all four axes (the name predates
    the ``gibbs`` update rule; ``SamplerEngine`` aliases it).

    Methods are traceable (no internal ``jax.jit``) so thin wrappers can
    jit at whatever boundary fits their API; ``run_engine`` below is the
    ready-made jitted entry.
    """

    def __init__(self, config: EngineConfig = EngineConfig()):
        self.config = config
        self._backend = config.backend()

    @property
    def randomness(self) -> RandomnessBackend:
        return self._backend

    def submit(self, plan, *, compiled: bool = False):
        """Run a validated ``RunPlan``; returns a re-submittable
        ``RunHandle`` (DESIGN.md §Run-API) — the documented public entry.

        ``compiled=True`` routes through the cached jitted dispatcher
        (one device dispatch per distinct static signature; needs a
        concrete ``step0``).  The default direct path is traceable, so
        plans built inside jitted/vmapped programs (tempering segments,
        the serving tier's packed advance) submit the same way.
        """
        from repro.samplers.plan import submit  # lazy: plan imports engine

        return submit(self, plan, compiled=compiled)

    def run(
        self, key, target, n_steps: int, init_words, *,
        chain_id: int = 0, mesh=None, step0=0, collect: str | None = None,
        init_logp=None,
    ) -> EngineResult:
        """Run ``n_steps`` of the configured update rule from
        ``init_words``; keep what ``collect`` says (default: every state).

        ``collect`` overrides ``config.collect`` for this run (DESIGN.md
        §Collection): ``"all"`` materialises every post-step state,
        ``"thin:<k>"`` keeps the absolute steps ``(step0 + t) % k == 0``
        (bit-identical to the strided slice ``all[(-step0) % k :: k]``,
        so thinning commutes with chunking *and* with ``step0``
        segmentation), ``"last"`` keeps none — ``final_words`` /
        ``final_logp`` / ``accept_count`` are the whole result and
        ``samples`` is a (0, *chain_shape) placeholder.  The chain
        dynamics are identical in all three modes.  ``"thin:<k>"``
        requires a concrete ``step0`` (the kept count is shape-static).

        ``init_logp`` (solo MH scan only) seeds the carried log-prob
        instead of re-evaluating ``target.log_prob(init_words)`` — pass
        the previous segment's ``final_logp`` when resuming so segmented
        runs touch the target exactly once per step, like an unsegmented
        run (the serving tier's donated-carry contract, DESIGN.md
        §Serving).  It must equal ``target.log_prob(init_words)``;
        nothing is re-checked.

        ``step0`` offsets the randomness stream (and the Gibbs
        checkerboard parity) by an absolute step count: operands for
        step ``t`` of this run are those of absolute step ``step0 + t``,
        so a run resumed from ``(final_words, step0=s)`` continues the
        exact stream of one unsegmented run — the segment-invariance the
        tempering subsystem's swap boundaries rely on (DESIGN.md
        §Tempering).  Both executors accept a traced ``step0`` for
        ``collect="all"``/``"last"`` — the fused pallas kernels take the
        absolute-step base (and the Gibbs checkerboard parity it
        carries) as a runtime operand, so segments at different offsets
        reuse one compiled program; only ``"thin:<k>"`` needs a concrete
        int (the kept count is shape-static).

        ``mh``: ``init_words`` is (B, C) for table targets (B independent
        targets x C lock-step chains), any shape for callable targets.
        ``gibbs``: ``init_words`` is the lattice state (..., H, W) of
        {0, 1} spin words (strictly (B, H, W) under pallas execution);
        each step is one checkerboard half-sweep, ``accept_count`` is the
        per-site flip count, and ``final_logp`` is the per-site
        conditional log-prob (pseudo-likelihood) of the final state.

        **Chains axis** (DESIGN.md §Chains-axis): with
        ``config.num_chains == C > 1`` this runs C independent chains in
        one device program; ``init_words`` must carry a leading (C,)
        axis (broadcast a shared solo init yourself — the engine never
        guesses, a coincidental first dim would be misread) and every
        result field gains that leading axis.  Randomness is counter-derived per
        ``(chain_id, absolute_step)``, so chain c of a C-chain run is
        bit-identical to a solo run with ``chain_id=c``; in a multi-chain
        run ``chain_id`` acts as the chain-id *base* (chains cover
        [chain_id, chain_id + C), so two C-chain runs with bases 0 and C
        compose into the 2C-chain run).  ``mesh`` (a
        concrete ``jax.sharding.Mesh``) shards the chain axis across
        devices via ``shard_map`` under the "chains" sharding rule;
        chains never communicate, so sharded == unsharded bit-for-bit.
        """
        if n_steps < 1:
            raise ValueError(f"n_steps must be >= 1, got {n_steps}")
        if isinstance(step0, int) and step0 < 0:
            raise ValueError(f"step0 must be >= 0, got {step0}")
        collect = self._parse_collect(collect, step0)
        if init_logp is not None and (
            self.config.num_chains > 1 or self.config.update == "gibbs"
        ):
            raise ValueError(
                "init_logp resumes the solo MH carry only — the Gibbs "
                "carry holds no log-prob and the chains axis derives its "
                "own per-chain carries"
            )
        if self.config.num_chains > 1:
            return self._run_chains(
                key, target, n_steps, init_words, mesh, base=chain_id,
                step0=step0, collect=collect,
            )
        key = chain_key(key, chain_id)
        if self.config.update == "gibbs":
            return self._run_gibbs(
                key, target, n_steps, init_words, step0, collect
            )
        execution = resolve_execution(self.config.execution, target)
        args = (key, target, self._backend, target.nbits, n_steps,
                self.config.chunk_steps, step0)
        if execution == "scan":
            samples, acc, words, logp = _run_scan(
                *args, init_words, collect, init_logp
            )
        else:
            if init_logp is not None:
                raise ValueError(
                    "init_logp needs scan execution — the pallas MH kernel "
                    "re-derives the table log-prob from the state words"
                )
            samples, acc, words, logp = _run_pallas(
                *args, self.config.block_c, init_words, collect
            )
        total = jnp.float32(n_steps) * jnp.float32(max(1, init_words.size))
        return EngineResult(
            samples=samples,
            accept_count=acc,
            acceptance_rate=jnp.sum(acc).astype(jnp.float32) / total,
            final_words=words,
            final_logp=logp,
            n_steps=jnp.int32(n_steps),
        )

    def _parse_collect(self, collect: str | None, step0) -> tuple[str, int]:
        """Resolve the run-level override against the config default and
        pin down thin's static-shape requirement."""
        mode_k = parse_collect(
            self.config.collect if collect is None else collect
        )
        if mode_k[0] == "thin":
            try:
                int(step0)
            except TypeError as e:
                raise ValueError(
                    "collect='thin:<k>' needs a concrete (python int) step0 "
                    "— the kept-sample count is part of the output shape, "
                    "so a traced stream offset cannot size it.  Either pass "
                    "step0 as a python int (re-jitting per offset), or keep "
                    "the traced offset with collect='all' and take the "
                    "host-side strided slice samples[(-step0) % k :: k] "
                    "afterwards — bit-identical to engine thin on absolute "
                    "steps, and exactly the serving tier's fallback "
                    "(serving/executor.py, DESIGN.md §Serving).  "
                    "collect='last' also accepts traced offsets."
                ) from e
        return mode_k

    def _run_gibbs(
        self, key, target, n_steps: int, init_words, step0, collect
    ) -> EngineResult:
        if not hasattr(target, "conditional_logit"):
            raise ValueError(
                "gibbs update needs a conditional target exposing "
                "conditional_logit/update_mask (e.g. workloads.ising."
                f"IsingModel); got {type(target).__name__}"
            )
        execution = resolve_execution(self.config.execution, target, "gibbs")
        args = (key, target, self._backend, n_steps, self.config.chunk_steps,
                step0)
        if execution == "scan":
            samples, acc, words = _run_scan_gibbs(*args, init_words, collect)
        else:
            samples, acc, words = _run_pallas_gibbs(*args, init_words, collect)
        logit = target.conditional_logit(words)
        logp = jnp.where(
            words == 1, jax.nn.log_sigmoid(logit), jax.nn.log_sigmoid(-logit)
        ).astype(jnp.float32)
        total = jnp.float32(n_steps) * jnp.float32(max(1, init_words.size))
        return EngineResult(
            samples=samples,
            accept_count=acc,
            acceptance_rate=jnp.sum(acc).astype(jnp.float32) / total,
            final_words=words,
            final_logp=logp,
            n_steps=jnp.int32(n_steps),
        )

    def _run_chains(
        self, key, target, n_steps: int, init_words, mesh, base: int = 0,
        step0=0, collect: tuple[str, int] = ("all", 1),
    ):
        """C independent chains in one device program (optionally sharded).

        ``base`` offsets the chain ids: the run covers chains
        [base, base + C), so two C-chain runs with bases 0 and C compose
        into exactly the 2C-chain run's streams.
        """
        cfg = self.config
        num_chains = cfg.num_chains
        init = jnp.asarray(init_words)
        # the leading axis is ALWAYS the chain axis — never guessed from
        # shape coincidences (a solo init whose first dim happens to equal
        # num_chains would be silently misread); broadcast explicitly
        if init.ndim == 0 or init.shape[0] != num_chains:
            raise ValueError(
                f"multi-chain init_words must carry a leading "
                f"(num_chains={num_chains},) axis, got {init.shape}; "
                f"broadcast a solo init with "
                f"jnp.broadcast_to(init, ({num_chains}, *init.shape))"
            )
        keys = chain_keys(key, num_chains, base=base)
        if cfg.update == "gibbs":
            if not hasattr(target, "conditional_logit"):
                raise ValueError(
                    "gibbs update needs a conditional target exposing "
                    "conditional_logit/update_mask (e.g. workloads.ising."
                    f"IsingModel); got {type(target).__name__}"
                )
            execution = resolve_execution(cfg.execution, target, "gibbs")
            if execution == "scan":

                def body(ks, ini):
                    return jax.vmap(
                        lambda k, w: _run_scan_gibbs(
                            k, target, self._backend, n_steps,
                            cfg.chunk_steps, step0, w, collect,
                        )
                    )(ks, ini)
            else:

                def body(ks, ini):
                    return _run_pallas_gibbs_chains(
                        ks, target, self._backend, n_steps, cfg.chunk_steps,
                        step0, ini, collect,
                    )

            body = _shard_over_chains(body, mesh, num_chains, 3)
            samples, acc, words = body(keys, init)
            logit = target.conditional_logit(words)
            logp = jnp.where(
                words == 1,
                jax.nn.log_sigmoid(logit),
                jax.nn.log_sigmoid(-logit),
            ).astype(jnp.float32)
        else:
            execution = resolve_execution(cfg.execution, target)
            nbits = target.nbits
            if execution == "scan":

                def body(ks, ini):
                    return jax.vmap(
                        lambda k, w: _run_scan(
                            k, target, self._backend, nbits, n_steps,
                            cfg.chunk_steps, step0, w, collect,
                        )
                    )(ks, ini)
            else:

                def body(ks, ini):
                    return _run_pallas_chains(
                        ks, target, self._backend, nbits, n_steps,
                        cfg.chunk_steps, step0, cfg.block_c, ini, collect,
                    )

            body = _shard_over_chains(body, mesh, num_chains, 4)
            samples, acc, words, logp = body(keys, init)
        total = jnp.float32(n_steps) * jnp.float32(max(1, init.size))
        return EngineResult(
            samples=samples,
            accept_count=acc,
            acceptance_rate=jnp.sum(acc).astype(jnp.float32) / total,
            final_words=words,
            final_logp=logp,
            n_steps=jnp.int32(n_steps),
        )

    def sample_tokens(
        self,
        key,
        logits,
        n_steps: int,
        temperature: float = 1.0,
        top_k: int = 0,
        init_tokens=None,
    ) -> tuple[Array, EngineResult]:
        """Draw one token per row of ``logits`` (B, V): one chain per row.

        Returns (tokens (B,) int32, full EngineResult).  ``init_tokens``
        seeds the chains (the macro's x^(0) written into the bitcells);
        defaults to the row argmax — a guaranteed finite-logp start.
        """
        target = logits_target(logits, temperature=temperature, top_k=top_k)
        if init_tokens is None:
            init = jnp.argmax(target.table, axis=-1).astype(jnp.uint32)
        else:
            init = jnp.clip(
                init_tokens.astype(jnp.uint32), 0, target.table.shape[-1] - 1
            )
        result = self.run(key, target, n_steps, init[:, None])
        tokens = target.decode(result.final_words)[:, 0].astype(jnp.int32)
        return tokens, result


SamplerEngine = MHEngine  # the engine outgrew its MH-only name in PR 2


def run_engine(
    key, init_words, *, engine: MHEngine, target, n_steps: int,
    chain_id: int = 0, step0: int = 0, collect: str | None = None,
):
    """Deprecated jitted entry — build a ``RunPlan`` and call
    ``engine.submit(plan, compiled=True)`` instead (DESIGN.md §Run-API).

    Bit- and dispatch-compatible with the historical signature: routes
    through the same cached jitted dispatcher (``engine``/``target`` are
    identity-hashed statics — reuse the same instances to reuse the
    trace), and the warning fires per call because it lives outside the
    trace.
    """
    import warnings

    from repro.samplers.plan import RunPlan, submit

    warnings.warn(
        "run_engine is deprecated; build a samplers.RunPlan and call "
        "engine.submit(plan, compiled=True) (DESIGN.md §Run-API)",
        DeprecationWarning,
        stacklevel=2,
    )
    plan = RunPlan(
        target=target, n_steps=n_steps, init_words=init_words, key=key,
        chain_id=chain_id, step0=step0, collect=collect,
    )
    return submit(engine, plan, compiled=True).result
