"""Collective-traffic accounting from lowered/compiled HLO text.

``cost_analysis()`` reports FLOPs and memory bytes but *not* collective
bytes, so the roofline's third term is derived here: parse the (stable)
HLO text for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops and sum their operand sizes.

Bytes convention (per participating device, which is what the ICI roofline
term wants):
  * all-reduce: operand bytes (ring: 2x(n-1)/n ~ 2x; we report raw operand
    bytes and apply the algorithm factor in the roofline model)
  * all-gather: output bytes - operand bytes received
  * reduce-scatter: operand bytes - output sent
  * all-to-all / collective-permute: operand bytes

The parser reads shapes like ``bf16[16,512]{1,0}`` from op result/operand
types; fusions never contain collectives, so top-level scanning suffices.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %ar = bf16[16,512]{1,0} all-reduce(bf16[16,512]{1,0} %x), ...
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|[^=(]*?)\s*"
    r"(all-reduce-start|all-gather-start|all-reduce|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\b"
    r"(.*)$"
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind.

    Returns {kind: bytes, ..., "total": bytes, "count": n_ops}.
    """
    out: dict = defaultdict(int)
    count = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if m is None:
            continue
        result_type, op, rest = m.group(1), m.group(2), m.group(3)
        kind = op.replace("-start", "")
        # operand shapes appear inside the call parens in `rest`
        operand_part = rest.split("(", 1)[-1]
        # strip attributes after the closing paren of operands
        depth, end = 1, len(operand_part)
        for i, ch in enumerate(operand_part):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        op_bytes = _shape_bytes(operand_part[:end])
        if op_bytes == 0:  # some forms put the shape only on the result
            op_bytes = _shape_bytes(result_type)
        out[kind] += op_bytes
        count += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES if k in out)
    out["count"] = count
    return dict(out)
