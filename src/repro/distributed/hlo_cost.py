"""Loop-aware HLO cost analysis (FLOPs / HBM bytes / collective bytes).

XLA's built-in ``compiled.cost_analysis()`` counts each while-loop *body
once*, ignoring trip counts — useless for scanned-layer models where >99%
of the work sits inside loops (verified: scan(5) and scan(10) report
identical FLOPs).  This module re-derives the three roofline inputs from
the post-SPMD compiled HLO text with loop multipliers applied:

  * **FLOPs** — from ``dot`` ops: 2 x |result| x contracted-extent
    (matmuls are >95 % of LM FLOPs; elementwise FLOPs are intentionally
    excluded and the omission is documented in EXPERIMENTS.md).
  * **HBM bytes** — per top-level instruction: result bytes + operand
    bytes.  Fusion-internal instructions are skipped (a fusion's memory
    traffic is its boundary); plumbing ops (parameter / tuple /
    get-tuple-element / bitcast / constant) are free.
  * **Collective bytes** — operand bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (+ ``-start``
    forms), per participating device.

Loop handling: ``while`` ops carry ``backend_config=
{"known_trip_count":{"n":"N"}}``; the walker multiplies body+condition
costs by N (nested loops compose multiplicatively).  Unknown trip counts
fall back to 1 and are flagged.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1,
    "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
    "all-reduce-start", "all-gather-start", "collective-permute-start",
}

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    "all-reduce-done", "all-gather-done", "collective-permute-done",
}

# Ops whose top-level appearance implies real HBM traffic ("mandatory"
# bytes: matmul operands/results, explicit data movement).  Bare
# elementwise ops / broadcasts / fusion boundaries at the top level are a
# CPU-lowering artefact — the TPU pipeline fuses elementwise chains into a
# handful of kernels per layer — so they go into the separate
# ``bytes_upper`` bound instead of the roofline memory term.
_MEMORY_OPS = {
    "dot", "custom-call", "copy",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "reduce", "reduce-window", "select-and-scatter", "sort",
    "concatenate", "slice", "pad", "cholesky", "triangular-solve",
    "convolution", "rng", "rng-bit-generator",
}

# %name = <type> <opcode>(...), attrs
# tuple types may contain /*index=N*/ comments (hence [^()]*, not [^=]*)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^()]*\)|[\w\[\],{}]+))\s+"
    r"([\w\-]+)\((.*)$"
)
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _numel(type_str: str) -> int:
    n = 1
    for d in _shape_dims(type_str):
        n *= d
    return n


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str


def parse_computations(hlo: str) -> dict:
    comps: dict[str, list[Instr]] = {}
    current = None
    for line in hlo.splitlines():
        head = _COMP_HEAD_RE.match(line)
        if head:
            current = head.group(1)
            comps[current] = []
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[current].append(Instr(*m.groups()))
    return comps


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0          # mandatory traffic (roofline memory term)
    bytes_upper: float = 0.0    # + fusion boundaries (CPU-granularity bound)
    coll: dict = dataclasses.field(default_factory=dict)
    unknown_trip_loops: int = 0

    def add(self, other: "Cost", mult: float = 1.0, count_bytes: bool = True):
        self.flops += mult * other.flops
        if count_bytes:
            self.bytes += mult * other.bytes
            self.bytes_upper += mult * other.bytes_upper
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + mult * v
        self.unknown_trip_loops += other.unknown_trip_loops


class HloCostModel:
    def __init__(self, hlo: str):
        self.comps = parse_computations(hlo)
        self.entry = None
        for line in hlo.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HEAD_RE.match(line)
                if m:
                    self.entry = m.group(1)
        self._memo: dict[tuple, Cost] = {}
        # name -> result type, per computation
        self._types = {
            cname: {i.name: i.type_str for i in instrs}
            for cname, instrs in self.comps.items()
        }

    # --- per-instruction costs -------------------------------------------------

    def _operand_bytes(self, comp: str, rest: str) -> float:
        # operands live before the first "), " attribute boundary
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        types = self._types.get(comp, {})
        total = 0.0
        for name in _OPERAND_RE.findall(rest[:end]):
            t = types.get(name)
            if t:
                total += _type_bytes(t)
        return total

    def _dot_flops(self, comp: str, instr: Instr) -> float:
        out_elems = _numel(instr.type_str)
        m = _CONTRACT_RE.search(instr.rest)
        contracted = 1
        if m:
            dims = [int(d) for d in m.group(1).split(",") if d]
            # lhs operand = first %name in the call parens
            names = _OPERAND_RE.findall(instr.rest)
            if names:
                lhs_t = self._types.get(comp, {}).get(names[0])
                if lhs_t:
                    shape = _shape_dims(lhs_t)
                    for d in dims:
                        if d < len(shape):
                            contracted *= shape[d]
        return 2.0 * out_elems * contracted

    # --- walk ---------------------------------------------------------------------

    def cost_of(self, comp: str, in_fusion: bool = False) -> Cost:
        key = (comp, in_fusion)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        for instr in self.comps.get(comp, []):
            op = instr.opcode
            if op == "while":
                cb = _COND_BODY_RE.search(instr.rest)
                trip_m = _TRIP_RE.search(instr.rest)
                trips = int(trip_m.group(1)) if trip_m else 1
                if trip_m is None:
                    total.unknown_trip_loops += 1
                if cb:
                    total.add(self.cost_of(cb.group(1), in_fusion), trips)
                    total.add(self.cost_of(cb.group(2), in_fusion), trips)
                continue
            if op in ("fusion",):
                m = _CALLS_RE.search(instr.rest)
                if m:
                    total.add(
                        self.cost_of(m.group(1), in_fusion=True), 1.0
                    )
                if not in_fusion:
                    total.bytes_upper += _type_bytes(instr.type_str)
                    total.bytes_upper += self._operand_bytes(comp, instr.rest)
                continue
            if op in ("call", "custom-call", "conditional", "sort", "reduce",
                      "reduce-window", "scatter", "map", "select-and-scatter"):
                for callee in _CALLS_RE.findall(instr.rest):
                    total.add(self.cost_of(callee, in_fusion=True), 1.0)
                # to_apply= computations (reduce/sort/scatter combiners)
                m2 = re.search(r"to_apply=%([\w.\-]+)", instr.rest)
                if m2:
                    total.add(self.cost_of(m2.group(1), in_fusion=True), 1.0)
                if not in_fusion:
                    b = _type_bytes(instr.type_str) + self._operand_bytes(
                        comp, instr.rest
                    )
                    total.bytes += b
                    total.bytes_upper += b
                continue
            if op == "dot":
                total.flops += self._dot_flops(comp, instr)
                if not in_fusion:
                    b = _type_bytes(instr.type_str) + self._operand_bytes(
                        comp, instr.rest
                    )
                    total.bytes += b
                    total.bytes_upper += b
                continue
            if op in _COLLECTIVE_OPS:
                kind = op.replace("-start", "")
                b = self._operand_bytes(comp, instr.rest)
                if b == 0:
                    b = _type_bytes(instr.type_str)
                total.coll[kind] = total.coll.get(kind, 0.0) + b
                continue
            if op in _FREE_OPS:
                continue
            if op == "dynamic-update-slice" and not in_fusion:
                # in-place update: traffic = read update + write region,
                # NOT the whole target operand (decode caches are GBs; the
                # per-token update is KBs)
                names = _OPERAND_RE.findall(instr.rest)
                upd = (
                    _type_bytes(self._types.get(comp, {}).get(names[1], ""))
                    if len(names) > 1
                    else 0
                )
                total.bytes += 2 * upd
                total.bytes_upper += 2 * upd
                continue
            if not in_fusion and op in _MEMORY_OPS:
                b = _type_bytes(instr.type_str) + self._operand_bytes(
                    comp, instr.rest
                )
                total.bytes += b
                total.bytes_upper += b
        self._memo[key] = total
        return total

    def analyze(self) -> dict:
        if self.entry is None:
            raise ValueError("no ENTRY computation found")
        c = self.cost_of(self.entry)
        coll_total = sum(c.coll.values())
        return {
            "flops": c.flops,
            "bytes": c.bytes,
            "bytes_upper": c.bytes + c.bytes_upper,
            "collectives": {**c.coll, "total": coll_total},
            "unknown_trip_loops": c.unknown_trip_loops,
        }


def analyze_hlo(hlo_text: str) -> dict:
    return HloCostModel(hlo_text).analyze()
