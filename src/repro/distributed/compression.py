"""Cross-pod gradient compression: int8 quantised psum with error feedback.

At 1000+ node scale the inter-pod reduction rides the slow DCN links, so
the pod-axis all-reduce is the bandwidth bottleneck for data parallelism
across pods.  This module compresses exactly (and only) that hop:

  * gradients are first reduced over the fast intra-pod axes by GSPMD as
    usual (the loss mean over "data" happens inside the auto region);
  * the "pod" axis is made *manual* via partial-auto ``jax.shard_map``; each
    pod quantises its local gradient to int8 (per-leaf absmax scale), psums
    the int8 payload + f32 scales over "pod", and dequantises;
  * the quantisation residual is carried as **error feedback** into the
    next step (standard 1-bit/8-bit SGD trick: the compression error is
    re-added before the next quantisation, making the scheme unbiased over
    time and empirically loss-neutral at int8).

Traffic on the pod axis: 1 byte/grad element + one f32 scale per leaf,
i.e. a 4x reduction vs f32 psum (2x vs bf16).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def compressed_pmean(grads, err_state, axis: str = "pod", n_pods: int | None = None):
    """int8 error-feedback mean-reduce over ``axis`` — call from INSIDE a
    shard_map region that is manual over ``axis`` (e.g. the train step's
    pod-local gradient body).

    grads/err_state: matching pytrees (err_state f32, zeros initially).
    Returns (reduced_grads, new_err_state).

    A *shared* scale (pod-max of the local absmax, one scalar f32 pmax per
    leaf — negligible traffic) makes the int8 dequantisation exact:
    sum_i(q_i) * scale == sum_i(q_i * scale).  The only lossy step is the
    local rounding, which error feedback re-injects next step.
    """
    if n_pods is None:
        n_pods = jax.lax.axis_size(axis)

    def one(g, e):
        target = g.astype(jnp.float32) + e
        local_max = jnp.max(jnp.abs(target))
        scale = jnp.maximum(jax.lax.pmax(local_max, axis), 1e-12) / 127.0
        q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
        new_e = target - q.astype(jnp.float32) * scale
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis)
        return q_sum.astype(jnp.float32) * scale / n_pods, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    reduced = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return reduced, new_err


def compressed_psum_pod(grads, err_state, mesh, axis: str = "pod"):
    """Standalone wrapper: runs ``compressed_pmean`` in its own partial-auto
    shard_map (for callers not already inside a pod-manual region)."""
    n_pods = dict(zip(mesh.axis_names, mesh.axis_sizes))[axis]

    def body(*flat):
        n = len(flat) // 2
        g = jax.tree.unflatten(jax.tree.structure(grads), list(flat[:n]))
        e = jax.tree.unflatten(jax.tree.structure(err_state), list(flat[n:]))
        red, new_e = compressed_pmean(g, e, axis, n_pods)
        return tuple(jax.tree.leaves(red)) + tuple(jax.tree.leaves(new_e))

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    n = len(flat_g)
    specs = tuple(P() for _ in range(2 * n))
    out = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=specs,
        out_specs=specs,
        axis_names={axis},
        check_vma=False,
    )(*flat_g, *flat_e)
    reduced = jax.tree.unflatten(treedef, list(out[:n]))
    new_err = jax.tree.unflatten(treedef, list(out[n:]))
    return reduced, new_err


def init_error_state(grads_like):
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
    )
