"""Straggler detection for 1000+ node fleets.

Per-host step wall-times feed an EMA; hosts whose smoothed step time
exceeds ``threshold`` x the fleet median are flagged.  The *policy* applied
to a flagged host (re-slice its data shard away, drain + hot-swap, or just
alert) is deployment-specific; this module implements the detector plus a
pluggable policy callback, and the launcher wires it to logging in this
container (no real fleet to evict from).

The detector is deliberately stateless across restarts (a restarted host
re-earns its reputation) and robust to fleet-wide slowdowns (median-relative,
so a global slow step flags nobody).
"""

from __future__ import annotations

import dataclasses
import statistics
from typing import Callable


@dataclasses.dataclass
class HostStats:
    ema_s: float | None = None
    flagged: bool = False
    n_steps: int = 0


class StragglerWatchdog:
    def __init__(
        self,
        n_hosts: int,
        threshold: float = 1.5,
        ema_alpha: float = 0.3,
        min_steps: int = 5,
        on_flag: Callable[[int, float, float], None] | None = None,
    ):
        self.threshold = threshold
        self.alpha = ema_alpha
        self.min_steps = min_steps
        self.hosts = {h: HostStats() for h in range(n_hosts)}
        self.on_flag = on_flag or (lambda *a: None)

    def record(self, host_id: int, step_time_s: float):
        st = self.hosts[host_id]
        st.n_steps += 1
        st.ema_s = (
            step_time_s
            if st.ema_s is None
            else self.alpha * step_time_s + (1 - self.alpha) * st.ema_s
        )

    def check(self) -> list[int]:
        """Returns newly-flagged host ids (and fires the policy callback)."""
        emas = [s.ema_s for s in self.hosts.values() if s.ema_s is not None]
        ready = [s for s in self.hosts.values() if s.n_steps >= self.min_steps]
        if len(ready) < max(2, len(self.hosts) // 2) or not emas:
            return []
        med = statistics.median(emas)
        newly = []
        for hid, st in self.hosts.items():
            if st.ema_s is None or st.n_steps < self.min_steps:
                continue
            is_slow = st.ema_s > self.threshold * med
            if is_slow and not st.flagged:
                st.flagged = True
                newly.append(hid)
                self.on_flag(hid, st.ema_s, med)
            elif not is_slow and st.flagged:
                st.flagged = False  # recovered
        return newly

    @property
    def flagged(self) -> list[int]:
        return [h for h, s in self.hosts.items() if s.flagged]
