"""Preemption / fault handling for long-running training.

``PreemptionHandler`` converts SIGTERM/SIGINT into a *checkpoint request*
honoured at the next step boundary (never mid-step, so the saved state is
bit-exact a step boundary), after which the loop exits cleanly with code 0
— the contract cluster schedulers (Borg/K8s eviction, TPU maintenance
events) expect.  Training resumes from the latest checkpoint via
``CheckpointManager.restore_latest`` — combined with the (seed, step)-pure
data pipeline, the restarted run replays identical batches.

``simulate_preemption()`` triggers the same path in-process for the fault
injection test.
"""

from __future__ import annotations

import signal
import threading


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._requested = threading.Event()
        self._prev = {}
        self._signals = signals

    def install(self):
        for sig in self._signals:
            self._prev[sig] = signal.signal(sig, self._on_signal)
        return self

    def uninstall(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
        self._prev.clear()

    def _on_signal(self, signum, frame):
        self._requested.set()

    def simulate_preemption(self):
        self._requested.set()

    @property
    def preemption_requested(self) -> bool:
        return self._requested.is_set()

    def clear(self):
        self._requested.clear()
