from repro.distributed import sharding  # noqa: F401
from repro.distributed.hlo_analysis import collective_bytes  # noqa: F401
from repro.distributed.straggler import StragglerWatchdog  # noqa: F401
from repro.distributed.fault import PreemptionHandler  # noqa: F401
