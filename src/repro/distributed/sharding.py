"""Logical-axis sharding rules (DP / TP / EP / SP / ZeRO) for the framework.

Model code annotates arrays with *logical* axis names; this module maps them
onto mesh axes per a rules table, filtered by what the active mesh actually
provides and by divisibility (a logical dim not divisible by its mesh-axis
extent falls back to replication — GSPMD could pad, but even sharding keeps
the collective schedule predictable at 1000+ nodes).

Baseline rules (see DESIGN.md §6):
  batch   -> ("pod", "data")     data parallelism (pod axis = outer DP)
  heads / kv_heads / ffn / vocab / experts / ssm_heads -> "model"   (TP / EP)
  seq_ctx -> "data"              context parallelism for long-context decode
  everything else  -> replicated

ZeRO-1: optimizer states / master params additionally shard their largest
replicated dim over ("pod", "data") via ``add_zero_axes``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

Axes = tuple  # tuple[str | None | tuple[str, ...], ...]


DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "ssm_heads": "model",
    "chains": ("pod", "data"),  # sampler-engine chain axis (DP-like)
    "seq_ctx": "data",      # context parallelism (long-context decode)
    "seq_sp": "model",      # sequence parallelism on the residual stream
    # replicated logical axes
    "seq": None,
    "cache_seq": None,   # decode KV cache seq (arch override -> "model"/"data")
    "embed": None,
    "embed_tp": "model",  # input-embedding d-sharding (gather stays local)
    "vocab_rep": None,    # input-embedding vocab axis (replicated)
    "head_dim": None,
    "ssm_state": None,
    "conv": None,
    "layers": None,
    "expert_cap": None,
    "frames": None,
    "patches": None,
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: tuple = tuple(sorted(DEFAULT_RULES.items()))

    def as_dict(self) -> dict:
        return dict(self.rules)

    def replace(self, **updates) -> "ShardingRules":
        d = self.as_dict()
        d.update(updates)
        return ShardingRules(rules=tuple(sorted(d.items())))


# --- active-rules context ----------------------------------------------------
# Model code calls shard(x, logical_axes) without threading rules; launchers
# install per-arch rule patches (cfg.sharding_overrides) around tracing.

_ACTIVE_RULES: list = [ShardingRules()]


def get_rules() -> ShardingRules:
    return _ACTIVE_RULES[-1]


class use_rules:
    """Context manager installing sharding rules for the enclosed trace."""

    def __init__(self, rules: ShardingRules):
        self.rules = rules

    def __enter__(self):
        _ACTIVE_RULES.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE_RULES.pop()
        return False


def rules_for_config(cfg) -> ShardingRules:
    """Base rules + per-arch overrides (cfg.sharding_overrides tuple)."""
    overrides = dict(getattr(cfg, "sharding_overrides", ()) or ())
    return ShardingRules().replace(**overrides) if overrides else ShardingRules()


def active_mesh():
    """The abstract mesh from ``jax.set_mesh``; None when not set.

    Older jax releases predate ``get_abstract_mesh`` (and the AxisType
    machinery); treat them as "no ambient mesh" so single-process paths
    (serve/examples on CPU) still run.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        return None
    mesh = get()
    if mesh is None or mesh.empty:
        return None
    return mesh


def _mesh_axis_size(mesh, axis) -> int:
    sizes = getattr(mesh, "axis_sizes", None)  # absent on old-jax Mesh
    if sizes is None:
        return dict(mesh.shape)[axis]
    return dict(zip(mesh.axis_names, sizes))[axis]


def _manual_axes(mesh) -> set:
    """Mesh axes currently in Manual mode (inside a shard_map region)."""
    types = getattr(mesh, "axis_types", None)
    if types is None:
        return set()
    manual = jax.sharding.AxisType.Manual
    return {n for n, t in zip(mesh.axis_names, types) if t == manual}


def _filter_entry(entry, mesh, dim_size: int | None, used: set = frozenset()):
    """Resolve one logical axis to mesh axes present, unused & divisible.

    Axes that are Manual in the current context (inside a shard_map over
    them) are skipped — constraints may only name Auto axes there.
    """
    if entry is None:
        return None
    names = entry if isinstance(entry, tuple) else (entry,)
    manual = _manual_axes(mesh)
    kept = []
    extent = 1
    for name in names:
        if name not in mesh.axis_names or name in used or name in manual:
            continue
        size = _mesh_axis_size(mesh, name)
        if dim_size is not None and dim_size % (extent * size) != 0:
            continue
        kept.append(name)
        extent *= size
    if not kept:
        return None
    return tuple(kept) if len(kept) > 1 else kept[0]


def spec_for(
    logical_axes: Axes,
    rules: ShardingRules = ShardingRules(),
    shape: tuple | None = None,
    mesh=None,
) -> P | None:
    """Map logical axes -> PartitionSpec under the active mesh (None = no mesh)."""
    mesh = mesh or active_mesh()
    if mesh is None:
        return None
    table = rules.as_dict()
    entries = []
    used: set = set()
    for i, ax in enumerate(logical_axes):
        entry = table.get(ax) if ax is not None else None
        dim = None if shape is None else shape[i]
        # a mesh axis may appear at most once in a spec: skip used names
        resolved = _filter_entry(entry, mesh, dim, used)
        if resolved is not None:
            names = resolved if isinstance(resolved, tuple) else (resolved,)
            used.update(names)
        entries.append(resolved)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def shard(x, logical_axes: Axes, rules: ShardingRules | None = None):
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    spec = spec_for(logical_axes, rules or get_rules(), shape=jnp.shape(x))
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def add_zero_axes(
    logical_axes: Axes,
    shape: tuple,
    rules: ShardingRules = ShardingRules(),
    mesh=None,
    zero_axes: tuple = ("pod", "data"),
) -> Axes:
    """ZeRO-1: extend a param's axes so optimizer state also shards over DP.

    Picks the first replicated dim divisible by the full DP extent and maps
    it to a synthetic logical axis bound to ``zero_axes``.
    """
    mesh = mesh or active_mesh()
    if mesh is None:
        return logical_axes
    table = rules.as_dict()
    dp = 1
    for name in zero_axes:
        if name in mesh.axis_names:
            dp *= _mesh_axis_size(mesh, name)
    if dp <= 1:
        return logical_axes
    out = list(logical_axes)
    for i, ax in enumerate(out):
        entry = table.get(ax) if ax is not None else None
        if entry is None and shape[i] % dp == 0:
            out[i] = "_zero"
            return tuple(out)
    return logical_axes


ZERO_RULES_PATCH = {"_zero": ("pod", "data")}


def rules_with_zero(rules: ShardingRules = ShardingRules()) -> ShardingRules:
    return rules.replace(**ZERO_RULES_PATCH)


def named_sharding(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_specs(axes_tree, rules: ShardingRules, shapes_tree=None, mesh=None):
    """Map a pytree of LogicalAxes leaves to PartitionSpecs."""
    from repro.models.layers import LogicalAxes

    def _names(a):
        return a.names if isinstance(a, LogicalAxes) else tuple(a)

    if shapes_tree is None:
        return jax.tree.map(lambda a: spec_for(_names(a), rules, mesh=mesh), axes_tree)
    return jax.tree.map(
        lambda a, s: spec_for(_names(a), rules, shape=s.shape, mesh=mesh),
        axes_tree,
        shapes_tree,
    )
