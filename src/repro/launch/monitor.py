"""Tail, summarize, or validate a telemetry trace file.

The read-side companion of ``--trace`` (DESIGN.md §Telemetry): point it
at a JSONL trace emitted by ``launch/sample``, ``launch/serve_engine``
or ``benchmarks/run`` and get a per-span-name aggregation (count, total
/ mean / max duration, share of traced time) plus the instant/log
events.  ``--check`` validates every line against the trace event
schema and exits non-zero on the first malformed file — the CI
telemetry smoke runs exactly this.  ``--follow`` tails a live file,
printing events as a run appends them.

Usage:
  PYTHONPATH=src python -m repro.launch.sample --workload ising --smoke \
      --trace out.trace.jsonl
  PYTHONPATH=src python -m repro.launch.monitor out.trace.jsonl
  PYTHONPATH=src python -m repro.launch.monitor --check out.trace.jsonl
  PYTHONPATH=src python -m repro.launch.monitor --follow live.trace.jsonl
"""

from __future__ import annotations

import argparse
import json
import time

from repro import telemetry


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.launch.monitor",
        description="Tail/summarize/validate a telemetry JSONL trace.",
    )
    p.add_argument("trace", help="JSONL trace file (--trace output)")
    p.add_argument(
        "--check", action="store_true",
        help="validate against the event schema; exit 1 on any problem",
    )
    p.add_argument(
        "--follow", action="store_true",
        help="tail the file, printing events as they are appended",
    )
    p.add_argument(
        "--top", type=int, default=20,
        help="span names shown in the summary (by total duration)",
    )
    return p


def read_events(path: str) -> tuple[dict | None, list[dict]]:
    """(header, events) from a JSONL trace; malformed lines are skipped
    (use --check for strict validation)."""
    header = None
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError:
                continue
            if obj.get("kind") == "trace_meta":
                header = obj
            else:
                events.append(obj)
    return header, events


def summarize_events(events: list[dict], top: int = 20) -> list[dict]:
    """Per-span-name aggregate rows, sorted by total duration."""
    agg: dict[str, dict] = {}
    for ev in events:
        if ev.get("kind") != "span":
            continue
        row = agg.setdefault(
            ev["name"], {"count": 0, "total_us": 0.0, "max_us": 0.0}
        )
        dur = float(ev.get("dur_us", 0.0))
        row["count"] += 1
        row["total_us"] += dur
        row["max_us"] = max(row["max_us"], dur)
    total = sum(r["total_us"] for r in agg.values()) or 1.0
    rows = []
    for name, r in sorted(
        agg.items(), key=lambda kv: -kv[1]["total_us"]
    )[: max(1, top)]:
        rows.append(
            {
                "span": name,
                "count": r["count"],
                "total_ms": round(r["total_us"] / 1e3, 3),
                "mean_us": round(r["total_us"] / r["count"], 1),
                "max_us": round(r["max_us"], 1),
                "share": round(r["total_us"] / total, 3),
            }
        )
    return rows


def _print_summary(path: str, top: int) -> int:
    header, events = read_events(path)
    spans = [e for e in events if e.get("kind") == "span"]
    instants = [e for e in events if e.get("kind") == "instant"]
    print(
        f"[monitor] {path}: {len(spans)} spans, {len(instants)} instants"
        + (
            f", {header.get('dropped', 0)} dropped (ring overflow)"
            if header
            else ", no header (partial file?)"
        )
    )
    for row in summarize_events(events, top=top):
        print("  " + "  ".join(f"{k}={v}" for k, v in row.items()))
    if instants:
        print("[monitor] last instants:")
        for ev in instants[-min(10, len(instants)):]:
            meta = ev.get("meta", {})
            print(
                f"  {ev['name']} @ {float(ev['ts_us']) / 1e6:.3f}s  "
                + "  ".join(f"{k}={v}" for k, v in meta.items())
            )
    return 0


def _check(path: str) -> int:
    problems = telemetry.validate_jsonl(path)
    if problems:
        print(f"[monitor] {path}: INVALID ({len(problems)} problems)")
        for msg in problems[:20]:
            print(f"  {msg}")
        return 1
    header, events = read_events(path)
    print(
        f"[monitor] {path}: valid trace (schema "
        f"{header.get('schema') if header else '?'}, {len(events)} events)"
    )
    return 0


def _follow(path: str) -> int:  # pragma: no cover - interactive loop
    with open(path) as f:
        while True:
            line = f.readline()
            if not line:
                time.sleep(0.2)
                continue
            line = line.strip()
            if line:
                print(line)


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.check:
        return _check(args.trace)
    if args.follow:
        return _follow(args.trace)
    return _print_summary(args.trace, args.top)


if __name__ == "__main__":
    raise SystemExit(main())
