"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — required because the
dry-run must set ``XLA_FLAGS`` *before* the first jax device query, and
smoke tests must keep seeing 1 device.

Meshes (assignment):
  single-pod:  (16, 16)      axes ("data", "model")   = 256 chips
  multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

``alt_mesh`` builds §Perf-lever variants (e.g. (32, 8) to restore attention
TP for 40/24/20-head archs) — same chip count, different axis split.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def alt_mesh(data: int, model: int, *, pods: int = 1):
    """Same-chip-count §Perf variants, e.g. alt_mesh(32, 8)."""
    if pods > 1:
        return jax.make_mesh(
            (pods, data, model),
            ("pod", "data", "model"),
            axis_types=(AxisType.Auto,) * 3,
        )
    return jax.make_mesh(
        (data, model), ("data", "model"), axis_types=(AxisType.Auto,) * 2
    )


def mesh_chip_count(mesh) -> int:
    n = 1
    for s in mesh.axis_sizes:
        n *= s
    return n
