"""Production mesh definitions.

Every builder here is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — required because the
dry-run must set ``XLA_FLAGS`` *before* the first jax device query, and
smoke tests must keep seeing 1 device.

Meshes (assignment):
  single-pod:  (16, 16)      axes ("data", "model")   = 256 chips
  multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

``alt_mesh`` builds §Perf-lever variants (e.g. (32, 8) to restore attention
TP for 40/24/20-head archs) — same chip count, different axis split.

``make_chains_mesh`` is the sampler engine's scale-out mesh: a 1-D
process-spanning device mesh for the "chains" sharding rule (DESIGN.md
§Chains-axis / §Run-API).  CI exercises it at N host devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``
(tests/test_multidevice.py).
"""

from __future__ import annotations

import jax
import numpy as np

try:  # AxisType only exists from jax 0.4.3x; the pinned-min CI cell
    from jax.sharding import AxisType  # (0.4.30) must still import us
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def make_production_mesh(*, multi_pod: bool = False):
    if AxisType is None:
        raise RuntimeError(
            "make_production_mesh needs jax >= 0.4.35 (jax.make_mesh / "
            "AxisType); the sampler meshes (make_chains_mesh) support the "
            "full pinned range"
        )
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def alt_mesh(data: int, model: int, *, pods: int = 1):
    """Same-chip-count §Perf variants, e.g. alt_mesh(32, 8)."""
    if AxisType is None:
        raise RuntimeError(
            "alt_mesh needs jax >= 0.4.35 (jax.make_mesh / AxisType)"
        )
    if pods > 1:
        return jax.make_mesh(
            (pods, data, model),
            ("pod", "data", "model"),
            axis_types=(AxisType.Auto,) * 3,
        )
    return jax.make_mesh(
        (data, model), ("data", "model"), axis_types=(AxisType.Auto,) * 2
    )


def make_chains_mesh(num_chains: int | None = None, *, devices=None):
    """The engine's scale-out mesh: 1-D ("data",) over every addressable
    device, for sharding the chains axis via the "chains" rule.

    ``jax.devices()`` spans *all* processes in a multi-host run, so the
    same call builds the process-spanning production mesh and the
    CI-side mock (``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    turns one CPU into N host devices).  Returns ``None`` when sharding
    cannot help — fewer than 2 devices, or a known chain count below 2 —
    so callers can pass the result straight to ``RunPlan(mesh=...)``.

    Built via the ``jax.sharding.Mesh`` constructor directly:
    ``jax.make_mesh`` only exists from jax 0.4.35, and this must run on
    the whole supported range (pyproject pins >= 0.4.30).
    """
    if num_chains is not None and num_chains < 2:
        return None
    if devices is None:
        devices = jax.devices()
    if len(devices) < 2:
        return None
    return jax.sharding.Mesh(np.asarray(devices), ("data",))


def mesh_chip_count(mesh) -> int:
    n = 1
    for s in mesh.axis_sizes:
        n *= s
    return n
