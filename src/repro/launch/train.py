"""Training driver: data -> train_step -> checkpoint, with fault tolerance.

Runs end-to-end on CPU with smoke/small configs (the examples train a
~100M-param model for a few hundred steps); the identical code path lowers
onto the production meshes (the dry-run proves each arch compiles there).

Fault tolerance in the loop:
  * auto-resume from the latest valid checkpoint (mesh-elastic restore),
  * SIGTERM/SIGINT -> checkpoint at the next step boundary, exit 0,
  * periodic + final checkpoints (atomic, integrity-hashed, retained K),
  * per-step wall-time watchdog feeding the straggler detector (single-host
    here: flags log; a fleet launcher would re-slice data away),
  * deterministic (seed, step) data — restart replays identical batches.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch hymba_1p5b --smoke \
      --steps 50 --batch 8 --seq 64
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.distributed.fault import PreemptionHandler
from repro.distributed.straggler import StragglerWatchdog
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init, cosine_schedule
from repro.training.step import TrainStepConfig, make_train_step


@dataclasses.dataclass
class TrainRun:
    cfg: object
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 64
    lr: float = 3e-4
    warmup: int = 20
    seed: int = 0
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    n_micro: int = 1
    log_every: int = 10


def run_training(run: TrainRun, preemption: PreemptionHandler | None = None):
    cfg = run.cfg
    key = jax.random.PRNGKey(run.seed)
    vals, axes = lm.init_lm_values(key, cfg)
    opt_cfg = AdamWConfig(lr=run.lr)
    opt_state = adamw_init(vals, opt_cfg)

    data = SyntheticTokenPipeline(
        DataConfig(
            vocab_size=cfg.vocab_size,
            seq_len=run.seq_len,
            global_batch=run.global_batch,
            seed=run.seed,
        )
    )

    schedule = lambda s: cosine_schedule(s, run.warmup, run.steps)  # noqa: E731
    step_fn = jax.jit(
        make_train_step(
            cfg,
            axes,
            opt_cfg,
            schedule_fn=schedule,
            step_cfg=TrainStepConfig(n_micro=run.n_micro),
        )
    )

    manager = None
    start_step = 0
    if run.ckpt_dir:
        manager = CheckpointManager(
            CheckpointConfig(directory=run.ckpt_dir, retention=3)
        )
        state = {"params": vals, "opt": opt_state}
        restored, ck_step = manager.restore_latest(state)
        if restored is not None:
            vals, opt_state = restored["params"], restored["opt"]
            start_step = ck_step
            print(f"[train] resumed from step {start_step}")

    watchdog = StragglerWatchdog(
        n_hosts=1,
        on_flag=lambda h, ema, med: print(
            f"[train] WARN host {h} straggling: {ema:.3f}s vs median {med:.3f}s"
        ),
    )

    losses = []
    step = start_step
    for step in range(start_step, run.steps):
        batch = data.host_batch(step)
        t0 = time.time()
        vals, opt_state, metrics = step_fn(vals, opt_state, batch)
        loss = float(metrics["loss"])
        watchdog.record(0, time.time() - t0)
        watchdog.check()
        losses.append(loss)
        if step % run.log_every == 0 or step == run.steps - 1:
            print(
                f"[train] step {step:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr x{float(metrics['lr']):.2e} ({time.time() - t0:.2f}s)"
            )
        if manager and (step + 1) % run.ckpt_every == 0:
            manager.save(step + 1, {"params": vals, "opt": opt_state})
        if preemption is not None and preemption.preemption_requested:
            print(f"[train] preemption requested — checkpointing at step {step + 1}")
            if manager:
                manager.save(step + 1, {"params": vals, "opt": opt_state})
                manager.wait()
            return vals, opt_state, losses
    if manager:
        manager.save(run.steps, {"params": vals, "opt": opt_state})
        manager.wait()
    return vals, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (
        configs.get_smoke_config(args.arch)
        if args.smoke
        else configs.get_config(args.arch)
    )
    handler = PreemptionHandler().install()
    run = TrainRun(
        cfg=cfg,
        steps=args.steps,
        global_batch=args.batch,
        seq_len=args.seq,
        lr=args.lr,
        n_micro=args.n_micro,
        ckpt_dir=args.ckpt_dir,
        seed=args.seed,
    )
    _, _, losses = run_training(run, preemption=handler)
    n = max(1, len(losses) // 10)
    print(
        f"[train] done: first-{n} mean loss {np.mean(losses[:n]):.4f} -> "
        f"last-{n} mean loss {np.mean(losses[-n:]):.4f}"
    )


if __name__ == "__main__":
    main()
