"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

Per (arch x shape x mesh) cell, from the compiled artifact:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s_per_chip
  memory term     = HLO_bytes_per_device / HBM_bw_per_chip
  collective term = effective_collective_bytes_per_device / ICI link bw

``cost_analysis()`` numbers on the SPMD-partitioned module are already
per-device.  Collective bytes come from the post-partitioning HLO operand
sizes, with per-kind algorithm factors (ring all-reduce moves ~2x the
payload; all-gather/reduce-scatter ~1x).

Hardware constants (TPU v5e, per assignment):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = params (active for
MoE), D = tokens processed per step; the ratio MODEL_FLOPS / global HLO
FLOPs flags remat/redundancy waste (>1 is impossible; ~0.3 means 3x
overhead from remat + attention + non-matmul work).

Usage:
  python -m repro.launch.roofline                 # 16x16 artifacts table
  python -m repro.launch.roofline --mesh pod2_16x16
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12         # bf16 / chip
HBM_BW = 819e9              # B/s / chip
ICI_BW = 50e9               # B/s / link

# effective bytes multipliers per collective kind (ring algorithms)
ALGO_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

ARTIFACT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun"
)


def effective_collective_bytes(coll: dict) -> float:
    return sum(
        coll.get(kind, 0) * fac for kind, fac in ALGO_FACTOR.items()
    )


def analyse(report: dict) -> dict:
    """Attach roofline terms to one dry-run artifact.

    Prefers the loop-aware HLO cost model (``hlo_cost``: while bodies
    multiplied by trip counts); ``cost_analysis`` raw values remain in the
    artifact as the body-once reference.
    """
    if report.get("status") != "ok":
        return dict(report)
    hc = report.get("hlo_cost")
    if hc:
        flops = hc["flops"]
        bytes_acc = hc["bytes"]
        bytes_upper = hc.get("bytes_upper", hc["bytes"])
        coll_eff = effective_collective_bytes(hc.get("collectives", {}))
    else:
        flops = report["cost_analysis"]["flops"]
        bytes_acc = report["cost_analysis"]["bytes_accessed"]
        bytes_upper = bytes_acc
        coll_eff = effective_collective_bytes(report.get("collectives", {}))
    chips = report["chips"]

    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll_eff / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    # MODEL_FLOPS: useful math per step
    n_params = (
        report["param_count_active"]
        if report["param_count_active"] != report["param_count"]
        else report["param_count"]
    )
    kind = report["kind"]
    shape = report["shape"]
    seq = {"train_4k": 4096, "prefill_32k": 32768, "decode_32k": 1, "long_500k": 1}[
        shape
    ]
    batch = {"train_4k": 256, "prefill_32k": 32, "decode_32k": 128, "long_500k": 1}[
        shape
    ]
    tokens = seq * batch
    mult = 6 if kind == "train" else 2
    model_flops = mult * n_params * tokens
    hlo_flops_global = flops * chips
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0

    bound_time = max(terms.values())
    out = dict(report)
    out["roofline"] = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "memory_upper_s": bytes_upper / HBM_BW,
        "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": useful,
        # fraction of roofline: useful work rate vs chip peak if running at
        # the dominant-term time
        "roofline_fraction": (
            model_flops / chips / PEAK_FLOPS / bound_time if bound_time else 0.0
        ),
    }
    return out


def load_reports(mesh_tag: str, tag: str | None = None):
    pat = os.path.join(ARTIFACT_DIR, mesh_tag, "*.json")
    reports = []
    for path in sorted(glob.glob(pat)):
        base = os.path.basename(path)[: -len(".json")]
        parts = base.split("__")
        if tag is None and len(parts) > 2:
            continue  # perf-iteration artifact, not baseline
        if tag is not None and (len(parts) < 3 or parts[2] != tag):
            continue
        with open(path) as f:
            reports.append(json.load(f))
    return reports


def table(reports) -> str:
    rows = [
        (
            "arch",
            "shape",
            "dom",
            "compute_ms",
            "memory_ms",
            "coll_ms",
            "useful",
            "roofline%",
        )
    ]
    for r in reports:
        a = analyse(r)
        if a.get("status") != "ok":
            rows.append((a["arch"], a["shape"], a.get("status"), "-", "-", "-", "-", "-"))
            continue
        rl = a["roofline"]
        rows.append(
            (
                a["arch"],
                a["shape"],
                rl["dominant"][:4],
                f"{rl['compute_s'] * 1e3:9.3f}",
                f"{rl['memory_s'] * 1e3:9.3f}",
                f"{rl['collective_s'] * 1e3:9.3f}",
                f"{rl['useful_flops_ratio']:6.3f}",
                f"{rl['roofline_fraction'] * 100:6.2f}",
            )
        )
    widths = [max(len(str(r[i])) for r in rows) for i in range(len(rows[0]))]
    lines = [
        "  ".join(str(c).rjust(w) for c, w in zip(row, widths)) for row in rows
    ]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    reports = load_reports(args.mesh, args.tag)
    if not reports:
        print(f"no artifacts under {ARTIFACT_DIR}/{args.mesh}")
        return
    print(table(reports))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([analyse(r) for r in reports], f, indent=1)


if __name__ == "__main__":
    main()
