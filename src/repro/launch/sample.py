"""Run a workload from the probabilistic-model zoo and report diagnostics.

The non-LLM face of the sampler engine: pick a workload (2-D Ising/MRF
via checkerboard Gibbs, GMM posterior via MH), a randomness backend
(ideal host vs the paper's CIM pipeline), and an execution substrate
(scan vs the fused Pallas kernel), run the chains, and print throughput
plus chain diagnostics (flip/acceptance rate, integrated autocorrelation
time, ESS, split-R-hat).

Usage:
  PYTHONPATH=src python -m repro.launch.sample --workload ising --smoke \
      --randomness cim --backend scan
  PYTHONPATH=src python -m repro.launch.sample --workload gmm \
      --chains 64 --steps 2048 --backend pallas
  PYTHONPATH=src python -m repro.launch.sample --workload ising \
      --num-chains 8 --backend pallas

All combinations of --randomness {host,cim} x --backend {scan,pallas}
run on CPU (pallas in interpret mode); scan and pallas produce
bit-identical sample streams under the same seed (tests/test_workloads).

``--num-chains C`` runs C independent chains in one device program
(DESIGN.md §Chains-axis): per-chain randomness and inits are
counter-derived, so chain 0 is bit-identical to a ``--num-chains 1``
run, and cross-chain ESS / split-R-hat are streamed in O(chunk) memory.
With more than one device visible, the chain axis shards over a 1-D
device mesh via shard_map (bit-identical to the unsharded run).
"""

from __future__ import annotations

import argparse
import time

import jax

from repro import workloads
from repro.core import energy


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.launch.sample",
        description="Sample a zoo workload on the unified engine.",
    )
    p.add_argument(
        "--workload", required=True, choices=sorted(workloads.WORKLOADS)
    )
    p.add_argument("--randomness", default="cim", choices=("host", "cim"))
    p.add_argument(
        "--backend", default="auto", choices=("auto", "scan", "pallas")
    )
    p.add_argument(
        "--smoke", action="store_true", help="tiny sizes for CPU CI runs"
    )
    p.add_argument("--steps", type=int, default=None, help="chain steps")
    p.add_argument(
        "--num-chains", type=int, default=1,
        help="independent chains run in one device program",
    )
    p.add_argument("--seed", type=int, default=0)
    # ising knobs
    p.add_argument("--height", type=int, default=None, help="ising lattice H")
    p.add_argument("--width", type=int, default=None, help="ising lattice W")
    p.add_argument("--batch", type=int, default=None, help="ising lattices")
    p.add_argument("--beta", type=float, default=None, help="ising coupling")
    p.add_argument("--field", type=float, default=0.0, help="ising ext. field")
    # gmm knobs
    p.add_argument("--nbits", type=int, default=None, help="gmm grid bits")
    p.add_argument("--chains", type=int, default=None, help="gmm chains")
    return p


def _workload_kwargs(args) -> dict:
    common = dict(
        randomness=args.randomness,
        backend=args.backend,
        smoke=args.smoke,
        n_steps=args.steps,
        num_chains=args.num_chains,
    )
    if args.workload == "ising":
        return dict(
            common,
            height=args.height,
            width=args.width,
            batch=args.batch,
            beta=args.beta,
            field=args.field,
        )
    return dict(common, nbits=args.nbits, chains=args.chains)


def _chains_mesh(num_chains: int):
    """A 1-D device mesh for sharding the chains axis, when it helps.

    Built via the ``jax.sharding.Mesh`` constructor directly —
    ``jax.make_mesh`` only exists from jax 0.4.35, and this must run on
    the whole supported range (pyproject pins >=0.4.30)."""
    n_dev = jax.device_count()
    if num_chains < 2 or n_dev < 2:
        return None
    import numpy as np

    return jax.sharding.Mesh(np.asarray(jax.devices()), ("data",))


def main(argv=None) -> dict:
    args = build_parser().parse_args(argv)
    key = jax.random.PRNGKey(args.seed)
    k_init, k_run = jax.random.split(key)
    wl = workloads.build(args.workload, k_init, **_workload_kwargs(args))
    mesh = _chains_mesh(args.num_chains)

    t0 = time.time()
    result = wl.run(k_run, mesh=mesh)
    jax.block_until_ready(result.samples)
    wall_s = time.time() - t0

    diag = wl.diagnostics(result)
    n_sites = int(wl.init_words.size)
    site_steps = wl.n_steps * n_sites
    nbits = int(wl.meta.get("nbits", wl.target.nbits))
    macro_fj = energy.energy_per_sample_fj(
        float(result.acceptance_rate), nbits
    ) * site_steps

    row = {
        "workload": wl.name,
        "update": wl.engine.config.update,
        "randomness": args.randomness,
        "backend": args.backend,
        "n_steps": wl.n_steps,
        "burn_in": wl.burn_in,
        "n_sites": n_sites,
        "wall_s": round(wall_s, 3),
        "site_steps_per_s": round(site_steps / max(wall_s, 1e-9), 1),
        "macro_energy_pj": round(macro_fj * 1e-3, 2),
        **{k: v for k, v in wl.meta.items() if k != "nbits"},
        # diagnostics run on the post-burn-in series; disambiguate its
        # step count from the chain's
        **{("kept_steps" if k == "n_steps" else k): v for k, v in diag.items()},
    }
    print("  ".join(f"{k}={v}" for k, v in row.items()))
    return row


if __name__ == "__main__":
    main()
