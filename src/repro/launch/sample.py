"""Run a workload from the probabilistic-model zoo and report diagnostics.

The non-LLM face of the sampler engine: pick a workload from the
registry (2-D Ising/MRF via checkerboard Gibbs, GMM posterior via MH,
±J spin glass), a randomness backend (ideal host vs the paper's CIM
pipeline), and an execution substrate (scan vs the fused Pallas kernel),
run the chains, and print throughput plus chain diagnostics
(flip/acceptance rate, integrated autocorrelation time, ESS,
split-R-hat).

Usage:
  PYTHONPATH=src python -m repro.launch.sample --workload ising --smoke \
      --randomness cim --backend scan
  PYTHONPATH=src python -m repro.launch.sample --workload gmm \
      --chains 64 --steps 2048 --backend pallas
  PYTHONPATH=src python -m repro.launch.sample --workload ising \
      --num-chains 8 --backend pallas

  # long chain, keep every 16th sample (diagnostics on the kept stream)
  PYTHONPATH=src python -m repro.launch.sample --workload ising \
      --steps 20000 --thin 16
  # optimisation-style run: O(state) sample memory, rate-only output
  PYTHONPATH=src python -m repro.launch.sample --workload spin_glass \
      --steps 50000 --keep-last

Workload choices and their knobs come straight from the
``workloads.WORKLOADS`` registry (flags a builder doesn't accept are
simply not forwarded), so a newly registered workload appears here with
no CLI change.

``--num-chains C`` runs C independent chains in one device program
(DESIGN.md §Chains-axis); with more than one device visible the chain
axis shards over a 1-D mesh via shard_map (bit-identical to unsharded).

Tempering (DESIGN.md §Tempering) wraps the same workload target:

  # parallel tempering: 8 replicas, geometric ladder down to beta 0.25
  PYTHONPATH=src python -m repro.launch.sample --workload spin_glass \
      --smoke --ladder 8 --beta-min 0.25 --swap-every 16

  # simulated annealing to a ground state / MAX-CUT
  PYTHONPATH=src python -m repro.launch.sample --workload spin_glass \
      --smoke --anneal 8 --beta-min 0.4 --beta-max 4.0

Both print swap/round-trip diagnostics (ladder) or the best-ever energy
(anneal) next to the cold-chain sample diagnostics; tempered streams are
bit-identical across {scan, pallas} x chunkings (tests/test_tempering).
"""

from __future__ import annotations

import argparse
import inspect
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import diagnostics, samplers, telemetry, tempering, workloads
from repro.core import energy
from repro.launch.mesh import make_chains_mesh


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.launch.sample",
        description="Sample a zoo workload on the unified engine.",
    )
    p.add_argument(
        "--workload", required=True, choices=sorted(workloads.WORKLOADS)
    )
    p.add_argument(
        "--randomness", default="cim", choices=("host", "cim", "fused"),
        help="operand source: host jax.random, the CIM pseudo-read+MSXOR "
        "pipeline, or fused in-kernel counter RNG (zero operand traffic "
        "under --backend pallas; DESIGN.md §Randomness)",
    )
    p.add_argument(
        "--backend", default="auto", choices=("auto", "scan", "pallas")
    )
    p.add_argument(
        "--smoke", action="store_true", help="tiny sizes for CPU CI runs"
    )
    p.add_argument("--steps", type=int, default=None, help="chain steps")
    p.add_argument(
        "--num-chains", type=int, default=1,
        help="independent chains run in one device program",
    )
    # collection axis (DESIGN.md §Collection) — mutually exclusive
    coll = p.add_mutually_exclusive_group()
    coll.add_argument(
        "--thin", type=int, default=None, metavar="K",
        help="keep every K-th absolute step (engine collect='thin:K'); "
        "diagnostics run on the kept stream",
    )
    coll.add_argument(
        "--keep-last", action="store_true",
        help="keep only the final state (engine collect='last'): O(state) "
        "sample memory for any chain length; series diagnostics skipped",
    )
    p.add_argument("--seed", type=int, default=0)
    # lattice knobs (ising / spin_glass)
    p.add_argument("--height", type=int, default=None, help="lattice H")
    p.add_argument("--width", type=int, default=None, help="lattice W")
    p.add_argument("--batch", type=int, default=None, help="lattices")
    p.add_argument("--beta", type=float, default=None, help="ising coupling")
    p.add_argument("--field", type=float, default=0.0, help="external field")
    p.add_argument(
        "--maxcut", action="store_true",
        help="spin_glass: signed MAX-CUT couplings (J = -w); tempered "
        "rows then report best_cut",
    )
    # gmm knobs
    p.add_argument("--nbits", type=int, default=None, help="gmm grid bits")
    p.add_argument("--chains", type=int, default=None, help="gmm chains")
    # tempering (repro/tempering, DESIGN.md §Tempering)
    p.add_argument(
        "--ladder", type=int, default=0, metavar="R",
        help="parallel tempering with R replicas on a geometric ladder",
    )
    p.add_argument(
        "--swap-every", type=int, default=16,
        help="replica-exchange period in engine steps",
    )
    p.add_argument(
        "--anneal", type=int, default=0, metavar="S",
        help="simulated annealing over S geometric cooling stages",
    )
    p.add_argument(
        "--autotune", action="store_true",
        help="replace the hand-chosen chunk_steps/block_c/backend with "
        "the measured per-(workload, shape, device) winner (cached; "
        "DESIGN.md §Run-API)",
    )
    p.add_argument(
        "--autotune-cache", default=None, metavar="PATH",
        help="autotune cache file (default $REPRO_AUTOTUNE_CACHE or "
        "~/.cache/repro/autotune.json)",
    )
    p.add_argument(
        "--beta-min", type=float, default=0.25,
        help="hottest ladder beta / annealing start beta",
    )
    p.add_argument(
        "--beta-max", type=float, default=4.0,
        help="annealing end beta (annealing only; ladders end at 1.0)",
    )
    # telemetry (DESIGN.md §Telemetry)
    p.add_argument(
        "--trace", default=None, metavar="PATH",
        help="record host-side trace spans and export on exit: "
        "*.json/*.trace -> Chrome-trace (chrome://tracing / Perfetto), "
        "anything else -> JSONL (validate/summarize with "
        "python -m repro.launch.monitor)",
    )
    p.add_argument(
        "--metrics", default=None, metavar="PATH",
        help="write the final metrics snapshot: *.prom/*.txt -> "
        "Prometheus exposition text, anything else -> one JSONL line",
    )
    return p


def _export_telemetry(args) -> None:
    if args.trace:
        n = telemetry.TRACER.export(args.trace)
        print(f"[trace] wrote {n} events to {args.trace}")
        telemetry.disable()
    if args.metrics:
        if args.metrics.endswith((".prom", ".txt")):
            with open(args.metrics, "w") as f:
                f.write(telemetry.REGISTRY.prometheus_text())
        else:
            telemetry.REGISTRY.flush_jsonl(args.metrics)
        print(f"[metrics] wrote snapshot to {args.metrics}")


def _collect_arg(args) -> str:
    """The engine collection spec the CLI flags select."""
    if args.thin is not None:
        if args.thin < 1:
            raise SystemExit(f"--thin must be >= 1, got {args.thin}")
        return f"thin:{args.thin}"
    return "last" if args.keep_last else "all"


def _workload_kwargs(args) -> dict:
    """Forward exactly the flags the registered builder accepts — the
    registry, not this module, decides a workload's knobs."""
    candidates = dict(
        randomness=args.randomness,
        backend=args.backend,
        smoke=args.smoke,
        n_steps=args.steps,
        num_chains=args.num_chains,
        collect=_collect_arg(args),
        height=args.height,
        width=args.width,
        batch=args.batch,
        beta=args.beta,
        field=args.field,
        maxcut=args.maxcut,
        nbits=args.nbits,
        chains=args.chains,
    )
    params = inspect.signature(workloads.WORKLOADS[args.workload]).parameters
    return {k: v for k, v in candidates.items() if k in params}


def _rate_key(wl) -> str:
    """The workload owns the canonical rate label (DESIGN.md §2)."""
    return wl.rate_key


def _series_diagnostics(wl, samples) -> dict:
    """Post-burn-in diagnostics of the workload statistic over one
    (solo-shaped) sample block."""
    series = np.asarray(wl.series_fn(samples))
    series = series.reshape(series.shape[0], -1)
    return diagnostics.summarize(series[wl.burn_in:])


def _run_ladder(args, wl, k_run, monitor) -> dict:
    ladder = tempering.Ladder.geometric(args.ladder, beta_min=args.beta_min)
    rex = tempering.ReplicaExchange(
        ladder=ladder, engine=wl.engine, swap_every=args.swap_every
    )
    init = jnp.broadcast_to(
        wl.init_words, (ladder.num_replicas, *wl.init_words.shape)
    )
    t0 = time.time()
    result = rex.run(k_run, wl.target, wl.n_steps, init)
    jax.block_until_ready(result.samples)
    wall_s = time.time() - t0

    site_steps = wl.n_steps * int(init.size)
    diag = _series_diagnostics(wl, result.cold_samples)
    monitor.check_acceptance(
        float(result.acceptance_rate), label=_rate_key(wl), where=wl.name
    )
    monitor.check_swap_stats(result.swap, where=wl.name)
    monitor.check_chain_stats(diag, where=wl.name)
    row = {
        "mode": "ladder",
        "num_replicas": ladder.num_replicas,
        "swap_every": args.swap_every,
        "beta_min": round(min(ladder.betas), 4),
        "n_steps": wl.n_steps,
        "wall_s": round(wall_s, 3),
        "site_steps_per_s": round(site_steps / max(wall_s, 1e-9), 1),
        _rate_key(wl): round(float(result.acceptance_rate), 4),
        **result.swap.summary(),
        # sample quality of the cold (beta = betas[0]) replica; its
        # post-burn-in step count is kept_steps, as in the plain rows
        **{
            ("kept_steps" if k == "n_steps" else k): v
            for k, v in diag.items()
        },
    }
    if getattr(wl.target, "maxcut_reduction", False):
        # best cut the target-measure replica ever visited
        row["best_cut"] = round(
            float(np.asarray(wl.target.cut_value(result.cold_samples)).max()),
            4,
        )
    return row


def _run_anneal(args, wl, k_run, monitor) -> dict:
    annealer = tempering.Annealer.geometric(
        args.anneal,
        max(1, wl.n_steps // args.anneal),
        beta_min=args.beta_min,
        beta_max=args.beta_max,
    )
    t0 = time.time()
    result = annealer.run(k_run, wl.target, wl.init_words, engine=wl.engine)
    jax.block_until_ready(result.best_words)
    wall_s = time.time() - t0

    site_steps = result.n_steps * int(wl.init_words.size)
    best_logp = np.asarray(result.best_logp)
    monitor.check_acceptance(
        float(result.acceptance_rate), label=_rate_key(wl), where=wl.name
    )
    row = {
        "mode": "anneal",
        "stages": args.anneal,
        "beta_min": round(min(annealer.betas), 4),
        "beta_max": round(max(annealer.betas), 4),
        "n_steps": result.n_steps,
        "wall_s": round(wall_s, 3),
        "site_steps_per_s": round(site_steps / max(wall_s, 1e-9), 1),
        _rate_key(wl): round(float(result.acceptance_rate), 4),
        # lattice targets: best_logp is -energy, report the best energy
        "best_energy": round(float(-best_logp.max()), 4),
    }
    if getattr(wl.target, "maxcut_reduction", False):
        row["best_cut"] = round(
            float(np.asarray(wl.target.cut_value(result.best_words)).max()), 4
        )
    return row


def main(argv=None) -> dict:
    parser = build_parser()
    args = parser.parse_args(argv)
    if args.ladder and args.anneal:
        parser.error("--ladder and --anneal are mutually exclusive")
    if (args.ladder or args.anneal) and args.num_chains > 1:
        parser.error(
            "--ladder/--anneal occupy the engine's chain-id axis; batch "
            "the workload (e.g. --batch/--chains) for parallel ensembles"
        )
    if (args.ladder or args.anneal) and (
        args.thin is not None or args.keep_last
    ):
        parser.error(
            "--thin/--keep-last apply to plain runs; the tempering "
            "drivers consume the full segment streams for their own "
            "diagnostics/best-state tracking"
        )
    if args.trace:
        telemetry.enable()
    monitor = telemetry.HealthMonitor(warn=False)
    key = jax.random.PRNGKey(args.seed)
    k_init, k_run = jax.random.split(key)
    wl = workloads.build(args.workload, k_init, **_workload_kwargs(args))

    base = {
        "workload": wl.name,
        "update": wl.engine.config.update,
        "randomness": args.randomness,
        "backend": args.backend,
        "collect": _collect_arg(args),
    }
    if args.autotune:
        wl.engine, tuned = samplers.autotune_engine(
            wl.engine, wl.target, wl.init_words,
            cache_path=args.autotune_cache,
        )
        base["backend"] = tuned.execution
        base["autotune"] = (
            f"chunk{tuned.chunk_steps}:{tuned.execution} ({tuned.source}, "
            f"{tuned.steps_per_s / max(tuned.baseline_steps_per_s, 1e-9):.2f}x"
            " vs incumbent)"
        )
    if args.ladder:
        row = {**base, **_run_ladder(args, wl, k_run, monitor)}
    elif args.anneal:
        row = {**base, **_run_anneal(args, wl, k_run, monitor)}
    else:
        mesh = make_chains_mesh(args.num_chains)
        t0 = time.time()
        result = wl.run(k_run, mesh=mesh)
        jax.block_until_ready(result.samples)
        wall_s = time.time() - t0

        diag = wl.diagnostics(result)
        monitor.check_acceptance(
            float(result.acceptance_rate), label=_rate_key(wl), where=wl.name
        )
        monitor.check_chain_stats(diag, where=wl.name)
        n_sites = int(wl.init_words.size)
        site_steps = wl.n_steps * n_sites
        nbits = int(wl.meta.get("nbits", wl.target.nbits))
        macro_fj = energy.energy_per_sample_fj(
            float(result.acceptance_rate), nbits
        ) * site_steps

        row = {
            **base,
            "n_steps": wl.n_steps,
            "burn_in": wl.burn_in,
            "n_sites": n_sites,
            "wall_s": round(wall_s, 3),
            "site_steps_per_s": round(site_steps / max(wall_s, 1e-9), 1),
            "macro_energy_pj": round(macro_fj * 1e-3, 2),
            **{k: v for k, v in wl.meta.items() if k != "nbits"},
            # diagnostics run on the post-burn-in series; disambiguate
            # its step count from the chain's
            **{
                ("kept_steps" if k == "n_steps" else k): v
                for k, v in diag.items()
            },
        }
    print("  ".join(f"{k}={v}" for k, v in row.items()))
    for alert in monitor.alerts:
        print(f"[health] {alert.severity} {alert.kind}: {alert.message}")
    _export_telemetry(args)
    return row


if __name__ == "__main__":
    main()
