"""Slot-based batched serving with CIM-MCMC token sampling.

A fixed pool of ``--slots`` decode slots shares one KV cache; requests
join free slots (their prompt is prefilled into the slot's cache rows),
decode steps advance *all* active slots in lock-step, finished slots free
up and are refilled from a FIFO overflow queue (``--requests`` may exceed
the pool).  The decode index is per-row, so slots hold prompts of
different lengths.  Tokens are drawn either by the paper's MCMC sampler
(softmax-free — the default, this is the paper's technique in serving
position) or by standard categorical sampling (baseline).

This is the batch-continuous ("continuous batching"-lite) discipline real
LLM servers use, sized down to run on CPU with smoke configs; the decode
step is the same function the dry-run lowers for the 256/512-chip meshes.

``--backend`` selects the MCMC execution substrate (DESIGN.md §2):
``scan`` runs the pure-JAX chain, ``pallas`` routes decode through the
fused MH kernel (compiled on TPU, interpret mode on CPU), ``auto`` picks
by ``jax.default_backend()``.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch granite3_8b --smoke \
      --requests 8 --prompt-len 12 --gen 16 --sampler mcmc --backend scan
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import token_sampler
from repro.models import lm
from repro.serving import FIFOQueue


@dataclasses.dataclass
class ServeConfig:
    n_slots: int = 4
    max_len: int = 128
    gen_tokens: int = 16
    sampler: str = "mcmc"            # mcmc | categorical | greedy
    backend: str = "auto"            # auto | scan | pallas (MCMC execution)
    mcmc_steps: int = 32
    temperature: float = 1.0
    seed: int = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    out_tokens: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_done: float = 0.0


class BatchedServer:
    """One model, n_slots concurrent sequences, lock-step decode."""

    def __init__(self, cfg, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.scfg = serve_cfg
        key = jax.random.PRNGKey(serve_cfg.seed)
        self.vals, _ = lm.init_lm_values(key, cfg)
        self.key = jax.random.fold_in(key, 1)

        self._decode = jax.jit(
            lambda vals, toks, cache: lm.decode_step(vals, cfg, toks, cache)
        )
        self._prefill_len = {}
        self.sampler_cfg = token_sampler.TokenSamplerConfig(
            vocab_size=cfg.vocab_size,
            n_steps=serve_cfg.mcmc_steps,
            temperature=serve_cfg.temperature,
            execution=serve_cfg.backend,
        )
        # slot state; the decode index is per-row (B,) so slots sit at
        # their own positions — heterogeneous prompt lengths pack safely
        # (cache contract: models/lm.py)
        self.cache = lm.init_cache(cfg, serve_cfg.n_slots, serve_cfg.max_len)
        self.cache["index"] = jnp.zeros((serve_cfg.n_slots,), jnp.int32)
        self.slot_req: list[Request | None] = [None] * serve_cfg.n_slots
        self.slot_remaining = np.zeros(serve_cfg.n_slots, dtype=int)
        self.last_tokens = jnp.zeros((serve_cfg.n_slots, 1), jnp.int32)
        self.acceptance: list[float] = []

    # --- request admission ----------------------------------------------------

    def _prefill_slot(self, slot: int, req: Request):
        """Per-slot prefill: runs the prompt through the stack into row ``slot``.

        Production note: on the big mesh this is the batched prefill_32k
        lowering; here slots prefill one-by-one (CPU-sized prompts) via a
        padded single-row batch written into the shared cache at ``slot``.
        """
        cfg = self.cfg
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        row_cache = lm.init_cache(cfg, 1, self.scfg.max_len)
        batch = {"tokens": prompt}
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (1, cfg.n_image_tokens, cfg.image_embed_dim), cfg.compute_dtype
            )
        if cfg.is_encdec:
            batch["frames"] = jnp.zeros(
                (1, cfg.encoder_len, cfg.frame_dim), cfg.compute_dtype
            )
        logits, row_cache = lm.prefill(self.vals, cfg, batch, row_cache)

        # splice the prefilled row into the shared slot cache
        def splice(shared, row):
            return shared.at[:, slot : slot + 1].set(row)

        self.cache["layers"] = jax.tree.map(
            splice, self.cache["layers"], row_cache["layers"]
        )
        # only this slot's decode position moves — other slots keep
        # decoding at their own indices mid-flight
        self.cache["index"] = (
            self.cache["index"].at[slot].set(
                jnp.asarray(row_cache["index"], jnp.int32)
            )
        )
        return logits[0]

    def submit(self, slot: int, req: Request):
        req.t_submit = time.time()
        logits = self._prefill_slot(slot, req)
        self.slot_req[slot] = req
        self.slot_remaining[slot] = self.scfg.gen_tokens
        first = self._sample(logits[None, :])[0]
        req.out_tokens.append(int(first))
        self.last_tokens = self.last_tokens.at[slot, 0].set(int(first))

    # --- sampling ---------------------------------------------------------------

    def _sample(self, logits):
        self.key, sub = jax.random.split(self.key)
        v = self.cfg.vocab_size
        if self.scfg.sampler == "greedy":
            return jnp.argmax(logits[:, :v], axis=-1).astype(jnp.int32)
        if self.scfg.sampler == "categorical":
            return jax.random.categorical(
                sub, logits[:, :v] / self.scfg.temperature, axis=-1
            ).astype(jnp.int32)
        result = token_sampler._sample_tokens_impl(
            sub, logits[:, :v], self.sampler_cfg
        )
        self.acceptance.append(float(result.acceptance_rate))
        return result.tokens

    # --- decode loop ------------------------------------------------------------

    def step(self) -> list[Request]:
        """One lock-step decode across all active slots; finished
        requests free their slot and are returned (continuous batching:
        the caller refills freed slots from its overflow queue)."""
        logits, self.cache = self._decode(self.vals, self.last_tokens, self.cache)
        tokens = self._sample(logits)
        done = []
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = int(tokens[slot])
            req.out_tokens.append(tok)
            self.slot_remaining[slot] -= 1
            if self.slot_remaining[slot] == 0:
                req.t_done = time.time()
                self.slot_req[slot] = None
                done.append(req)
        self.last_tokens = tokens[:, None]
        return done

    def free_slot(self) -> int | None:
        """Lowest free slot index, or None when the pool is full."""
        for slot, req in enumerate(self.slot_req):
            if req is None:
                return slot
        return None

    def active(self) -> int:
        return sum(req is not None for req in self.slot_req)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument(
        "--slots", type=int, default=None,
        help="decode slot pool size (default min(requests, 4)); overflow "
        "requests wait in a FIFO and join as slots free up",
    )
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sampler", default="mcmc", choices=["mcmc", "categorical", "greedy"])
    ap.add_argument(
        "--backend",
        default="auto",
        choices=["auto", "scan", "pallas"],
        help="MCMC execution backend: pure-JAX scan or the fused Pallas "
        "kernel (interpret mode off-TPU); auto dispatches on "
        "jax.default_backend()",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (
        configs.get_smoke_config(args.arch)
        if args.smoke
        else configs.get_config(args.arch)
    )
    n_slots = args.slots if args.slots is not None else min(args.requests, 4)
    scfg = ServeConfig(
        n_slots=n_slots,
        # prompts jitter up to +2 tokens below; size the cache for the max
        max_len=args.prompt_len + 2 + args.gen + 8,
        gen_tokens=args.gen,
        sampler=args.sampler,
        backend=args.backend,
        seed=args.seed,
    )
    server = BatchedServer(cfg, scfg)
    rng = np.random.default_rng(args.seed)
    # heterogeneous prompt lengths — the per-row decode index packs them
    queue = FIFOQueue()
    for rid in range(args.requests):
        plen = args.prompt_len + (rid % 3)
        prompt = rng.integers(0, cfg.vocab_size, size=plen)
        queue.push(Request(rid=rid, prompt=prompt))
    finished: list[Request] = []
    t0 = time.time()
    while queue or server.active():
        while queue:
            slot = server.free_slot()
            if slot is None:
                break
            server.submit(slot, queue.pop_ready())
        finished.extend(server.step())
    dt = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in finished)
    backend_note = f", backend={args.backend}" if args.sampler == "mcmc" else ""
    print(
        f"[serve] {args.requests} requests x {args.gen} tokens on "
        f"{n_slots} slots ({args.sampler}{backend_note}): {total_tokens} "
        f"tokens in {dt:.2f}s ({total_tokens / dt:.1f} tok/s)"
    )
    if server.acceptance:
        print(f"[serve] MCMC acceptance rate: {np.mean(server.acceptance):.3f}")
    for r in finished:
        print(f"  req {r.rid}: {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
