"""Serve concurrent MCMC sampling requests on the packed chain engine.

The serving face of the sampler (DESIGN.md §Serving): heterogeneous
requests — each a (workload, n_steps, seed, collect) tuple — are packed
into the chain axis of one engine program by ``repro.serving``.
Admission and retirement happen between ``chunk_steps`` segments via the
engine's ``step0`` resume axis, so every request's sample stream is
bit-identical to its solo ``launch.sample``-style run no matter when it
joined or who shared the batch.

Requests come from a JSONL spec (one object per line with any of
``rid / workload / n_steps / seed / collect / t_arrive``) or from a
synthetic Poisson arrival generator (``--poisson-rate`` arrivals/s,
seeds 0..N-1).  Arrival gaps are fast-forwarded by default; pass
``--realtime`` to sleep through them.

``--workload`` takes a comma-separated list for a mixed burst
(round-robin assignment): under scan execution every uint32 workload
shares ONE compiled shape-class program; under pallas each workload
geometry gets one packed kernel grid over all its slots.  ``--mesh``
shards the slot axis over all addressable devices (scan only).

Usage:
  PYTHONPATH=src python -m repro.launch.serve_engine --smoke \
      --requests 6 --slots 3 --poisson-rate 50
  PYTHONPATH=src python -m repro.launch.serve_engine --smoke \
      --workload gmm --requests 8 --slots 4 --randomness fused \
      --collect thin:4
  PYTHONPATH=src python -m repro.launch.serve_engine --smoke \
      --workload ising,gmm --backend pallas --randomness fused \
      --slots 4 --requests 6
  PYTHONPATH=src python -m repro.launch.serve_engine --spec requests.jsonl

Per-request lines report wait/latency and the accept (MH) or flip
(Gibbs) rate; the footer is the ``latency_summary`` row (requests/s,
p50/p99 latency) that ``benchmarks.bench_serving`` tables.
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro import telemetry, workloads
from repro.serving import Scheduler, ServeRequest, latency_summary


def _workload_list(value: str) -> list[str]:
    names = [w.strip() for w in value.split(",") if w.strip()]
    if not names:
        raise argparse.ArgumentTypeError("empty workload list")
    for name in names:
        if name not in workloads.WORKLOADS:
            raise argparse.ArgumentTypeError(
                f"unknown workload {name!r} (choices: "
                f"{', '.join(sorted(workloads.WORKLOADS))})"
            )
    return names


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.launch.serve_engine",
        description="Serve sampling requests packed into one engine program.",
    )
    p.add_argument(
        "--workload", default=["ising"], type=_workload_list,
        help="workload for synthetic requests, or a comma-separated list "
        "(round-robin assignment) for a mixed burst; JSONL specs name "
        "their own.  Choices: " + ", ".join(sorted(workloads.WORKLOADS)),
    )
    p.add_argument(
        "--randomness", default="cim", choices=("host", "cim", "fused")
    )
    p.add_argument(
        "--backend", default="scan", choices=("auto", "scan", "pallas"),
        help="engine execution: scan packs every uint32 workload into ONE "
        "vmapped shape-class program (per-slot lax.switch dispatch, "
        "traced step0); pallas folds all slots into one batched "
        "fused-kernel grid per workload geometry (per-slot operand step0)",
    )
    p.add_argument(
        "--mesh", action="store_true",
        help="shard the slot axis over all addressable devices through "
        "the 'chains' sharding rule (scan backend only; no-op on a "
        "single device)",
    )
    p.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    p.add_argument("--slots", type=int, default=4, help="packed slot pool")
    p.add_argument(
        "--requests", type=int, default=8,
        help="synthetic request count (overflow waits in the FIFO)",
    )
    p.add_argument(
        "--steps", type=int, default=None,
        help="steps per synthetic request (default: workload default)",
    )
    p.add_argument(
        "--collect", default="last",
        help="collection mode for synthetic requests: all | thin:<k> | "
        "last (the serving default — O(state) memory)",
    )
    p.add_argument(
        "--chunk-steps", type=int, default=None,
        help="admission/retirement granularity (default: engine chunk)",
    )
    p.add_argument(
        "--autotune", action="store_true",
        help="measure chunk_steps for the workload template before "
        "serving (samplers.autotune; cached per workload/shape/device)",
    )
    p.add_argument(
        "--autotune-cache", default=None, metavar="PATH",
        help="autotune cache file (default: $REPRO_AUTOTUNE_CACHE or "
        "~/.cache/repro/autotune.json)",
    )
    p.add_argument(
        "--poisson-rate", type=float, default=0.0,
        help="mean synthetic arrivals/s (0 = all requests arrive at t=0)",
    )
    p.add_argument(
        "--spec", default=None, metavar="PATH",
        help="JSONL request spec; overrides the synthetic generator",
    )
    p.add_argument(
        "--realtime", action="store_true",
        help="sleep through arrival gaps instead of fast-forwarding",
    )
    p.add_argument("--seed", type=int, default=0, help="arrival-process seed")
    # telemetry + SLO health (DESIGN.md §Telemetry)
    p.add_argument(
        "--trace", default=None, metavar="PATH",
        help="record host-side trace spans and export on exit "
        "(*.json/*.trace -> Chrome-trace, else JSONL)",
    )
    p.add_argument(
        "--metrics", default=None, metavar="PATH",
        help="flush metrics snapshots: *.prom/*.txt -> final Prometheus "
        "text, anything else -> periodic JSONL lines from the serve loop",
    )
    p.add_argument(
        "--metrics-interval", type=float, default=5.0,
        help="seconds between periodic JSONL metrics flushes",
    )
    p.add_argument(
        "--slo-p99", type=float, default=None, metavar="SECONDS",
        help="p99 end-to-end latency SLO; breach prints a [health] line",
    )
    p.add_argument(
        "--slo-wait", type=float, default=None, metavar="SECONDS",
        help="p99 queue-wait SLO; breach prints a [health] line",
    )
    return p


def load_spec(path: str) -> list[ServeRequest]:
    """Requests from a JSONL file, one object per line; missing fields
    take the ``ServeRequest`` defaults, ``rid`` defaults to the line
    number."""
    requests = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            obj.setdefault("rid", i)
            requests.append(ServeRequest(**obj))
    return requests


def poisson_requests(args) -> list[ServeRequest]:
    """N synthetic requests with Poisson arrivals (exponential gaps at
    ``--poisson-rate``; rate 0 = a burst at t=0) and seeds 0..N-1."""
    rng = np.random.default_rng(args.seed)
    t = 0.0
    requests = []
    names = args.workload
    for rid in range(args.requests):
        if args.poisson_rate > 0:
            t += float(rng.exponential(1.0 / args.poisson_rate))
        requests.append(
            ServeRequest(
                rid=rid,
                workload=names[rid % len(names)],  # round-robin mixed burst
                n_steps=args.steps,
                seed=rid,
                collect=args.collect,
                t_arrive=t,
            )
        )
    return requests


def main(argv=None) -> dict:
    args = build_parser().parse_args(argv)
    requests = (
        load_spec(args.spec) if args.spec else poisson_requests(args)
    )
    chunk_steps = args.chunk_steps
    if args.autotune and chunk_steps is None:
        # tune the segment granularity on the workload template (the
        # executor group's engine/target pair); execution stays as the
        # --backend pin — the serving tier's pack-vs-solo dispatch is
        # chosen there, not by throughput alone
        import jax

        from repro import samplers

        wl = workloads.build(
            args.workload[0], jax.random.PRNGKey(0),
            randomness=args.randomness, smoke=args.smoke,
        )
        cfg = wl.engine.config
        if args.backend in ("scan", "pallas"):
            import dataclasses

            cfg = dataclasses.replace(cfg, execution=args.backend)
        _, tuned = samplers.autotune_config(
            cfg, wl.target, wl.init_words, cache_path=args.autotune_cache
        )
        chunk_steps = tuned.chunk_steps
        print(
            f"[serve_engine] autotune: chunk_steps={chunk_steps} "
            f"({tuned.source}, {tuned.steps_per_s:.3g} site-steps/s vs "
            f"incumbent {tuned.baseline_steps_per_s:.3g})"
        )
    if args.trace:
        telemetry.enable()
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_chains_mesh

        mesh = make_chains_mesh()
        if mesh is None:
            print("[serve_engine] --mesh: single device, serving unsharded")
    sched = Scheduler(
        n_slots=args.slots,
        randomness=args.randomness,
        execution=args.backend,
        smoke=args.smoke,
        chunk_steps=chunk_steps,
        mesh=mesh,
    )
    if args.metrics and not args.metrics.endswith((".prom", ".txt")):
        sched.metrics_flusher = telemetry.JsonlFlusher(
            telemetry.REGISTRY, args.metrics,
            interval_s=args.metrics_interval,
        )
    done = sched.serve(requests, realtime=args.realtime)
    for r in sorted(done, key=lambda r: r.rid):
        n_kept = 0 if r.samples is None else r.samples.shape[0]
        print(
            f"  req {r.rid}: workload={r.workload} steps="
            f"{r.n_steps or 'default'} collect={r.collect} kept={n_kept} "
            f"wait_s={r.wait_s:.3f} service_s={r.service_s:.3f} "
            f"latency_s={r.latency_s:.3f} "
            f"{r.rate_label}={r.acceptance_rate:.4f}"
        )
    summary = latency_summary(done)
    row = {
        "slots": args.slots,
        "randomness": args.randomness,
        "backend": args.backend,
        "shape_classes": sched.shape_classes,
        "compiled_programs": sched.compiled_programs,
        **summary,
    }
    print("[serve_engine] " + "  ".join(f"{k}={v}" for k, v in row.items()))
    monitor = telemetry.HealthMonitor(
        telemetry.HealthThresholds(
            p99_latency_slo_s=args.slo_p99, max_wait_slo_s=args.slo_wait
        ),
        warn=False,
    )
    monitor.check_serving(summary, where=",".join(args.workload))
    for alert in monitor.alerts:
        print(f"[health] {alert.severity} {alert.kind}: {alert.message}")
    if args.trace:
        n = telemetry.TRACER.export(args.trace)
        print(f"[trace] wrote {n} events to {args.trace}")
        telemetry.disable()
    if args.metrics:
        if args.metrics.endswith((".prom", ".txt")):
            with open(args.metrics, "w") as f:
                f.write(telemetry.REGISTRY.prometheus_text())
        else:
            sched.metrics_flusher.close()
        print(f"[metrics] wrote snapshot to {args.metrics}")
    return row


if __name__ == "__main__":
    main()
