import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import (jax locks the device
count on first init).  512 placeholder host devices back both the
single-pod (16,16) and the multi-pod (2,16,16) production meshes.

Per cell this produces, into ``artifacts/dryrun/<mesh>/<arch>__<shape>.json``:
  * ``memory_analysis``  — per-device argument/output/temp bytes (fits proof)
  * ``cost_analysis``    — HLO FLOPs + bytes accessed (roofline terms 1+2)
  * ``collectives``      — per-kind collective operand bytes parsed from the
                           post-SPMD compiled HLO (roofline term 3)
  * compile wall time, shardings summary, skip reasons.

Usage:
  python -m repro.launch.dryrun --all                      # 40 cells, 1 pod
  python -m repro.launch.dryrun --all --multi-pod          # 40 cells, 2 pods
  python -m repro.launch.dryrun --arch granite_34b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3_moe_30b --shape train_4k \
      --mesh 32x8 --tag perf_iter1       # §Perf hillclimb variants
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.distributed.hlo_analysis import collective_bytes
from repro.distributed.hlo_cost import analyze_hlo
from repro.distributed.sharding import (
    rules_for_config,
    rules_with_zero,
    spec_for,
    tree_specs,
    use_rules,
)
from repro.launch.mesh import alt_mesh, make_production_mesh, mesh_chip_count
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init, opt_state_axes
from repro.training.step import TrainStepConfig, make_train_step, make_decode_step, make_prefill_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")


def _save_hlo(hlo: str, cfg, shape_name: str, mesh_tag: str, variant: str) -> str:
    import gzip

    d = os.path.join(ARTIFACT_DIR, mesh_tag, "hlo")
    os.makedirs(d, exist_ok=True)
    name = f"{cfg.name.replace('/', '_')}__{shape_name}"
    if variant != "baseline":
        name += f"__{variant}"
    path = os.path.join(d, name + ".hlo.gz")
    with gzip.open(path, "wt") as f:
        f.write(hlo)
    return path


def _named(mesh, spec):
    from jax.sharding import NamedSharding

    return NamedSharding(mesh, spec if spec is not None else jax.sharding.PartitionSpec())


def _tree_shardings(mesh, axes_tree, shapes_tree, rules):
    specs = tree_specs(axes_tree, rules, shapes_tree=shapes_tree, mesh=mesh)
    return jax.tree.map(lambda s: _named(mesh, s), specs)


def _batch_shardings(mesh, cfg, batch, rules):
    logical = {
        "tokens": ("batch", "seq"),
        "labels": ("batch", "seq"),
        "image_embeds": ("batch", "seq", None),
        "frames": ("batch", "seq", None),
    }
    return {
        k: _named(mesh, spec_for(logical[k], rules, shape=v.shape, mesh=mesh))
        for k, v in batch.items()
    }


def lower_cell(
    cfg,
    shape_name: str,
    mesh,
    *,
    variant: str = "baseline",
    compress_pods: bool = False,
    decode_sample: bool = False,
):
    """Lower+compile one cell; returns the artifact dict."""
    shape = configs.SHAPES[shape_name]
    rules = rules_for_config(cfg)
    report: dict = {
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.axis_sizes),
        "mesh_axes": list(mesh.axis_names),
        "chips": mesh_chip_count(mesh),
        "variant": variant,
        "kind": shape.kind,
        "param_count": cfg.param_count(),
        "param_count_active": cfg.param_count(active_only=True),
    }

    with jax.set_mesh(mesh):
        with use_rules(rules):
            params_shapes, axes_tree = lm.abstract_params(cfg)
            params_sh = _tree_shardings(mesh, axes_tree, params_shapes, rules)
            batch = configs.batch_specs(cfg, shape)
            batch_sh = _batch_shardings(mesh, cfg, batch, rules)

            t0 = time.time()
            if shape.kind == "train":
                opt_cfg = AdamWConfig()
                opt_shapes = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_shapes)
                opt_axes = opt_state_axes(params_shapes, axes_tree, opt_cfg)
                opt_sh = _tree_shardings(
                    mesh, opt_axes, opt_shapes, rules_with_zero(rules)
                )
                step_cfg = TrainStepConfig(
                    n_micro=cfg.train_microbatches, compress_pods=compress_pods
                )
                step = make_train_step(
                    cfg, axes_tree, opt_cfg, step_cfg=step_cfg, mesh=mesh
                )
                if compress_pods:
                    err_shapes = jax.tree.map(
                        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                        params_shapes,
                    )
                    jitted = jax.jit(
                        step,
                        in_shardings=(params_sh, opt_sh, batch_sh, params_sh),
                    )
                    lowered = jitted.lower(params_shapes, opt_shapes, batch, err_shapes)
                else:
                    jitted = jax.jit(
                        step, in_shardings=(params_sh, opt_sh, batch_sh)
                    )
                    lowered = jitted.lower(params_shapes, opt_shapes, batch)
            else:
                cache = configs.cache_specs(cfg, shape)
                cache_axes = lm.cache_axes(cfg)
                cache_sh = _tree_shardings(mesh, cache_axes, cache, rules)
                if shape.kind == "prefill":
                    step = make_prefill_step(cfg)
                    jitted = jax.jit(
                        step,
                        in_shardings=(params_sh, batch_sh, cache_sh),
                        donate_argnums=(2,),
                    )
                    lowered = jitted.lower(params_shapes, batch, cache)
                elif decode_sample:
                    # the paper's technique fused into the decode step
                    from repro.training.step import make_decode_sample_step

                    step = make_decode_sample_step(cfg)
                    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
                    jitted = jax.jit(
                        step,
                        in_shardings=(
                            params_sh,
                            batch_sh["tokens"],
                            cache_sh,
                            _named(mesh, None),
                        ),
                        donate_argnums=(2,),
                    )
                    lowered = jitted.lower(
                        params_shapes, batch["tokens"], cache, key_spec
                    )
                else:  # decode
                    step = make_decode_step(cfg)
                    jitted = jax.jit(
                        step,
                        in_shardings=(params_sh, batch_sh["tokens"], cache_sh),
                        donate_argnums=(2,),
                    )
                    lowered = jitted.lower(params_shapes, batch["tokens"], cache)
            t_lower = time.time() - t0

            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        report["memory_analysis"] = {
            "argument_size_bytes": mem.argument_size_in_bytes,
            "output_size_bytes": mem.output_size_in_bytes,
            "temp_size_bytes": mem.temp_size_in_bytes,
            "alias_size_bytes": mem.alias_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        }
        cost = compiled.cost_analysis() or {}
        report["cost_analysis"] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        }
        hlo = compiled.as_text()
        report["collectives"] = collective_bytes(hlo)  # body-once (reference)
        # loop-aware costs: while bodies multiplied by known trip counts —
        # XLA's cost_analysis counts scan bodies once, useless for scanned
        # layers (see repro.distributed.hlo_cost)
        report["hlo_cost"] = analyze_hlo(hlo)
        report["hlo_bytes"] = len(hlo)
        report["hlo_gz"] = _save_hlo(hlo, cfg, shape_name, report["mesh"], variant)
        report["lower_s"] = round(t_lower, 2)
        report["compile_s"] = round(t_compile, 2)
        report["status"] = "ok"
        print(
            f"[dryrun] {cfg.name} x {shape_name} x {report['mesh']} "
            f"({variant}): OK  compile={t_compile:.1f}s "
            f"flops={report['cost_analysis']['flops']:.3e} "
            f"coll={report['collectives'].get('total', 0):.3e}B"
        )
        print(f"  memory_analysis: {mem}")           # proves it fits
        print(f"  cost_analysis: flops={report['cost_analysis']['flops']:.4e} "
              f"bytes={report['cost_analysis']['bytes_accessed']:.4e} "
              f"(body-once; loop-aware: flops={report['hlo_cost']['flops']:.4e} "
              f"bytes={report['hlo_cost']['bytes']:.4e} "
              f"coll={report['hlo_cost']['collectives'].get('total', 0):.4e})")
    return report


def run_cell(arch: str, shape_name: str, mesh, variant="baseline", cfg=None, **kw):
    cfg = cfg or configs.get_config(arch)
    shape = configs.SHAPES[shape_name]
    ok, reason = configs.shape_applicable(cfg, shape)
    if not ok:
        print(f"[dryrun] {arch} x {shape_name}: SKIP ({reason})")
        return {
            "arch": cfg.name,
            "shape": shape_name,
            "variant": variant,
            "status": "skipped",
            "reason": reason,
        }
    try:
        return lower_cell(cfg, shape_name, mesh, variant=variant, **kw)
    except Exception as e:  # a failing cell is a bug — surface it loudly
        traceback.print_exc()
        return {
            "arch": cfg.name,
            "shape": shape_name,
            "variant": variant,
            "status": "failed",
            "error": f"{type(e).__name__}: {e}",
        }


def save_report(report: dict, mesh_tag: str, tag: str | None = None):
    d = os.path.join(ARTIFACT_DIR, mesh_tag)
    os.makedirs(d, exist_ok=True)
    arch = report["arch"].replace("/", "_")
    name = f"{arch}__{report['shape']}"
    if tag:
        name += f"__{tag}"
    path = os.path.join(d, name + ".json")
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="arch id (see repro.configs.ARCH_IDS)")
    ap.add_argument("--shape", help="shape name", choices=list(configs.SHAPES))
    ap.add_argument("--all", action="store_true", help="run every assigned cell")
    ap.add_argument("--multi-pod", action="store_true", help="use the (2,16,16) mesh")
    ap.add_argument("--mesh", help="override mesh as DATAxMODEL, e.g. 32x8")
    ap.add_argument("--compress-pods", action="store_true")
    ap.add_argument("--tag", help="artifact filename suffix (perf iterations)")
    ap.add_argument("--seq-shard", action="store_true", help="enable SP override")
    # §Perf hillclimb levers
    ap.add_argument("--n-micro", type=int, help="override train microbatches")
    ap.add_argument("--capacity-factor", type=float, help="MoE capacity factor")
    ap.add_argument("--cache-dtype", help="decode cache dtype (e.g. float8_e4m3fn)")
    ap.add_argument("--remat", help="remat policy: nothing|dots|none")
    ap.add_argument("--attn-causal-skip", action="store_true")
    ap.add_argument("--logits-chunk", type=int)
    ap.add_argument("--decode-sample", action="store_true",
                    help="lower the MCMC-sampling decode step")
    args = ap.parse_args()

    if args.mesh:
        data, model = (int(x) for x in args.mesh.split("x"))
        mesh = alt_mesh(data, model, pods=2 if args.multi_pod else 1)
        mesh_tag = ("pod2_" if args.multi_pod else "") + args.mesh
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        mesh_tag = "pod2_16x16" if args.multi_pod else "16x16"

    cells = (
        [(a, s) for a, s, _, _ in configs.assigned_cells()]
        if args.all
        else [(args.arch, args.shape)]
    )
    n_ok = n_skip = n_fail = 0
    for arch, shape in cells:
        cfg = configs.get_config(arch)
        patch = {}
        if args.seq_shard:
            patch["seq_shard"] = True
        if args.n_micro:
            patch["train_microbatches"] = args.n_micro
        if args.capacity_factor:
            patch["moe_capacity_factor"] = args.capacity_factor
        if args.cache_dtype:
            patch["cache_dtype_str"] = args.cache_dtype
        if args.remat:
            patch["remat_policy"] = args.remat
        if args.attn_causal_skip:
            patch["attn_causal_skip"] = True
        if args.logits_chunk:
            patch["logits_chunk"] = args.logits_chunk
        if patch:
            cfg = dataclasses.replace(cfg, **patch)
        report = run_cell(
            arch, shape, mesh,
            variant=args.tag or "baseline",
            cfg=cfg,
            compress_pods=args.compress_pods,
            decode_sample=args.decode_sample,
        )
        save_report(report, mesh_tag, tag=args.tag)
        n_ok += report["status"] == "ok"
        n_skip += report["status"] == "skipped"
        n_fail += report["status"] == "failed"
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
