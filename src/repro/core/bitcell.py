"""Behavioral model of 6T-SRAM bitcell stochasticity under "pseudo-read".

The paper (§3.1, Fig. 4) lowers the bitcell supply CVDD while holding both
bitlines high, collapsing the static noise margin so thermal noise flips the
stored bit with a controllable probability ("bit flip rate", BFR).  Anchor
points taken from the paper:

  * normal read at CVDD = 0.8 V: BFR ~ 0 (stable storage),
  * pseudo-read at CVDD = 0.6 V: BFR ~ 40 %  (§4.2: "p_BFR >= 0.4
    corresponding to the case of CVDD is disturbed from 0.5V to 0.6V"),
  * pseudo-read at CVDD = 0.5 V: BFR ~ 45 %  (§3.1),
  * CVDD -> DRV: BFR -> 50 % (pure thermal noise).

Fig. 15 temperature dependence at CVDD = 0.5 V: ~45 % flat over 0-70 C,
mild decrease below -20 C (less thermal noise), mild increase toward 85 C.

The exact analogue curve is foundry-confidential; we reproduce it as a
monotone piecewise-linear interpolation through digitized anchors, which is
sufficient for every downstream system property (all of which depend only on
p_BFR being a known value in (0, 0.5]).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# --- digitized anchors from paper figures -------------------------------

# (CVDD [V], BFR) at nominal 25 C, pseudo-read conditions (Fig. 4(c)).
_BFR_VS_CVDD = np.array(
    [
        (0.30, 0.499),
        (0.40, 0.490),
        (0.45, 0.475),
        (0.50, 0.450),
        (0.55, 0.425),
        (0.60, 0.400),
        (0.65, 0.300),
        (0.70, 0.150),
        (0.75, 0.030),
        (0.80, 0.001),
    ]
)

# (temperature [C], BFR) at CVDD = 0.5 V (Fig. 15).
_BFR_VS_TEMP = np.array(
    [
        (-40.0, 0.360),
        (-20.0, 0.420),
        (0.0, 0.440),
        (25.0, 0.450),
        (70.0, 0.455),
        (85.0, 0.460),
    ]
)

NOMINAL_CVDD = 0.8  # V, standard bitcell supply
PSEUDO_READ_CVDD = 0.5  # V, the paper's operating point
NOMINAL_TEMP_C = 25.0


def bfr_vs_cvdd(cvdd) -> jnp.ndarray:
    """Bit flip rate of a pseudo-read at supply ``cvdd`` volts (25 C)."""
    cvdd = jnp.asarray(cvdd)
    return jnp.interp(
        cvdd,
        jnp.asarray(_BFR_VS_CVDD[:, 0]),
        jnp.asarray(_BFR_VS_CVDD[:, 1]),
        left=0.5,
        right=0.0,
    )


def temperature_factor(temp_c) -> jnp.ndarray:
    """Multiplicative thermal factor, normalised to 1.0 at 25 C."""
    temp_c = jnp.asarray(temp_c)
    base = jnp.interp(
        jnp.asarray(NOMINAL_TEMP_C),
        jnp.asarray(_BFR_VS_TEMP[:, 0]),
        jnp.asarray(_BFR_VS_TEMP[:, 1]),
    )
    cur = jnp.interp(
        temp_c,
        jnp.asarray(_BFR_VS_TEMP[:, 0]),
        jnp.asarray(_BFR_VS_TEMP[:, 1]),
        left=float(_BFR_VS_TEMP[0, 1]),
        right=float(_BFR_VS_TEMP[-1, 1]),
    )
    return cur / base


def bit_flip_rate(cvdd=PSEUDO_READ_CVDD, temp_c=NOMINAL_TEMP_C) -> jnp.ndarray:
    """p_BFR(CVDD, T) — clipped to the physically meaningful [0, 0.5]."""
    p = bfr_vs_cvdd(cvdd) * temperature_factor(temp_c)
    return jnp.clip(p, 0.0, 0.5)


@dataclasses.dataclass(frozen=True)
class BitcellConfig:
    """Operating condition of the bitcell sub-array during pseudo-read."""

    cvdd: float = PSEUDO_READ_CVDD
    temp_c: float = NOMINAL_TEMP_C

    @property
    def p_bfr(self) -> float:
        return float(bit_flip_rate(self.cvdd, self.temp_c))


# --- pseudo-read operations ----------------------------------------------


@partial(jax.jit, static_argnames=("shape",))
def pseudo_read_flip(key, stored_bits: jnp.ndarray, p_bfr, *, shape=None):
    """Block-wise RNG pseudo-read: every selected bit flips w.p. ``p_bfr``.

    This is the proposal generator (paper §3.2): applied to the bitcells that
    hold the current sample x^(i), it yields the candidate x*.  The flip
    events are i.i.d. per bit, giving the symmetric transfer matrix
    q(y|x) = p^d(x,y) (1-p)^(k-d).
    """
    del shape
    flips = jax.random.bernoulli(key, p_bfr, stored_bits.shape)
    return jnp.bitwise_xor(stored_bits.astype(jnp.uint8), flips.astype(jnp.uint8))


@partial(jax.jit, static_argnames=("shape",))
def pseudo_read_fresh(key, p_bfr, *, shape):
    """Reset-then-pseudo-read (paper §4.2 step 1+2): bits ~ Bernoulli(p_bfr).

    The accurate-[0,1]-RNG module first flushes its bitcells to "0" so that
    lambda_0 = p_BFR <= 0.5 is guaranteed (required by the MSXOR convergence
    proof, paper Appendix A note).
    """
    return jax.random.bernoulli(key, p_bfr, shape).astype(jnp.uint8)


def raw_random_words(key, p_bfr, shape, nbits: int = 32) -> jnp.ndarray:
    """Biased random *words*: each of ``nbits`` bit-planes ~ Bernoulli(p_bfr).

    Packs pseudo-read bits into uint32 words so the MSXOR kernels can debias
    32 independent bit-streams per lane-op.  Bit i of the output word is an
    independent Bernoulli(p_bfr) draw.
    """
    if not (0 < nbits <= 32):
        raise ValueError(f"nbits must be in (0, 32], got {nbits}")
    bits = jax.random.bernoulli(key, p_bfr, (*shape, nbits))
    weights = (jnp.uint32(1) << jnp.arange(nbits, dtype=jnp.uint32)).astype(jnp.uint32)
    return jnp.sum(bits.astype(jnp.uint32) * weights, axis=-1).astype(jnp.uint32)
