"""Vectorised Metropolis–Hastings — paper Algorithm 1 + §3.2.

The chain state is a block of k-bit integer words, one word per compartment
(the paper's macro runs 64 compartments in lock-step; here the compartment
axis is an arbitrary batch shape).  Each step:

  1. candidate = pseudo-read bit-flip of the current word  (block-wise RNG)
  2. u ~ accurate [0,1] RNG                                 (MSXOR-debiased)
  3. accept iff u < min(1, p(x*) / p(x)) — q cancels by symmetry (paper §3.2)
  4. "in-memory copy": accepted candidates overwrite the state; rejected
     compartments re-copy the previous value (costed in the energy model)

This module is a thin, API-compatible wrapper over the unified sampler
engine (``repro.samplers``); the step body lives there exactly once
(DESIGN.md §2).

Note: paper §4.2 contains the typo "if p(x^(i)) > u * p(x*) ... accept"; we
implement the correct test from the paper's own Algorithm 1
(u < p(x*)/p(x^(i))), see DESIGN.md §1.
"""

from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro import samplers

Array = jnp.ndarray
LogProbFn = Callable[[Array], Array]


@dataclasses.dataclass(frozen=True)
class MHConfig:
    nbits: int = 4                    # sample precision (paper: 4..32, up to 64)
    p_bfr: float = 0.45               # proposal bit-flip rate (pseudo-read)
    rng_p_bfr: float = 0.45           # [0,1]-RNG raw-bit bias
    rng_stages: int = 3               # MSXOR stages
    rng_bit_width: int = 16           # u precision (>=8; 16 tightens the
                                      # accept test for peaked targets)
    burn_in: int = 500                # paper §2.1: empirical 500-1000
    thin: int = 1
    randomness: str = "cim"           # host | cim randomness backend
    chunk_steps: int = 64             # randomness streaming granularity

    def __post_init__(self):
        if not 1 <= self.nbits <= 32:
            raise ValueError(f"nbits must be in [1,32], got {self.nbits}")

    def engine_config(self) -> samplers.EngineConfig:
        return samplers.EngineConfig(
            p_bfr=self.p_bfr,
            randomness=self.randomness,
            rng_p_bfr=self.rng_p_bfr,
            rng_bit_width=self.rng_bit_width,
            rng_stages=self.rng_stages,
            execution="scan",          # callable targets: no table for pallas
            chunk_steps=self.chunk_steps,
        )


class MHStepState(NamedTuple):
    words: Array          # (...,) uint32 current samples
    log_prob: Array       # (...,) float32 cached log p(x)
    accept_count: Array   # (...,) int32


class MHResult(NamedTuple):
    samples: Array        # (n_kept, ...) uint32
    final: MHStepState
    n_steps: jnp.int32
    acceptance_rate: Array  # scalar float32


@partial(
    jax.jit,
    static_argnames=("log_prob_fn", "n_samples", "cfg", "chain_shape"),
)
def _run_chain_impl(
    key,
    log_prob_fn: LogProbFn,
    cfg: MHConfig,
    n_samples: int,
    chain_shape: tuple = (),
    init_words: Array | None = None,
) -> MHResult:
    if init_words is None:
        k_init, key = jax.random.split(key)
        init_words = jax.random.randint(
            k_init, chain_shape, 0, 1 << cfg.nbits, dtype=jnp.uint32
        )
    else:
        init_words = jnp.broadcast_to(init_words, chain_shape).astype(jnp.uint32)

    n_steps = cfg.burn_in + n_samples * cfg.thin
    engine = samplers.MHEngine(cfg.engine_config())
    target = samplers.CallableTarget(log_prob_fn, cfg.nbits)
    res = engine.submit(
        samplers.RunPlan(
            target=target, n_steps=n_steps, init_words=init_words, key=key
        )
    ).result

    kept = res.samples[cfg.burn_in :]
    if cfg.thin > 1:
        kept = kept[cfg.thin - 1 :: cfg.thin]

    return MHResult(
        samples=kept,
        final=MHStepState(
            words=res.final_words,
            log_prob=res.final_logp,
            accept_count=res.accept_count,
        ),
        n_steps=jnp.int32(n_steps),
        acceptance_rate=res.acceptance_rate,
    )


def run_chain(
    key,
    log_prob_fn: LogProbFn,
    cfg: MHConfig,
    n_samples: int,
    chain_shape: tuple = (),
    init_words: Array | None = None,
) -> MHResult:
    """Run MH and keep ``n_samples`` post-burn-in (thinned) states per chain.

    Total iterations = burn_in + n_samples * thin.  Samples are the *chain
    states* after each kept step (MH output convention: a rejected step
    re-emits the previous value — exactly the macro's re-copy behaviour).

    .. deprecated:: build a ``samplers.RunPlan`` and call
       ``MHEngine.submit(plan, compiled=True)`` instead (DESIGN.md
       §Run-API); this wrapper stays bit-compatible but only covers the
       burn-in/thin convenience slice of the engine surface.
    """
    warnings.warn(
        "core.metropolis.run_chain is deprecated; build a samplers.RunPlan "
        "and call engine.submit(plan, compiled=True) (DESIGN.md §Run-API)",
        DeprecationWarning,
        stacklevel=2,
    )
    return _run_chain_impl(
        key, log_prob_fn, cfg, n_samples, chain_shape, init_words
    )


def effective_sample_count(result: MHResult) -> int:
    return int(result.samples.shape[0]) * int(
        max(1, jnp.size(result.samples[0]))
    )
