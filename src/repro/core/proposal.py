"""Bit-flip proposal and its transfer matrix — paper §3.2, Fig. 6.

The block-wise pseudo-read applied to the bitcells holding x^(i) flips every
bit independently with probability p_BFR, so

    q(y | x) = p^d(x,y) * (1-p)^(k - d(x,y)),   d = Hamming distance.

d(x,y) = d(y,x)  =>  q is symmetric  =>  the MH accept ratio collapses to
alpha = p(x*) / p(x^(i))   (no proposal densities, no normaliser).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("nbits",))
def propose_bitflip(key, state: jnp.ndarray, p_bfr, nbits: int):
    """Flip each of the low ``nbits`` bits of integer ``state`` w.p. p_bfr.

    state: (...,) uint32 words.  Returns candidate words, same shape/dtype.
    Vectorised analogue of pseudo-read over a block of compartments.
    """
    flips = jax.random.bernoulli(key, p_bfr, (*state.shape, nbits))
    weights = (jnp.uint32(1) << jnp.arange(nbits, dtype=jnp.uint32)).astype(jnp.uint32)
    mask = jnp.sum(flips.astype(jnp.uint32) * weights, axis=-1).astype(jnp.uint32)
    return jnp.bitwise_xor(state.astype(jnp.uint32), mask)


def propose_bitflip_from_words(state: jnp.ndarray, flip_words: jnp.ndarray, nbits: int):
    """Same proposal, but from pre-generated biased flip words.

    ``flip_words`` carries Bernoulli(p_bfr) bit-planes (cf.
    bitcell.raw_random_words); only the low ``nbits`` are used.  This is the
    form consumed by the Pallas kernel (bits generated out-of-kernel on CPU,
    in-kernel via the hardware PRNG on TPU).
    """
    mask = jnp.uint32((1 << nbits) - 1)
    return jnp.bitwise_xor(
        state.astype(jnp.uint32), flip_words.astype(jnp.uint32) & mask
    )


def hamming_distance(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Popcount of x ^ y (numpy, for analytics/tests)."""
    v = np.bitwise_xor(np.asarray(x, dtype=np.uint64), np.asarray(y, dtype=np.uint64))
    # vectorised popcount
    count = np.zeros_like(v)
    while np.any(v):
        count += v & 1
        v >>= 1
    return count


def transfer_matrix(nbits: int, p_bfr: float) -> np.ndarray:
    """Full 2^k x 2^k transfer matrix q(i, j) (paper Fig. 6).

    Only practical for small k (analytics/tests); q is symmetric and
    doubly-stochastic.
    """
    n = 1 << nbits
    idx = np.arange(n)
    d = hamming_distance(idx[:, None], idx[None, :]).astype(np.float64)
    return (p_bfr**d) * ((1.0 - p_bfr) ** (nbits - d))


def mh_transition_matrix(nbits: int, p_bfr: float, log_prob: np.ndarray) -> np.ndarray:
    """Exact MH transition kernel P for a k-bit target (for stationarity tests).

    P[i, j] = q(i,j) * min(1, p(j)/p(i))  for j != i, diagonal = leftover.
    """
    n = 1 << nbits
    if log_prob.shape != (n,):
        raise ValueError(f"log_prob must have shape ({n},)")
    q = transfer_matrix(nbits, p_bfr)
    ratio = np.exp(np.clip(log_prob[None, :] - log_prob[:, None], -700, 0.0))
    accept = np.minimum(1.0, ratio)
    p_mat = q * accept
    np.fill_diagonal(p_mat, 0.0)
    np.fill_diagonal(p_mat, 1.0 - p_mat.sum(axis=1))
    return p_mat
