"""Target distributions p(x) for the MCMC benchmarks — paper §6.6, Fig. 17.

The macro samples k-bit integer words; continuous targets are evaluated on a
uniform grid over a box, with the word's bit-field split across dimensions
(the paper's multi-bit words are raster-ordered the same way).  A Gray-code
option is provided as a beyond-paper improvement: it makes single-bit flips
move to *adjacent* grid cells, improving proposal locality at high bit
widths (documented in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray
LogProbFn = Callable[[Array], Array]  # int words (...,) -> log p (...,)


def binary_to_gray(x: Array) -> Array:
    x = x.astype(jnp.uint32)
    return jnp.bitwise_xor(x, x >> 1)


def gray_to_binary(g: Array) -> Array:
    g = g.astype(jnp.uint32)
    b = g
    for shift in (1, 2, 4, 8, 16):
        b = jnp.bitwise_xor(b, b >> shift)
    return b


@dataclasses.dataclass(frozen=True)
class GridCodec:
    """Maps k-bit integer words <-> points in a [lo, hi]^dim box."""

    nbits: int                       # total bits in the word
    dim: int = 1
    lo: tuple = (-8.0,)
    hi: tuple = (8.0,)
    gray: bool = False               # Gray-coded per-dimension fields

    def __post_init__(self):
        if self.nbits % self.dim != 0:
            raise ValueError("nbits must divide evenly across dimensions")
        if len(self.lo) != self.dim or len(self.hi) != self.dim:
            raise ValueError("lo/hi must have length dim")

    @property
    def bits_per_dim(self) -> int:
        return self.nbits // self.dim

    @property
    def levels(self) -> int:
        return 1 << self.bits_per_dim

    def decode(self, words: Array) -> Array:
        """(...,) uint words -> (..., dim) float coordinates (cell centers)."""
        b = self.bits_per_dim
        mask = jnp.uint32((1 << b) - 1)
        words = words.astype(jnp.uint32)
        coords = []
        for d in range(self.dim):
            field = (words >> jnp.uint32(d * b)) & mask
            if self.gray:
                field = gray_to_binary(field) & mask
            frac = (field.astype(jnp.float32) + 0.5) / jnp.float32(self.levels)
            coords.append(self.lo[d] + frac * (self.hi[d] - self.lo[d]))
        return jnp.stack(coords, axis=-1)

    def encode(self, x: Array) -> Array:
        """(..., dim) float -> (...,) uint words (nearest cell)."""
        b = self.bits_per_dim
        word = jnp.zeros(x.shape[:-1], dtype=jnp.uint32)
        for d in range(self.dim):
            frac = (x[..., d] - self.lo[d]) / (self.hi[d] - self.lo[d])
            field = jnp.clip(
                jnp.floor(frac * self.levels).astype(jnp.int32), 0, self.levels - 1
            ).astype(jnp.uint32)
            if self.gray:
                field = binary_to_gray(field)
            word = word | (field << jnp.uint32(d * b))
        return word


# --- continuous densities -------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GaussianMixture:
    """Mixture of diagonal/full-covariance Gaussians (paper Fig. 17(a): 4 comps)."""

    means: tuple            # (K, dim)
    covs: tuple             # (K, dim, dim)
    weights: tuple          # (K,)

    @staticmethod
    def paper_gmm() -> "GaussianMixture":
        """A 4-component 1-D mixture matching Fig. 17(a)'s qualitative shape."""
        means = ((-6.0,), (-2.0,), (2.0,), (6.0,))
        covs = (((0.8,),), ((0.5,),), ((0.7,),), ((1.0,),))
        weights = (0.2, 0.3, 0.3, 0.2)
        return GaussianMixture(means, covs, weights)

    def log_prob(self, x: Array) -> Array:
        """x: (..., dim) -> (...,) log density."""
        means = jnp.asarray(self.means)                 # (K, dim)
        covs = jnp.asarray(self.covs)                   # (K, dim, dim)
        weights = jnp.asarray(self.weights)             # (K,)
        dim = means.shape[-1]
        diff = x[..., None, :] - means                  # (..., K, dim)
        prec = jnp.linalg.inv(covs)                     # (K, dim, dim)
        maha = jnp.einsum("...ki,kij,...kj->...k", diff, prec, diff)
        _, logdet = jnp.linalg.slogdet(covs)            # (K,)
        log_comp = (
            -0.5 * (maha + logdet + dim * jnp.log(2.0 * jnp.pi))
            + jnp.log(weights)
        )
        return jax.scipy.special.logsumexp(log_comp, axis=-1)


@dataclasses.dataclass(frozen=True)
class MultivariateGaussian:
    """Multivariate normal (paper Fig. 17(b): bivariate example)."""

    mean: tuple
    cov: tuple

    @staticmethod
    def paper_mgd() -> "MultivariateGaussian":
        """Correlated bivariate Gaussian matching Fig. 17(b)'s heat map."""
        return MultivariateGaussian(mean=(0.0, 0.0), cov=((1.0, 0.6), (0.6, 1.2)))

    def log_prob(self, x: Array) -> Array:
        mean = jnp.asarray(self.mean)
        cov = jnp.asarray(self.cov)
        dim = mean.shape[-1]
        diff = x - mean
        prec = jnp.linalg.inv(cov)
        maha = jnp.einsum("...i,ij,...j->...", diff, prec, diff)
        _, logdet = jnp.linalg.slogdet(cov)
        return -0.5 * (maha + logdet + dim * jnp.log(2.0 * jnp.pi))


# --- discrete word-space targets ------------------------------------------


def discretized_target(density, codec: GridCodec) -> LogProbFn:
    """log p over k-bit words = log density at the decoded grid point."""

    def log_prob(words: Array) -> Array:
        return density.log_prob(codec.decode(words))

    return log_prob


def table_target(log_prob_table: Array) -> LogProbFn:
    """Target given as an explicit table over all 2^k words (or V logits)."""

    table = jnp.asarray(log_prob_table)

    def log_prob(words: Array) -> Array:
        safe = jnp.clip(words.astype(jnp.int32), 0, table.shape[-1] - 1)
        vals = table[safe]
        in_range = words.astype(jnp.int32) < table.shape[-1]
        return jnp.where(in_range, vals, -jnp.inf)

    return log_prob


def categorical_from_logits(logits: Array, temperature: float = 1.0) -> LogProbFn:
    """Unnormalised categorical target — softmax-free (only ratios are used)."""
    return table_target(jnp.asarray(logits) / temperature)


def reference_grid_probs(density, codec: GridCodec) -> np.ndarray:
    """Exact normalised cell probabilities on the codec grid (for TV tests)."""
    words = jnp.arange(1 << codec.nbits, dtype=jnp.uint32)
    logp = np.asarray(density.log_prob(codec.decode(words)), dtype=np.float64)
    p = np.exp(logp - logp.max())
    return p / p.sum()
