# The paper's primary contribution: a behavioural + algorithmic twin of the
# SRAM compute-in-memory MCMC macro, vectorised in JAX.
#
#   bitcell       pseudo-read stochasticity model, BFR(CVDD, T)
#   msxor         multi-stage XOR debiasing (lambda recursion + folds)
#   uniform_rng   accurate [0,1] RNG (reset -> pseudo-read -> MSXOR -> pack)
#   proposal      bit-flip proposal + symmetric transfer matrix
#   metropolis    Metropolis-Hastings API (wraps repro.samplers engine)
#   macro         compartment-parallel macro + 28 nm energy/time ledger
#   energy        calibrated per-op energy/latency model (paper Fig. 14/16)
#   targets       GMM / MGD / categorical targets + grid codecs
#   token_sampler softmax-free MCMC token sampling for LLM decode
#
# The MH step itself lives exactly once, in repro/samplers (DESIGN.md §2).

from repro.core import (  # noqa: F401
    bitcell,
    energy,
    macro,
    metropolis,
    msxor,
    proposal,
    targets,
    token_sampler,
    uniform_rng,
)
from repro.core.macro import CIMMacro, MacroConfig  # noqa: F401
from repro.core.metropolis import MHConfig, run_chain  # noqa: F401
