"""Multi-stage XOR (MSXOR) debiasing — paper §4.2 + Appendix A.

A raw pseudo-read bit is "1" with probability lambda_0 = p_BFR < 0.5.
XOR-ing two i.i.d. biased bits gives a bit with
    lambda_{n+1} = 2 * lambda_n * (1 - lambda_n),
the logistic map whose fixed point on (0, 0.5] is 0.5.  Three stages
(2^3 = 8 raw words folded into 1) suffice for p_BFR >= 0.4:
lambda_3(0.4) = 0.49999872, i.e. |0.5 - lambda| = 1.28e-6 < 1e-5.

The circuit folds *words*: 64 bitcells = 8 groups of 8-bit raw numbers
R0^0..R0^7; each XOR stage pairs words bitwise (8 -> 4 -> 2 -> 1), producing
the final debiased word R3[7:0].  We reproduce that exact dataflow, extended
to arbitrary word widths (uint32 lanes on the TPU VPU).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_STAGES = 3  # paper: 3 stages adequate for p_BFR >= 0.4


def lambda_recursion(p_bfr: float, n_stages: int) -> float:
    """lambda_n after ``n_stages`` XOR stages (paper Fig. 9(d) analytics)."""
    lam = float(p_bfr)
    for _ in range(n_stages):
        lam = 2.0 * lam * (1.0 - lam)
    return lam


def debias_error(p_bfr: float, n_stages: int) -> float:
    """Distance from the uniform point, 0.5 - lambda_n (paper Fig. 9(d))."""
    return 0.5 - lambda_recursion(p_bfr, n_stages)


def required_stages(p_bfr: float, tol: float = 1e-5, max_stages: int = 16) -> int:
    """Smallest stage count n with 0.5 - lambda_n <= tol."""
    for n in range(max_stages + 1):
        if debias_error(p_bfr, n) <= tol:
            return n
    raise ValueError(
        f"p_bfr={p_bfr} cannot reach tol={tol} within {max_stages} stages"
    )


@partial(jax.jit, static_argnames=("n_stages", "axis"))
def xor_fold(raw: jnp.ndarray, n_stages: int = DEFAULT_STAGES, axis: int = -2):
    """Fold 2^n_stages raw words into one debiased word along ``axis``.

    ``raw`` must have size 2^n_stages along ``axis``; integer dtype.  Each
    stage XORs adjacent pairs, exactly mirroring the MSXOR gate tree.
    """
    if raw.shape[axis] != (1 << n_stages):
        raise ValueError(
            f"axis {axis} must have size 2**{n_stages}={1 << n_stages}, "
            f"got shape {raw.shape}"
        )
    out = jnp.moveaxis(raw, axis, -1)
    for _ in range(n_stages):
        out = jnp.bitwise_xor(out[..., 0::2], out[..., 1::2])
    return out[..., 0]


@partial(jax.jit, static_argnames=("n_stages",))
def debias_bits(raw_bits: jnp.ndarray, n_stages: int = DEFAULT_STAGES):
    """Debias a trailing-axis group of raw *bit* arrays.

    raw_bits: (..., 2^n_stages, W) uint8 in {0,1}  ->  (..., W) uint8.
    """
    return xor_fold(raw_bits, n_stages=n_stages, axis=-2)


def pack_bits_to_uint(bits: jnp.ndarray, nbits: int) -> jnp.ndarray:
    """(..., nbits) {0,1} -> (...,) uint32, bit 0 = least significant."""
    if bits.shape[-1] != nbits:
        raise ValueError(f"expected trailing dim {nbits}, got {bits.shape}")
    weights = (jnp.uint32(1) << jnp.arange(nbits, dtype=jnp.uint32)).astype(jnp.uint32)
    return jnp.sum(bits.astype(jnp.uint32) * weights, axis=-1).astype(jnp.uint32)


def unpack_uint_to_bits(words: jnp.ndarray, nbits: int) -> jnp.ndarray:
    """(...,) uint -> (..., nbits) uint8, bit 0 = least significant."""
    shifts = jnp.arange(nbits, dtype=jnp.uint32)
    return ((words[..., None].astype(jnp.uint32) >> shifts) & jnp.uint32(1)).astype(
        jnp.uint8
    )


def empirical_lambda(bits: np.ndarray) -> float:
    """Monte-Carlo estimate of P(bit = 1) for validation benchmarks."""
    return float(np.asarray(bits, dtype=np.float64).mean())
