"""The CIM macro — compartment-parallel MCMC with energy/time accounting.

Behavioural twin of the paper's 256 kb macro (§4-§6): 64 compartments of
64x64 bitcells, each running an independent MH chain in lock-step, a shared
accurate-[0,1] RNG, and the three working modes (memory / block-wise RNG /
CIM copy).  The sampling path is ``metropolis.run_chain`` — a thin
wrapper over the unified sampler engine (``repro.samplers``, DESIGN.md
§2) — so the macro rides the engine's jit cache; the macro layer adds
the compartment geometry, operating-condition -> p_BFR mapping, and the
28 nm energy/timing ledger.

Metric definitions (paper Fig. 16, see DESIGN.md §4): the energy/time
ledger charges *every* chain step (burn-in and thinned-away steps cost
real energy), while ``energy_per_sample_pj`` and
``throughput_samples_per_s`` are normalised by the *kept* sample count —
the samples a user actually receives.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core import bitcell, energy, metropolis, uniform_rng

Array = jnp.ndarray


class MacroMode(enum.Enum):
    MEMORY = "memory"            # plain SRAM R/W
    BLOCK_RNG = "block_rng"      # pseudo-read block random generation
    CIM_COPY = "cim_copy"        # in-memory copy


@dataclasses.dataclass(frozen=True)
class MacroConfig:
    n_compartments: int = energy.N_COMPARTMENTS
    rows: int = 64
    cols: int = 64
    nbits: int = 4                       # 4..64 via column-group ganging (§5.1)
    cvdd_pseudo_read: float = bitcell.PSEUDO_READ_CVDD
    temp_c: float = bitcell.NOMINAL_TEMP_C
    rng_bit_width: int = 16
    rng_stages: int = 3
    burn_in: int = 500
    thin: int = 1

    def __post_init__(self):
        if self.nbits > 64:
            raise ValueError("expandable precision tops out at 64 bits (§5.1)")
        groups_needed = -(-self.nbits // 4)
        if groups_needed > self.cols // 4:
            raise ValueError("sample wider than a compartment row")

    @property
    def p_bfr(self) -> float:
        return float(bitcell.bit_flip_rate(self.cvdd_pseudo_read, self.temp_c))

    @property
    def sample_nbits(self) -> int:
        return min(self.nbits, 32)

    def mh_config(self) -> metropolis.MHConfig:
        return metropolis.MHConfig(
            nbits=self.sample_nbits,
            p_bfr=self.p_bfr,
            rng_p_bfr=self.p_bfr,
            rng_stages=self.rng_stages,
            rng_bit_width=self.rng_bit_width,
            burn_in=self.burn_in,
            thin=self.thin,
            randomness="cim",            # the macro IS the CIM pipeline
        )


@dataclasses.dataclass(frozen=True)
class MacroRunStats:
    n_samples: int
    n_steps: int
    acceptance_rate: float
    energy_pj: float
    modeled_time_s: float
    energy_per_sample_pj: float          # total energy / KEPT samples
    throughput_samples_per_s: float      # KEPT samples / modeled time


class CIMMacro:
    """Compartment-parallel MCMC sampler with the paper's cost model."""

    def __init__(self, config: MacroConfig = MacroConfig()):
        self.config = config

    @property
    def p_bfr(self) -> float:
        return self.config.p_bfr

    def uniform_rng_config(self) -> uniform_rng.UniformRNGConfig:
        return uniform_rng.UniformRNGConfig(
            p_bfr=self.config.p_bfr,
            n_stages=self.config.rng_stages,
            bit_width=self.config.rng_bit_width,
        )

    def sample(
        self,
        key,
        log_prob_fn: Callable[[Array], Array],
        n_samples: int,
        init_words: Array | None = None,
    ) -> tuple[np.ndarray, MacroRunStats]:
        """Draw >= ``n_samples`` words; returns (samples, stats).

        Samples are drawn across all compartments in lock-step, so the kept
        count per chain is ceil(n_samples / n_compartments).
        """
        cfg = self.config
        per_chain = -(-n_samples // cfg.n_compartments)
        result = metropolis._run_chain_impl(
            key,
            log_prob_fn,
            cfg.mh_config(),
            n_samples=per_chain,
            chain_shape=(cfg.n_compartments,),
            init_words=init_words,
        )
        samples = np.asarray(result.samples).reshape(-1)[:n_samples]

        n_steps_total = int(result.n_steps) * cfg.n_compartments
        n_accepted = int(jnp.sum(result.final.accept_count))
        n_kept = int(samples.size)
        ledger = energy.EnergyLedger(
            n_steps=n_steps_total,
            n_accepted=n_accepted,
            nbits=cfg.nbits,
            n_chains=cfg.n_compartments,
        )
        stats = MacroRunStats(
            n_samples=n_kept,
            n_steps=n_steps_total,
            acceptance_rate=float(result.acceptance_rate),
            energy_pj=ledger.energy_pj,
            modeled_time_s=ledger.time_s,
            energy_per_sample_pj=ledger.energy_pj / max(1, n_kept),
            throughput_samples_per_s=(
                n_kept / ledger.time_s if ledger.time_s > 0 else float("inf")
            ),
        )
        return samples, stats

    def mh_config(self) -> metropolis.MHConfig:
        return self.config.mh_config()

    def sample_points(
        self,
        key,
        density,
        codec,
        n_samples: int,
    ) -> tuple[np.ndarray, MacroRunStats]:
        """Sample a continuous density through a GridCodec (Fig. 17 workloads)."""
        from repro.core import targets as _targets

        log_prob_fn = _targets.discretized_target(density, codec)
        words, stats = self.sample(key, log_prob_fn, n_samples)
        pts = np.asarray(codec.decode(jnp.asarray(words, dtype=jnp.uint32)))
        return pts, stats
