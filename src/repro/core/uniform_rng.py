"""Accurate [0,1] RNG module — paper §4.2.

Pipeline (mirrors the circuit):
  1. reset the RNG sub-array bitcells to "0"            (guarantees lambda_0 <= 0.5)
  2. pseudo-read -> raw bits ~ Bernoulli(p_BFR)          (biased)
  3. MSXOR n-stage fold -> debiased bits (lambda_n ~ 0.5)
  4. pack ``bit_width`` debiased bits into an integer R_n
  5. u = R_n / 2^bit_width  in [0, 1)

The paper's instance: 64 bitcells = 8 raw 8-bit words, 3 XOR stages, one
8-bit output shared by all 64 compartments.  Here the module is vectorised:
one call produces any batch shape of independent uniforms.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import bitcell, msxor


@dataclasses.dataclass(frozen=True)
class UniformRNGConfig:
    p_bfr: float = 0.45          # pseudo-read at CVDD=0.5 V, 25 C
    n_stages: int = 3            # MSXOR stages (paper: 3 for p_BFR >= 0.4)
    bit_width: int = 8           # output sample precision (paper: 8-bit)

    def __post_init__(self):
        if not 0.0 < self.p_bfr <= 0.5:
            raise ValueError(f"p_bfr must be in (0, 0.5], got {self.p_bfr}")
        if not 1 <= self.bit_width <= 32:
            raise ValueError(f"bit_width must be in [1,32], got {self.bit_width}")

    @property
    def debias_error(self) -> float:
        return msxor.debias_error(self.p_bfr, self.n_stages)


@partial(jax.jit, static_argnames=("shape", "bit_width", "n_stages"))
def uniform_words(key, shape, p_bfr, bit_width: int = 8, n_stages: int = 3):
    """Debiased ``bit_width``-bit integers of the given batch ``shape``."""
    raw = bitcell.pseudo_read_fresh(
        key, p_bfr, shape=(*shape, 1 << n_stages, bit_width)
    )
    bits = msxor.debias_bits(raw, n_stages=n_stages)
    return msxor.pack_bits_to_uint(bits, bit_width)


@partial(jax.jit, static_argnames=("shape", "bit_width", "n_stages"))
def uniform(key, shape, p_bfr, bit_width: int = 8, n_stages: int = 3):
    """u ~ U[0,1) with per-bit bias |0.5 - lambda| = debias_error(p, n)."""
    words = uniform_words(key, shape, p_bfr, bit_width, n_stages)
    return words.astype(jnp.float32) / jnp.float32(1 << bit_width)


class AccurateUniformRNG:
    """Stateful convenience wrapper (splits its key per draw)."""

    def __init__(self, key, config: UniformRNGConfig = UniformRNGConfig()):
        self._key = key
        self.config = config

    def draw(self, shape=()):
        self._key, sub = jax.random.split(self._key)
        return uniform(
            sub,
            shape,
            self.config.p_bfr,
            self.config.bit_width,
            self.config.n_stages,
        )
