"""Calibrated 28 nm energy & timing model of the CIM macro — paper §6.4/§6.5.

All constants are the paper's measured values (Fig. 16(a), Fig. 14, §6.1).
Derived quantities are validated against every number quoted in the paper:

  * accepted sample:   0.5065 pJ       (§6.4)
  * rejected sample:   0.5547 pJ       (§6.4)
  * 30-40 % acceptance: 0.533-0.540 pJ (§6.4; we get 0.5402-0.5354, see note)
  * 4-bit throughput:  166.7 M samples/s  (§6.5, 6 ns/iteration)
  * >=1e7 samples/s up to 32-bit, sub-2x slowdown per bit doubling (Fig 16(b))

Model notes (documented deviations):
  * The per-sample energy decomposes as
      E_accept(4b) = E_rng + E_copy + E_read + E_u/64 + E_calc
                   = 79.1 + 47.5 + 343.1 + 3.67 + 33.1 = 506.5 fJ,
    which reproduces the paper's 0.5065 pJ exactly; E_calc = 33.1 fJ is the
    one fitted residual (the paper does not itemise the accept/reject logic).
  * Rejection adds one extra in-memory copy (+ WL overhead): +48.2 fJ.
  * R/W and copy energy/latency scale with ceil(bits/4) column groups
    (§5.1 "separate transmission" over 4-column groups); block-RNG energy
    scales with active bitcells but its *latency* does not (§6.5: WLs of any
    width open simultaneously).
"""

from __future__ import annotations

import dataclasses
import math

# --- per-operation energies, femtojoules (Fig. 16(a)) ---------------------
E_WRITE_FJ_PER_4B = 372.6
E_READ_FJ_PER_4B = 343.1
E_BLOCK_RNG_FJ_PER_4B = 79.1
E_COPY_FJ_PER_4B = 47.5
E_UNIFORM_RNG_FJ_PER_8B = 234.6   # shared by all 64 compartments (§6.1)
E_CALC_FJ = 33.1                  # fitted: accept/reject digital logic
E_REJECT_EXTRA_FJ = 48.2          # re-copy previous value (0.5547-0.5065 pJ)

# --- per-operation latencies, nanoseconds (Fig. 14 timing diagram) --------
T_WRITE_NS = 1.0
T_RNG_NS = 1.0        # independent of bit width (parallel WLs, §6.5)
T_COPY_NS = 2.0       # per 4-column group
T_READ_NS = 1.0       # per 4-column group
T_CALC_NS = 1.0
T_GUARD_NS = 1.0      # WL switch / precharge guard band

N_COMPARTMENTS = 64   # §5.2: 64 compartments of 64x64 bitcells
MACRO_CAPACITY_KB = 256
CORE_AREA_MM2 = 0.1967


def _groups(nbits: int) -> int:
    """Number of 4-column groups ganged for an ``nbits`` sample (§5.1)."""
    if not 1 <= nbits <= 64:
        raise ValueError(f"nbits must be in [1, 64], got {nbits}")
    return max(1, math.ceil(nbits / 4))


def energy_accepted_fj(nbits: int = 4) -> float:
    g = _groups(nbits)
    return (
        E_BLOCK_RNG_FJ_PER_4B * g
        + E_COPY_FJ_PER_4B * g
        + E_READ_FJ_PER_4B * g
        + E_UNIFORM_RNG_FJ_PER_8B / N_COMPARTMENTS
        + E_CALC_FJ
    )


def energy_rejected_fj(nbits: int = 4) -> float:
    # extra in-memory copy rewrites the previous value over the rejected one
    extra = E_REJECT_EXTRA_FJ * (_groups(nbits) / _groups(4))
    return energy_accepted_fj(nbits) + extra


def energy_per_sample_fj(accept_ratio: float, nbits: int = 4) -> float:
    """Expected energy per chain step at the given acceptance ratio (§6.4)."""
    if not 0.0 <= accept_ratio <= 1.0:
        raise ValueError(f"accept_ratio must be in [0,1], got {accept_ratio}")
    return accept_ratio * energy_accepted_fj(nbits) + (
        1.0 - accept_ratio
    ) * energy_rejected_fj(nbits)


def iteration_time_ns(nbits: int = 4) -> float:
    """Per-sample loop period (Fig. 14): 6 ns at 4-bit => 166.7 M samples/s."""
    g = _groups(nbits)
    return T_RNG_NS + T_CALC_NS + g * (T_READ_NS + T_COPY_NS) + T_GUARD_NS


def throughput_per_chain(nbits: int = 4) -> float:
    """Samples/s of one compartment chain (the paper's headline number)."""
    return 1e9 / iteration_time_ns(nbits)


def throughput_aggregate(nbits: int = 4, n_compartments: int = N_COMPARTMENTS) -> float:
    """Aggregate chain-steps/s with all compartments in lock-step (§5.2)."""
    return n_compartments * throughput_per_chain(nbits)


def power_w(nbits: int = 4, accept_ratio: float = 0.35) -> float:
    """Single-chain average power = energy/sample x chain rate.

    Reproduces the paper's §6.6 quote of 0.157 mW (GMM) / 0.152 mW (MGD) at
    32-bit: 37 M samples/s x ~3.8-4.2 pJ/sample ~ 0.15 mW.
    """
    return energy_per_sample_fj(accept_ratio, nbits) * 1e-15 * throughput_per_chain(
        nbits
    )


def time_for_samples_s(
    n_samples: int, nbits: int = 32, n_compartments: int = N_COMPARTMENTS
) -> float:
    """Macro wall time to emit ``n_samples`` with compartment parallelism.

    Fig. 17(c): 1e6 32-bit samples in ~4e-4 s ("within 1e-3 s" in the paper).
    """
    return n_samples / throughput_aggregate(nbits, n_compartments)


@dataclasses.dataclass(frozen=True)
class EnergyLedger:
    """Accumulated energy/time for a concrete MCMC run (macro accounting)."""

    n_steps: int = 0
    n_accepted: int = 0
    nbits: int = 4
    n_chains: int = 1

    def add(self, n_steps: int, n_accepted: int) -> "EnergyLedger":
        return dataclasses.replace(
            self,
            n_steps=self.n_steps + n_steps,
            n_accepted=self.n_accepted + n_accepted,
        )

    @property
    def n_rejected(self) -> int:
        return self.n_steps - self.n_accepted

    @property
    def energy_pj(self) -> float:
        return (
            self.n_accepted * energy_accepted_fj(self.nbits)
            + self.n_rejected * energy_rejected_fj(self.nbits)
        ) * 1e-3

    @property
    def time_s(self) -> float:
        per_chain_steps = math.ceil(self.n_steps / max(1, self.n_chains))
        return per_chain_steps * iteration_time_ns(self.nbits) * 1e-9

    @property
    def energy_per_sample_pj(self) -> float:
        return self.energy_pj / max(1, self.n_steps)
