"""Softmax-free MCMC token sampling — the paper's technique in LLM decode.

The next-token id is treated as a ceil(log2 V)-bit word.  The proposal flips
each bit with p_BFR (the pseudo-read analogue); u comes from the MSXOR
debiased uniform RNG; the accept test uses only the *logit difference*
exp((l* - l)/T) — exactly the paper's alpha = p(x*)/p(x^(i)) simplification.
No logsumexp over the vocabulary is ever computed.

Out-of-vocab proposals (V is rarely a power of two) have p = 0 and are
always rejected, which preserves detailed balance restricted to [0, V).

Statistical behaviour: with p_BFR ~ 0.45 the proposal is a near-uniform
independence sampler over the 2^k hypercube, so the chain mixes in O(1/p_max)
steps for heavy-tailed targets and benefits from temperature warm-up for
peaked ones.  ``n_steps`` and the top-k restriction (beyond-paper option)
trade fidelity for latency; fidelity is quantified in
benchmarks/bench_token_sampler.py.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import proposal, uniform_rng

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class TokenSamplerConfig:
    vocab_size: int
    n_steps: int = 64                 # MH iterations per emitted token
    p_bfr: float = 0.45
    rng_bit_width: int = 24           # u precision (logit ratios can be tiny)
    rng_stages: int = 3
    temperature: float = 1.0
    top_k: int = 0                    # 0 = full vocab (paper-faithful);
                                      # >0 restricts the chain to top-k logits

    @property
    def nbits(self) -> int:
        space = self.top_k if self.top_k > 0 else self.vocab_size
        return max(1, math.ceil(math.log2(space)))


class TokenSampleResult(NamedTuple):
    tokens: Array            # (batch,) int32 sampled token ids
    acceptance_rate: Array   # scalar float32
    final_logp: Array        # (batch,) float32 unnormalised log-prob


def _gather_logits(logits: Array, words: Array, vocab: int) -> Array:
    """logits: (B, V), words: (B,) -> (B,) with -inf outside [0, V)."""
    safe = jnp.clip(words.astype(jnp.int32), 0, vocab - 1)
    vals = jnp.take_along_axis(logits, safe[:, None], axis=-1)[:, 0]
    return jnp.where(words.astype(jnp.int32) < vocab, vals, -jnp.inf)


@partial(jax.jit, static_argnames=("cfg",))
def sample_tokens(
    key,
    logits: Array,
    cfg: TokenSamplerConfig,
    init_tokens: Array | None = None,
) -> TokenSampleResult:
    """Draw one token per row of ``logits`` (B, V) via the CIM-MCMC chain.

    ``init_tokens`` seeds each chain (e.g. the previous sampled token —
    the macro's "initial value x^(0) written into the bitcells"); defaults
    to the argmax, which guarantees a finite-logp start.
    """
    batch, vocab = logits.shape
    if cfg.top_k > 0:
        # beyond-paper: restrict the word space to the top-k logits
        top_vals, top_idx = jax.lax.top_k(logits, cfg.top_k)
        work_logits = top_vals / cfg.temperature
        space = cfg.top_k
    else:
        top_idx = None
        work_logits = logits / cfg.temperature
        space = vocab

    if init_tokens is None:
        init_words = jnp.argmax(work_logits, axis=-1).astype(jnp.uint32)
    else:
        init_words = jnp.clip(init_tokens.astype(jnp.uint32), 0, space - 1)

    init_logp = _gather_logits(work_logits, init_words, space)

    def body(carry, step_key):
        words, logp, acc = carry
        k_prop, k_u = jax.random.split(step_key)
        cand = proposal.propose_bitflip(k_prop, words, cfg.p_bfr, cfg.nbits)
        logp_cand = _gather_logits(work_logits, cand, space)
        u = uniform_rng.uniform(
            k_u, words.shape, cfg.p_bfr, cfg.rng_bit_width, cfg.rng_stages
        )
        delta = logp_cand - logp
        accept = jnp.logical_and(
            u < jnp.exp(jnp.minimum(delta, 0.0)), jnp.isfinite(logp_cand)
        )
        words = jnp.where(accept, cand, words)
        logp = jnp.where(accept, logp_cand, logp)
        return (words, logp, acc + accept.astype(jnp.int32)), None

    keys = jax.random.split(key, cfg.n_steps)
    (words, logp, acc), _ = jax.lax.scan(body, (init_words, init_logp, jnp.zeros(batch, jnp.int32)), keys)

    if top_idx is not None:
        tokens = jnp.take_along_axis(top_idx, words.astype(jnp.int32)[:, None], axis=-1)[:, 0]
    else:
        tokens = words.astype(jnp.int32)
    acc_rate = jnp.sum(acc).astype(jnp.float32) / jnp.float32(batch * cfg.n_steps)
    return TokenSampleResult(tokens=tokens, acceptance_rate=acc_rate, final_logp=logp)
