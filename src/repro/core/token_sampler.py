"""Softmax-free MCMC token sampling — the paper's technique in LLM decode.

The next-token id is treated as a ceil(log2 V)-bit word.  The proposal flips
each bit with p_BFR (the pseudo-read analogue); u comes from the MSXOR
debiased uniform RNG; the accept test uses only the *logit difference*
exp((l* - l)/T) — exactly the paper's alpha = p(x*)/p(x^(i)) simplification.
No logsumexp over the vocabulary is ever computed.

Out-of-vocab proposals (V is rarely a power of two) have p = 0 and are
always rejected, which preserves detailed balance restricted to [0, V).

This module is an API-compatible wrapper over the unified sampler engine
(``repro.samplers``): the chain itself lives there once, and the
``execution`` / ``randomness`` fields select the lax.scan vs fused-Pallas
executor and the host vs CIM randomness pipeline (DESIGN.md §2).

Statistical behaviour: with p_BFR ~ 0.45 the proposal is a near-uniform
independence sampler over the 2^k hypercube, so the chain mixes in O(1/p_max)
steps for heavy-tailed targets and benefits from temperature warm-up for
peaked ones.  ``n_steps`` and the top-k restriction (beyond-paper option)
trade fidelity for latency; fidelity is quantified in
benchmarks/bench_token_sampler.py.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import samplers

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class TokenSamplerConfig:
    vocab_size: int
    n_steps: int = 64                 # MH iterations per emitted token
    p_bfr: float = 0.45
    rng_bit_width: int = 24           # u precision (logit ratios can be tiny)
    rng_stages: int = 3
    temperature: float = 1.0
    top_k: int = 0                    # 0 = full vocab (paper-faithful);
                                      # >0 restricts the chain to top-k logits
    execution: str = "auto"           # auto | scan | pallas (engine dispatch)
    randomness: str = "cim"           # cim | host randomness backend
    chunk_steps: int = 64             # randomness streaming granularity

    @property
    def nbits(self) -> int:
        space = self.top_k if self.top_k > 0 else self.vocab_size
        return max(1, math.ceil(math.log2(space)))

    def engine_config(self) -> samplers.EngineConfig:
        return samplers.EngineConfig(
            p_bfr=self.p_bfr,
            randomness=self.randomness,
            rng_p_bfr=self.p_bfr,
            rng_bit_width=self.rng_bit_width,
            rng_stages=self.rng_stages,
            execution=self.execution,
            chunk_steps=self.chunk_steps,
        )


class TokenSampleResult(NamedTuple):
    tokens: Array            # (batch,) int32 sampled token ids
    acceptance_rate: Array   # scalar float32
    final_logp: Array        # (batch,) float32 unnormalised log-prob


@partial(jax.jit, static_argnames=("cfg",))
def _sample_tokens_impl(
    key,
    logits: Array,
    cfg: TokenSamplerConfig,
    init_tokens: Array | None = None,
) -> TokenSampleResult:
    engine = samplers.MHEngine(cfg.engine_config())
    tokens, result = engine.sample_tokens(
        key,
        logits,
        n_steps=cfg.n_steps,
        temperature=cfg.temperature,
        top_k=cfg.top_k,
        init_tokens=init_tokens,
    )
    return TokenSampleResult(
        tokens=tokens,
        acceptance_rate=result.acceptance_rate,
        final_logp=result.final_logp[:, 0],
    )


def sample_tokens(
    key,
    logits: Array,
    cfg: TokenSamplerConfig,
    init_tokens: Array | None = None,
) -> TokenSampleResult:
    """Draw one token per row of ``logits`` (B, V) via the CIM-MCMC chain.

    ``init_tokens`` seeds each chain (e.g. the previous sampled token —
    the macro's "initial value x^(0) written into the bitcells"); defaults
    to the argmax, which guarantees a finite-logp start.

    .. deprecated:: the documented surface is
       ``MHEngine.sample_tokens`` reached through ``repro.samplers``
       (DESIGN.md §Run-API); this wrapper stays bit-compatible.
    """
    warnings.warn(
        "core.token_sampler.sample_tokens is deprecated; configure an "
        "MHEngine via repro.samplers and call engine.sample_tokens "
        "(DESIGN.md §Run-API)",
        DeprecationWarning,
        stacklevel=2,
    )
    return _sample_tokens_impl(key, logits, cfg, init_tokens)
