"""MCMC chain diagnostics — is the macro's sample stream actually good?

Host-side (numpy, float64) estimators over engine outputs; none of this
is on the sampling hot path, so clarity beats jit-ability:

  * ``integrated_autocorr_time`` — Sokal's windowed estimator of the
    integrated autocorrelation time tau, with the automatic window
    M = min{m : m >= c * tau(m)} (c = 5, the emcee default).  FFT-based
    autocovariance, averaged across chains.
  * ``effective_sample_size``    — ESS = N_total / tau.  An i.i.d. chain
    has tau ~ 1 => ESS ~ N; a sticky chain has tau >> 1 => ESS << N.
  * ``split_rhat``               — Gelman–Rubin potential scale reduction
    with each chain split in half (BDA3 §11.4), which also flags
    within-chain non-stationarity.  ~1 at convergence; > ~1.1 is the
    conventional "keep sampling" threshold.

Conventions: chains are arrays shaped (n_steps,) or (n_steps, n_chains)
of a *scalar* statistic per step (decoded coordinate, magnetisation, …).
Degenerate inputs are defined rather than NaN: a zero-variance chain set
gets tau = n_steps (ESS = n_chains), and split-R-hat of a zero-variance
set is 1.0 (identical constants are trivially converged).
"""

from __future__ import annotations

import numpy as np


def _as_chains(x) -> np.ndarray:
    """Coerce to (n_steps, n_chains) float64."""
    x = np.asarray(x, dtype=np.float64)
    if x.ndim == 1:
        x = x[:, None]
    if x.ndim != 2:
        raise ValueError(
            f"chains must be (n_steps,) or (n_steps, n_chains), got {x.shape}"
        )
    if x.shape[0] < 2:
        raise ValueError(f"need at least 2 steps, got {x.shape[0]}")
    return x


def autocorrelation(chain: np.ndarray) -> np.ndarray:
    """Normalised autocorrelation function of one 1-D chain (FFT-based)."""
    x = np.asarray(chain, dtype=np.float64)
    if x.ndim != 1:
        raise ValueError(f"autocorrelation takes a 1-D chain, got {x.shape}")
    n = x.size
    x = x - x.mean()
    nfft = 1 << (2 * n - 1).bit_length()
    f = np.fft.rfft(x, nfft)
    acov = np.fft.irfft(f * np.conj(f), nfft)[:n]
    if acov[0] <= 0.0:
        # zero-variance chain: perfectly correlated by convention
        return np.ones(n)
    return acov / acov[0]


def integrated_autocorr_time(chains, c: float = 5.0) -> float:
    """Sokal-windowed integrated autocorrelation time, averaged over chains.

    tau(m) = 1 + 2 * sum_{t<=m} rho(t); the window is the smallest m with
    m >= c * tau(m).  Clipped to [1, n_steps].
    """
    x = _as_chains(chains)
    n = x.shape[0]
    rho = np.mean([autocorrelation(x[:, j]) for j in range(x.shape[1])], axis=0)
    taus = 2.0 * np.cumsum(rho) - 1.0  # rho[0] == 1 contributes once
    window = np.arange(n) < c * taus
    m = int(np.argmin(window)) if not window.all() else n - 1
    return float(np.clip(taus[m], 1.0, n))


def effective_sample_size(chains, c: float = 5.0) -> float:
    """ESS = (n_steps * n_chains) / tau."""
    x = _as_chains(chains)
    return float(x.size / integrated_autocorr_time(x, c=c))


def split_rhat(chains) -> float:
    """Split-chain Gelman–Rubin R-hat (BDA3 §11.4).

    Each chain is split into halves (2 * n_chains sequences of n // 2
    steps); R-hat = sqrt(((n-1)/n * W + B/n) / W) with W the mean
    within-sequence variance and B the between-sequence variance.
    """
    x = _as_chains(chains)
    n = (x.shape[0] // 2) * 2
    if n < 4:
        raise ValueError(f"split_rhat needs at least 4 steps, got {x.shape[0]}")
    halves = x[:n].T.reshape(-1, n // 2).T       # (n//2, 2 * n_chains)
    nh = halves.shape[0]
    within = np.mean(np.var(halves, axis=0, ddof=1))
    between = nh * np.var(np.mean(halves, axis=0), ddof=1)
    if within <= 0.0:
        return 1.0 if between <= 0.0 else np.inf
    var_plus = (nh - 1) / nh * within + between / nh
    return float(np.sqrt(var_plus / within))


def summarize(chains, acceptance_rate: float | None = None, c: float = 5.0) -> dict:
    """One-call diagnostic bundle over a scalar chain statistic."""
    x = _as_chains(chains)
    tau = integrated_autocorr_time(x, c=c)
    out = {
        "n_steps": int(x.shape[0]),
        "n_chains": int(x.shape[1]),
        "tau": round(tau, 3),
        "ess": round(x.size / tau, 1),
        "ess_per_step": round(x.size / tau / x.shape[0], 4),
        "split_rhat": round(split_rhat(x), 4),
        "mean": round(float(x.mean()), 5),
        "std": round(float(x.std()), 5),
    }
    if acceptance_rate is not None:
        out["acceptance_rate"] = round(float(acceptance_rate), 4)
    return out
