"""Streaming chain diagnostics — O(chunk) memory over (C, T) sample blocks.

The multi-chain engine (DESIGN.md §Chains-axis) produces a (C, T) block
of a scalar statistic per run; for long chains the diagnostics must not
re-materialise the whole block.  ``StreamingChainStats`` consumes the
series in chunks of any size and reproduces the batch estimators of
``chain_stats`` from O(num_chains * max_lag) state:

  * **tau / ESS** — the windowed Sokal estimator needs the autocovariance
    at lags 0..M where M is the (data-dependent) Sokal window.  Streaming
    state per chain: running sum, lag-k cross-product sums for
    k <= max_lag (a ring buffer of the last ``max_lag`` values produces
    each new product), plus the first/last ``max_lag`` values for the
    end-correction — acov_k = S_k - mean*(A_k + B_k) + (n-k)*mean^2.
    Exact w.r.t. the batch estimator whenever the Sokal window lands
    inside ``max_lag`` (asserted in tests); a window hitting the cap is
    reported via ``window_capped``.
  * **split-R-hat** — total steps are known up front (the engine knows
    ``n_steps``), so each arriving value routes to its half-sequence by
    absolute index; per half-sequence running (count, sum, sum-of-squares)
    reproduce BDA3 split-R-hat exactly.

Layout convention matches ``chain_stats``: chunks are (t, n_chains)
float blocks of a scalar statistic per step, concatenated over t.
"""

from __future__ import annotations

import numpy as np


class StreamingChainStats:
    """Accumulate chain diagnostics from (t, n_chains) chunks.

    Feed chunks with :meth:`update` (total rows must reach
    ``total_steps``), then read :meth:`summarize` — a dict with the same
    keys (and, within the max-lag window, the same values) as
    ``chain_stats.summarize`` over the concatenated series.
    """

    def __init__(
        self,
        num_chains: int,
        total_steps: int,
        max_lag: int | None = None,
        c: float = 5.0,
    ):
        if num_chains < 1:
            raise ValueError(f"num_chains must be >= 1, got {num_chains}")
        if total_steps < 2:
            raise ValueError(f"need at least 2 steps, got {total_steps}")
        self.num_chains = num_chains
        self.total_steps = total_steps
        self.max_lag = min(
            total_steps - 1, 256 if max_lag is None else max_lag
        )
        if self.max_lag < 1:
            raise ValueError(f"max_lag must be >= 1, got {self.max_lag}")
        self.c = c
        self.n = 0
        cshape = (num_chains,)
        self._sum = np.zeros(cshape)
        # lag-k cross-product sums S_k = sum_t x_t * x_{t+k}, k = 0..max_lag
        self._cross = np.zeros((self.max_lag + 1, num_chains))
        self._head = np.empty((0, num_chains))  # first max_lag values
        self._tail = np.empty((0, num_chains))  # last max_lag values
        # half-sequence accumulators for split-R-hat: (2, C) each
        self._half_n = np.zeros((2, num_chains))
        self._half_sum = np.zeros((2, num_chains))
        self._half_sumsq = np.zeros((2, num_chains))

    def update(self, block) -> "StreamingChainStats":
        """Consume the next (t, n_chains) rows of the series."""
        block = _as_chains_chunk(block, self.num_chains)
        t = block.shape[0]
        if self.n + t > self.total_steps:
            raise ValueError(
                f"stream overflow: got {self.n + t} rows, declared "
                f"total_steps={self.total_steps}"
            )
        lag = self.max_lag
        ext = np.concatenate([self._tail, block], axis=0)
        off = self._tail.shape[0]
        for k in range(min(lag, self.n + t - 1) + 1):
            lo = max(0, k - self.n)  # first new row with a lag-k partner
            if lo < t:
                self._cross[k] += np.sum(
                    ext[off + lo - k : off + t - k] * block[lo:], axis=0
                )
        self._sum += block.sum(axis=0)
        if self._head.shape[0] < lag:
            self._head = np.concatenate([self._head, block])[:lag]
        self._tail = ext[-lag:] if ext.shape[0] >= lag else ext
        # split-R-hat half routing by absolute index
        half_len = self.total_steps // 2
        idx = self.n + np.arange(t)
        for h in (0, 1):
            sel = (idx >= h * half_len) & (idx < (h + 1) * half_len)
            if sel.any():
                rows = block[sel]
                self._half_n[h] += rows.shape[0]
                self._half_sum[h] += rows.sum(axis=0)
                self._half_sumsq[h] += (rows * rows).sum(axis=0)
        self.n += t
        return self

    # --- cross-shard merge ---------------------------------------------

    def merge(self, other: "StreamingChainStats") -> "StreamingChainStats":
        """Combine with an accumulator over a *disjoint* chain shard.

        The engine's "chains" sharding rule never communicates between
        chains (DESIGN.md §Chains-axis), so each shard can stream its
        own (t, C/n_shards) blocks locally; merging is exact — every
        per-chain field simply concatenates along the chain axis, and
        the chain-averaged estimators (tau, split-R-hat) computed from
        the merged state equal the unsharded accumulator's bit-for-bit.
        Both sides must cover the same step span (same ``total_steps``,
        ``max_lag``, ``c``, and rows consumed so far).
        """
        for attr in ("total_steps", "max_lag", "c", "n"):
            if getattr(self, attr) != getattr(other, attr):
                raise ValueError(
                    f"cannot merge shards that disagree on {attr}: "
                    f"{getattr(self, attr)} != {getattr(other, attr)} — "
                    "shards must stream the same step span in lock-step"
                )
        out = StreamingChainStats(
            self.num_chains + other.num_chains,
            self.total_steps,
            max_lag=self.max_lag,
            c=self.c,
        )
        out.n = self.n
        cat = lambda a, b: np.concatenate([a, b], axis=-1)  # noqa: E731
        out._sum = cat(self._sum, other._sum)
        out._cross = cat(self._cross, other._cross)
        out._head = cat(self._head, other._head)
        out._tail = cat(self._tail, other._tail)
        out._half_n = cat(self._half_n, other._half_n)
        out._half_sum = cat(self._half_sum, other._half_sum)
        out._half_sumsq = cat(self._half_sumsq, other._half_sumsq)
        return out

    @classmethod
    def merge_shards(cls, shards) -> "StreamingChainStats":
        """Fold an iterable of per-shard accumulators (chain order =
        shard order, matching the mesh's device order)."""
        shards = list(shards)
        if not shards:
            raise ValueError("merge_shards needs at least one accumulator")
        out = shards[0]
        for s in shards[1:]:
            out = out.merge(s)
        return out

    # --- estimators ----------------------------------------------------

    def _autocov(self) -> np.ndarray:
        """(max_lag+1, C) end-corrected autocovariance sums (not /n),
        matching chain_stats.autocorrelation's FFT linear autocovariance."""
        n = self.n
        lag = min(self.max_lag, n - 1)
        mean = self._sum / n
        acov = np.empty((lag + 1, self.num_chains))
        for k in range(lag + 1):
            a_k = self._sum - (self._tail[-k:].sum(axis=0) if k else 0.0)
            b_k = self._sum - (self._head[:k].sum(axis=0) if k else 0.0)
            acov[k] = self._cross[k] - mean * (a_k + b_k) + (n - k) * mean**2
        return acov

    def tau(self) -> tuple[float, bool]:
        """(Sokal tau averaged over chains, window-hit-the-cap flag)."""
        if self.n < 2:
            raise ValueError(f"need at least 2 steps, got {self.n}")
        acov = self._autocov()
        var0 = acov[0]
        rho = np.where(var0 > 0.0, acov / np.where(var0 > 0.0, var0, 1.0), 1.0)
        rho_mean = rho.mean(axis=1)
        taus = 2.0 * np.cumsum(rho_mean) - 1.0
        window = np.arange(taus.size) < self.c * taus
        capped = bool(window.all()) and taus.size < self.n
        m = taus.size - 1 if window.all() else int(np.argmin(window))
        return float(np.clip(taus[m], 1.0, self.n)), capped

    def split_rhat(self) -> float:
        nh = self.total_steps // 2
        if nh < 2:
            raise ValueError(
                f"split_rhat needs at least 4 steps, got {self.total_steps}"
            )
        if not np.all(self._half_n == nh):
            raise ValueError(
                f"stream incomplete: halves hold {self._half_n.min()} of "
                f"{nh} rows"
            )
        means = (self._half_sum / nh).reshape(-1)        # (2C,)
        sq = (self._half_sumsq / nh).reshape(-1)
        variances = (sq - means**2) * nh / (nh - 1)      # ddof=1
        within = float(np.mean(variances))
        between = nh * float(np.var(means, ddof=1))
        if within <= 0.0:
            return 1.0 if between <= 0.0 else float(np.inf)
        var_plus = (nh - 1) / nh * within + between / nh
        return float(np.sqrt(var_plus / within))

    def summarize(self, acceptance_rate: float | None = None) -> dict:
        """The chain_stats.summarize bundle, computed from streamed state."""
        if self.n != self.total_steps:
            raise ValueError(
                f"stream incomplete: {self.n} of {self.total_steps} rows"
            )
        tau, capped = self.tau()
        size = self.n * self.num_chains
        mean = float(self._sum.mean() / self.n)
        sq = float(self._cross[0].sum() / size)
        out = {
            "n_steps": int(self.n),
            "n_chains": int(self.num_chains),
            "tau": round(tau, 3),
            "ess": round(size / tau, 1),
            "ess_per_step": round(size / tau / self.n, 4),
            "split_rhat": round(self.split_rhat(), 4),
            "mean": round(mean, 5),
            "std": round(float(np.sqrt(max(sq - mean**2, 0.0))), 5),
        }
        if capped:
            out["window_capped"] = True
        if acceptance_rate is not None:
            out["acceptance_rate"] = round(float(acceptance_rate), 4)
        return out


def _as_chains_chunk(x, num_chains: int) -> np.ndarray:
    """Coerce one chunk to (t, num_chains) float64 (t >= 1 is enough —
    chunk boundaries need not satisfy the >= 2 rule of _as_chains)."""
    x = np.asarray(x, dtype=np.float64)
    if x.ndim == 1:
        x = x[:, None]
    if x.ndim != 2 or x.shape[1] != num_chains:
        raise ValueError(
            f"chunk must be (t, {num_chains}), got {x.shape}"
        )
    return x


def summarize_stream(
    chunks,
    num_chains: int,
    total_steps: int,
    max_lag: int | None = None,
    acceptance_rate: float | None = None,
    c: float = 5.0,
) -> dict:
    """One-call streaming bundle over an iterable of (t, C) chunks."""
    acc = StreamingChainStats(num_chains, total_steps, max_lag=max_lag, c=c)
    for chunk in chunks:
        acc.update(chunk)
    return acc.summarize(acceptance_rate=acceptance_rate)
