"""Replica-exchange diagnostics — streaming swap statistics.

The tempering driver (repro/tempering) feeds one ``record`` per swap
event; state is O(num_replicas · num_elements) regardless of chain
length, mirroring ``StreamingChainStats``' streaming contract:

  * **per-pair swap acceptance** — attempt/accept counts per adjacent
    pair (r, r+1), pooled over elements and events.  Healthy ladders
    show rates in roughly (0.2, 0.6); a ~0 pair is a bottleneck that
    splits the ladder, a ~1 pair is wasted replicas.
  * **round trips** — walker labels ride the replica slots and move
    with accepted swaps; a round trip is cold → hot → cold, the
    standard measure of how well the ladder actually transports
    configurations across temperatures (swap rates alone can look
    healthy while walkers diffuse nowhere).

Updates are host-side numpy, off the sampling hot path like the chain
estimators (DESIGN.md §Workloads).
"""

from __future__ import annotations

import numpy as np


class SwapStats:
    """Accumulate per-pair acceptance and walker round trips from
    per-swap-event ``record`` calls."""

    def __init__(self, num_replicas: int, elem_shape: tuple = ()):
        if num_replicas < 1:
            raise ValueError(
                f"num_replicas must be >= 1, got {num_replicas}"
            )
        self.num_replicas = num_replicas
        self.elem_shape = tuple(elem_shape)
        self.num_elements = int(np.prod(self.elem_shape, dtype=np.int64))
        n_pairs = num_replicas - 1
        self.attempts = np.zeros(n_pairs, np.int64)
        self.accepts = np.zeros(n_pairs, np.int64)
        self.events = 0
        self.round_trips = 0
        e = self.num_elements
        # walker id currently at slot r, per element — starts as identity
        self._walker = np.tile(
            np.arange(num_replicas, dtype=np.int32)[:, None], (1, e)
        )
        # phase of the walker at slot r: -1 never cold yet, 0 last
        # touched cold (slot 0), 1 cold-then-hot (slot R-1)
        self._phase = np.full((num_replicas, e), -1, np.int8)
        self._phase[0] = 0

    def record(self, attempted, accepted) -> "SwapStats":
        """Consume one swap event: ``attempted`` (R-1,) bool marks the
        active-parity pairs, ``accepted`` (R-1, *elem) bool the
        per-element accepted exchanges (False wherever not attempted)."""
        n_pairs = self.num_replicas - 1
        attempted = np.asarray(attempted, bool).reshape(n_pairs)
        accepted = np.asarray(accepted, bool).reshape(
            n_pairs, self.num_elements
        )
        accepted = accepted & attempted[:, None]
        self.attempts += attempted * self.num_elements
        self.accepts += accepted.sum(axis=1)
        self.events += 1
        # move walker labels (and their phases) along accepted swaps;
        # active-parity pairs are disjoint so sequential apply is exact
        for i in np.nonzero(attempted)[0]:
            m = accepted[i]
            for arr in (self._walker, self._phase):
                lo, hi = arr[i].copy(), arr[i + 1].copy()
                arr[i] = np.where(m, hi, lo)
                arr[i + 1] = np.where(m, lo, hi)
        # round-trip bookkeeping after the move: a cold-slot walker that
        # had reached the hot end completes cold -> hot -> cold
        cold = self._phase[0]
        self.round_trips += int((cold == 1).sum())
        self._phase[0] = 0
        hot = self._phase[-1]
        self._phase[-1] = np.where(hot == 0, np.int8(1), hot)
        return self

    def pair_accept_rates(self) -> list[float]:
        """Acceptance fraction per adjacent pair (NaN if never tried)."""
        with np.errstate(invalid="ignore"):
            rates = self.accepts / np.where(self.attempts > 0,
                                            self.attempts, 1)
        return [
            float(r) if a > 0 else float("nan")
            for r, a in zip(rates, self.attempts)
        ]

    def summary(self) -> dict:
        """The swap bundle merged into CLI/bench rows."""
        total_att = int(self.attempts.sum())
        out = {
            "swap_events": int(self.events),
            "swap_accept_rate": round(
                float(self.accepts.sum()) / total_att, 4
            ) if total_att else float("nan"),
            "pair_accept_rate": [
                round(r, 4) if r == r else r
                for r in self.pair_accept_rates()
            ],
            "round_trips": int(self.round_trips),
        }
        return out
