# Chain diagnostics (DESIGN.md §Workloads): acceptance/flip rate comes
# from the engine itself; this package judges the *samples* — integrated
# autocorrelation time, effective sample size, and split-R-hat over a
# scalar statistic of the chain — and, for tempered runs, the replica-
# exchange health (per-pair swap acceptance, walker round trips).

from repro.diagnostics.chain_stats import (  # noqa: F401
    autocorrelation,
    effective_sample_size,
    integrated_autocorr_time,
    split_rhat,
    summarize,
)
from repro.diagnostics.streaming import (  # noqa: F401
    StreamingChainStats,
    summarize_stream,
)
from repro.diagnostics.swap_stats import SwapStats  # noqa: F401
