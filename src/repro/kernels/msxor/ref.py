"""Pure-jnp oracle for the MSXOR debias kernel."""

from __future__ import annotations

import jax.numpy as jnp


def msxor_fold_ref(raw: jnp.ndarray, n_stages: int) -> jnp.ndarray:
    """raw: (G, M) uint32 with G == 2**n_stages -> (M,) uint32 debiased words.

    Stage i XORs adjacent word pairs, exactly the paper's MSXOR gate tree
    (Fig. 9(a)): 8 raw words R0^0..R0^7 -> 4 -> 2 -> 1.
    """
    if raw.shape[0] != (1 << n_stages):
        raise ValueError(
            f"leading dim must be 2**{n_stages}={1 << n_stages}, got {raw.shape}"
        )
    out = raw
    for _ in range(n_stages):
        out = jnp.bitwise_xor(out[0::2], out[1::2])
    return out[0]


def msxor_uniform_ref(raw: jnp.ndarray, n_stages: int) -> jnp.ndarray:
    """Debiased words -> u in [0, 1): top 24 bits scaled by 2^-24."""
    words = msxor_fold_ref(raw, n_stages)
    return (words >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)
