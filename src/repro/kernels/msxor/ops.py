"""Public jit'd wrappers for the MSXOR kernel (padding + device dispatch)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.msxor.msxor import msxor_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def msxor_fold(raw: jnp.ndarray, n_stages: int = 3, block_m: int = 512):
    """Debias raw biased words: (G, M) uint32 -> (M,) uint32.

    Pads M up to a block multiple, dispatches the Pallas kernel (compiled on
    TPU, interpret elsewhere), and strips the padding.
    """
    g, m = raw.shape
    bm = min(block_m, _round_up(m, 128))
    m_pad = _round_up(m, bm)
    if m_pad != m:
        raw = jnp.pad(raw, ((0, 0), (0, m_pad - m)))
    out = msxor_pallas(
        raw, n_stages=n_stages, block_m=bm, interpret=not _on_tpu()
    )
    return out[:m]


def msxor_uniform(raw: jnp.ndarray, n_stages: int = 3, block_m: int = 512):
    """Fused debias + uniform conversion: (G, M) uint32 -> (M,) float32."""
    g, m = raw.shape
    bm = min(block_m, _round_up(m, 128))
    m_pad = _round_up(m, bm)
    if m_pad != m:
        raw = jnp.pad(raw, ((0, 0), (0, m_pad - m)))
    out = msxor_pallas(
        raw, n_stages=n_stages, to_uniform=True, block_m=bm, interpret=not _on_tpu()
    )
    return out[:m]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
