"""Pallas TPU kernel: MSXOR debias fold (paper §4.2) over uint32 lanes.

One grid step processes a VMEM block of (G, BM) raw words, where
G = 2**n_stages raw streams are folded pairwise on the VPU — each uint32
lane carries 32 independent biased bit-streams, so one block op debiases
32*BM bits.  The fold tree is fully unrolled (n_stages is static, <= 5).

TPU considerations:
  * block last dim BM is a multiple of 128 (lane width); G rides the
    sublane dimension (8-aligned for n_stages=3 — the paper's exact config).
  * output is either the debiased uint32 word or a fused conversion to
    u in [0,1) (top 24 bits * 2^-24), saving one HBM round-trip for the
    downstream accept/reject compare.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fold_block(raw_block: jnp.ndarray, n_stages: int) -> jnp.ndarray:
    out = raw_block
    for _ in range(n_stages):
        out = jnp.bitwise_xor(out[0::2], out[1::2])
    return out[0]


def _msxor_kernel(raw_ref, out_ref, *, n_stages: int, to_uniform: bool):
    folded = _fold_block(raw_ref[...], n_stages)
    if to_uniform:
        out_ref[...] = (folded >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(
            2.0**-24
        )
    else:
        out_ref[...] = folded


@functools.partial(
    jax.jit, static_argnames=("n_stages", "to_uniform", "block_m", "interpret")
)
def msxor_pallas(
    raw: jnp.ndarray,
    n_stages: int = 3,
    to_uniform: bool = False,
    block_m: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """raw: (G, M) uint32, G == 2**n_stages, M % block_m == 0 (padded by caller).

    Returns (M,) uint32 debiased words, or (M,) float32 uniforms if
    ``to_uniform``.
    """
    g, m = raw.shape
    if g != (1 << n_stages):
        raise ValueError(f"G must be 2**{n_stages}, got {g}")
    block_m = min(block_m, m)
    if m % block_m != 0:
        raise ValueError(f"M={m} not divisible by block_m={block_m}")
    out_dtype = jnp.float32 if to_uniform else jnp.uint32
    kernel = functools.partial(
        _msxor_kernel, n_stages=n_stages, to_uniform=to_uniform
    )
    return pl.pallas_call(
        kernel,
        grid=(m // block_m,),
        in_specs=[pl.BlockSpec((g, block_m), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), out_dtype),
        interpret=interpret,
    )(raw)
