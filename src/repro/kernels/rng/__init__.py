# Shared counter-based RNG (DESIGN.md §Randomness): one Threefry-2x32
# implementation in plain uint32 jnp ops, traced both into the fused
# Pallas kernel bodies and into the scan-side reference backend, so the
# randomness="fused" streams are bit-identical across executors by
# construction.

from repro.kernels.rng.rng import (  # noqa: F401
    FLIP_SALT,
    U_SALT,
    flips_at,
    key_words,
    raw_draw,
    site_index,
    step_key,
    threefry2x32,
    threshold_u32,
    uniform_at,
)
