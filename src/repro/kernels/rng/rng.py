"""Counter-based in-kernel RNG — the paper's RNG-inside-the-memory, fused.

Every other randomness backend in this repo materialises operand blocks
on host and ships them to the executor.  This module is the third way
(DESIGN.md §Randomness): a *counter-based* generator whose draw for
``(chain, absolute step t, site s)`` is a pure function of the chain
key and the ``(t, s)`` counter, implemented entirely in elementwise
uint32 arithmetic — add/xor/rotate/shift/compare — so the *same
functions* trace both into the Pallas kernel bodies and into the
scan-side reference backend (``samplers.FusedRandomness``).  Bit-parity
between executors is therefore by construction, not by mirroring.

The block cipher is Threefry-2x32 with 20 rounds (Salmon et al.,
"Parallel random numbers: as easy as 1, 2, 3" — the same cipher behind
``jax.random``'s default PRNG, reimplemented here because the kernel
body cannot call ``jax.random``).  Statistically it passes Crush-level
test batteries; its per-bit bias is 0 by construction, comfortably
inside the paper's <1e-5 deviation budget for the accurate-[0,1] RNG
(empirically pinned in tests/test_fused_rng.py).

Derivation contract (mirrors the engine's ``fold_in`` chain, DESIGN.md
§Chains-axis):

    chain fold   jax-side:  key_c = jax.random.fold_in(key, chain_id)
    key words    (k0, k1) = key_words(key_c)          # 2x uint32
    step fold    (s0, s1) = step_key(k0, k1, t)       # t = absolute step
    site draw    bits     = threefry2x32(s0, s1, site, salt)[0]

``site`` is the linear index into the *per-chain* state block (row-major
over the solo-run shape), and ``salt`` separates the operand streams —
``U_SALT`` for the accept/flip uniform, ``FLIP_SALT + i`` for proposal
bit-plane i — so consuming one operand can never perturb another (the
``need_flips`` invariance, DESIGN.md §Collection).  Everything after the
chain fold runs wherever the consumer lives: on host for the scan
reference, inside the kernel for the fused executors, with only the two
carried key words crossing the operand boundary.

Where available, TPU hardware PRNG primitives (``pltpu.prng_seed`` /
``prng_random_bits``) could replace the cipher's draw stage, but they
have no interpret-mode lowering and draw from a different stream, which
would break the scan<->pallas bit-parity contract; this repo keeps the
portable cipher everywhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Threefry-2x32 rotation schedule: rounds 4i..4i+3 use ROTATIONS[i % 2].
_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
# Key-schedule parity constant (the 2x32 slice of the Threefish C240).
_PARITY = 0x1BD11BDA

# Operand-stream salts (second counter word).  FLIP planes occupy
# [FLIP_SALT, FLIP_SALT + 32); U_SALT lives far outside that window.
U_SALT = 0x554E4946  # "UNIF"
FLIP_SALT = 0x464C4950  # "FLIP"


def _u32(x) -> jnp.ndarray:
    if isinstance(x, int):  # python ints coerce via int32 and overflow
        return jnp.uint32(x & 0xFFFFFFFF)
    return jnp.asarray(x).astype(jnp.uint32)


def _rotl(x, r: int):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32(k0, k1, x0, x1):
    """One Threefry-2x32-20 block: counter (x0, x1) under key (k0, k1).

    All inputs broadcast together; everything is elementwise uint32
    add/xor/rotate, so this traces identically on host, under scan, and
    inside a Pallas kernel body (interpret or compiled).
    """
    k0, k1, x0, x1 = _u32(k0), _u32(k1), _u32(x0), _u32(x1)
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(_PARITY))
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for i in range(5):
        for r in _ROTATIONS[i % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, r)
            x1 = x1 ^ x0
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x0, x1


def key_words(key) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The two uint32 key words of a jax PRNG key (typed or raw)."""
    if hasattr(key, "dtype") and jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    flat = _u32(key).reshape(-1)
    return flat[0], flat[1]


def step_key(k0, k1, t) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fold absolute step ``t`` into the chain key (one cipher block)."""
    return threefry2x32(k0, k1, _u32(t), jnp.uint32(0))


def raw_draw(s0, s1, site, salt: int) -> jnp.ndarray:
    """One uint32 of stream ``salt`` at each ``site`` under step key."""
    return threefry2x32(s0, s1, _u32(site), jnp.uint32(salt))[0]


def uniform_at(s0, s1, site) -> jnp.ndarray:
    """u ~ U[0,1) at each ``site``: the top 24 bits of the U-stream draw,
    scaled — (bits >> 8) < 2^24 is exactly representable in float32, so
    the conversion is deterministic across executors."""
    bits = raw_draw(s0, s1, site, U_SALT)
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(
        1.0 / (1 << 24)
    )


def threshold_u32(p: float) -> int:
    """Static uint32 threshold with P(draw < threshold) = p."""
    return max(0, min(0xFFFFFFFF, int(round(float(p) * 4294967296.0))))


def flips_at(s0, s1, site, nbits: int, p_u32: int) -> jnp.ndarray:
    """Flip word at each ``site``: low ``nbits`` bit-planes i.i.d.
    Bernoulli(p), plane i from stream ``FLIP_SALT + i``."""
    word = jnp.zeros_like(_u32(site))
    for i in range(nbits):
        plane = raw_draw(s0, s1, site, FLIP_SALT + i) < jnp.uint32(p_u32)
        word = word | (plane.astype(jnp.uint32) << jnp.uint32(i))
    return word


def site_index(shape: tuple) -> jnp.ndarray:
    """Row-major linear site index over a per-chain state block."""
    n = 1
    for d in shape:
        n *= int(d)
    return jnp.arange(n, dtype=jnp.uint32).reshape(shape)
