"""Pallas TPU kernel: fused Metropolis-Hastings chain (paper §4, §5.2).

The entire K-step MH loop runs inside one kernel invocation with the chain
state resident in VREG/VMEM — the TPU analogue of the paper's
"the entire MCMC processing happens locally inside the macro":

  * the log-prob table block (the "stored distribution") sits in VMEM,
  * propose = XOR with a biased flip word        (block-wise pseudo-read),
  * accept test vs a debiased uniform            (accurate [0,1] RNG),
  * state update = select                        (in-memory copy),
  * only the kept sample stream is written back  (R/W circuits touched once
    per step instead of five times — same saving the paper measures).

Random inputs (flip words, uniforms) are kernel *operands* on CPU/interpret;
on real TPU hardware the `hw_prng` variant generates them in-kernel from the
per-core PRNG (pltpu.prng_random_bits), restoring the paper's zero-traffic
randomness.  (Verified: pltpu.prng_* does not lower in interpret mode, so
that path is TPU-only and guarded.)

Grid: (B, C // BLOCK_C) — B independent targets (e.g. batch rows of logits),
C chains per target ("compartments").  BLOCK_C rides the 128-wide lane axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import rng


def _mh_kernel(
    table_ref,    # (1, V) float32
    init_ref,     # (1, BC) uint32
    flips_ref,    # (K, 1, BC) uint32
    u_ref,        # (K, 1, BC) float32
    samples_ref,  # (K, 1, BC) uint32  out
    accept_ref,   # (1, BC) int32      out
    *,
    nbits: int,
    n_steps: int,
):
    table = table_ref[0, :]
    vocab = table.shape[0]
    mask = jnp.uint32((1 << nbits) - 1)
    state0 = init_ref[0, :]

    def lookup(words):
        safe = jnp.minimum(words, jnp.uint32(vocab - 1)).astype(jnp.int32)
        vals = jnp.take(table, safe)
        return jnp.where(words < vocab, vals, -jnp.inf)

    logp0 = lookup(state0)

    def body(k, carry):
        state, logp, acc = carry
        cand = jnp.bitwise_xor(state, flips_ref[k, 0, :] & mask)
        logp_cand = lookup(cand)
        delta = (logp_cand - logp).astype(jnp.float32)
        accept = jnp.logical_and(
            u_ref[k, 0, :] < jnp.exp(jnp.minimum(delta, 0.0)),
            jnp.isfinite(logp_cand),
        )
        state = jnp.where(accept, cand, state)       # in-memory copy
        logp = jnp.where(accept, logp_cand, logp)
        samples_ref[k, 0, :] = state
        return state, logp, acc + accept.astype(jnp.int32)

    _, _, acc = jax.lax.fori_loop(
        0, n_steps, body, (state0, logp0, jnp.zeros_like(state0, jnp.int32))
    )
    accept_ref[0, :] = acc


@functools.partial(
    jax.jit, static_argnames=("nbits", "block_c", "interpret")
)
def mh_chain_pallas(
    table: jnp.ndarray,   # (B, V) float32
    init: jnp.ndarray,    # (B, C) uint32
    flips: jnp.ndarray,   # (K, B, C) uint32
    u: jnp.ndarray,       # (K, B, C) float32
    nbits: int,
    block_c: int = 256,
    interpret: bool = True,
):
    """Fused K-step MH over (B targets x C chains). C % block_c == 0."""
    b, vocab = table.shape
    k_steps, b2, c = flips.shape
    if (b2, c) != (b, init.shape[1]) or u.shape != flips.shape:
        raise ValueError(
            f"shape mismatch: table={table.shape} init={init.shape} "
            f"flips={flips.shape} u={u.shape}"
        )
    block_c = min(block_c, c)
    if c % block_c != 0:
        raise ValueError(f"C={c} not divisible by block_c={block_c}")

    kernel = functools.partial(_mh_kernel, nbits=nbits, n_steps=k_steps)
    samples, accept = pl.pallas_call(
        kernel,
        grid=(b, c // block_c),
        in_specs=[
            pl.BlockSpec((1, vocab), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((k_steps, 1, block_c), lambda i, j: (0, i, j)),
            pl.BlockSpec((k_steps, 1, block_c), lambda i, j: (0, i, j)),
        ],
        out_specs=[
            pl.BlockSpec((k_steps, 1, block_c), lambda i, j: (0, i, j)),
            pl.BlockSpec((1, block_c), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k_steps, b, c), jnp.uint32),
            jax.ShapeDtypeStruct((b, c), jnp.int32),
        ],
        interpret=interpret,
    )(table.astype(jnp.float32), init.astype(jnp.uint32), flips, u)
    return samples, accept


def _mh_fused_kernel(
    table_ref,    # (1, V) float32
    init_ref,     # (1, BC) uint32
    k0_ref,       # (1, BC) uint32 per-column chain-key word 0
    k1_ref,       # (1, BC) uint32 per-column chain-key word 1
    t0_ref,       # (1, BC) int32 per-column absolute-step base
    samples_ref,  # (K, 1, BC) uint32  out
    accept_ref,   # (1, BC) int32      out
    *,
    nbits: int,
    n_steps: int,
    cc: int,
    p_u32: int,
):
    """In-kernel-RNG MH chain (DESIGN.md §Randomness): instead of (K,)
    operand planes, the kernel carries two uint32 key words per column
    and derives the flip word + accept uniform for absolute step
    ``t0 + k`` at site ``row * cc + col % cc`` with the shared counter
    cipher (kernels/rng) — the same functions the scan-side
    ``FusedRandomness`` reference draws through, so parity is by
    construction.  The absolute-step base ``t0`` is a per-column
    *operand* (not a compile-time constant): columns at different
    stream offsets — the serving tier's packed slots, tempering
    segments — share one compiled program, and the counter arithmetic
    is identical either way, so the stream is unchanged by
    construction.  ``cc`` is the per-chain column count (chains fold
    chain-major into the compartment axis, DESIGN.md §Chains-axis)."""
    table = table_ref[0, :]
    vocab = table.shape[0]
    mask = jnp.uint32((1 << nbits) - 1)
    state0 = init_ref[0, :]
    k0 = k0_ref[0, :]
    k1 = k1_ref[0, :]
    t0 = t0_ref[0, :].astype(jnp.uint32)

    block_c = state0.shape[0]
    i = pl.program_id(0)
    j = pl.program_id(1)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, block_c), 1)[0]
    col = j * block_c + lane
    site = (i * cc + col % cc).astype(jnp.uint32)

    def lookup(words):
        safe = jnp.minimum(words, jnp.uint32(vocab - 1)).astype(jnp.int32)
        vals = jnp.take(table, safe)
        return jnp.where(words < vocab, vals, -jnp.inf)

    logp0 = lookup(state0)

    def body(k, carry):
        state, logp, acc = carry
        s0, s1 = rng.step_key(k0, k1, t0 + k.astype(jnp.uint32))
        flip = rng.flips_at(s0, s1, site, nbits, p_u32)
        u = rng.uniform_at(s0, s1, site)
        cand = jnp.bitwise_xor(state, flip & mask)
        logp_cand = lookup(cand)
        delta = (logp_cand - logp).astype(jnp.float32)
        accept = jnp.logical_and(
            u < jnp.exp(jnp.minimum(delta, 0.0)),
            jnp.isfinite(logp_cand),
        )
        state = jnp.where(accept, cand, state)       # in-memory copy
        logp = jnp.where(accept, logp_cand, logp)
        samples_ref[k, 0, :] = state
        return state, logp, acc + accept.astype(jnp.int32)

    _, _, acc = jax.lax.fori_loop(
        0, n_steps, body, (state0, logp0, jnp.zeros_like(state0, jnp.int32))
    )
    accept_ref[0, :] = acc


@functools.partial(
    jax.jit,
    static_argnames=(
        "nbits", "n_steps", "cc", "p_u32", "block_c", "interpret"
    ),
)
def mh_chain_pallas_fused(
    table: jnp.ndarray,   # (B, V) float32
    init: jnp.ndarray,    # (B, C) uint32
    k0c: jnp.ndarray,     # (C,) uint32 per-column chain-key word 0
    k1c: jnp.ndarray,     # (C,) uint32 per-column chain-key word 1
    t0c: jnp.ndarray,     # (C,) int32 per-column absolute-step base
    *,
    nbits: int,
    n_steps: int,
    cc: int,
    p_u32: int,
    block_c: int = 256,
    interpret: bool = True,
):
    """Fused K-step MH with in-kernel RNG: zero per-step randomness
    operands — only the per-column key words + step base (12
    bytes/column/chunk) cross the kernel boundary.  ``t0c`` is the
    absolute step of the first chunk row, per column, as a *runtime
    operand* so chunks/slots at different stream offsets reuse one
    compiled program; ``cc`` the per-chain column count.
    C % block_c == 0."""
    b, vocab = table.shape
    c = init.shape[1]
    if k0c.shape != (c,) or k1c.shape != (c,) or t0c.shape != (c,):
        raise ValueError(
            f"per-column key/step words must be ({c},), got "
            f"{k0c.shape}/{k1c.shape}/{t0c.shape}"
        )
    block_c = min(block_c, c)
    if c % block_c != 0:
        raise ValueError(f"C={c} not divisible by block_c={block_c}")

    kernel = functools.partial(
        _mh_fused_kernel,
        nbits=nbits, n_steps=n_steps, cc=cc, p_u32=p_u32,
    )
    samples, accept = pl.pallas_call(
        kernel,
        grid=(b, c // block_c),
        in_specs=[
            pl.BlockSpec((1, vocab), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_c), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_c), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_c), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((n_steps, 1, block_c), lambda i, j: (0, i, j)),
            pl.BlockSpec((1, block_c), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_steps, b, c), jnp.uint32),
            jax.ShapeDtypeStruct((b, c), jnp.int32),
        ],
        interpret=interpret,
    )(
        table.astype(jnp.float32),
        init.astype(jnp.uint32),
        k0c.reshape(1, c),
        k1c.reshape(1, c),
        t0c.astype(jnp.int32).reshape(1, c),
    )
    return samples, accept


def mh_chain_pallas_hwprng(*args, **kwargs):
    """TPU-only variant that seeds pltpu's per-core hardware PRNG instead
    of the portable counter cipher (``mh_chain_pallas_fused`` is the
    production in-kernel-RNG path — same zero operand traffic, and its
    stream is executor-portable).

    pltpu.prng_seed/prng_random_bits have no CPU/interpret lowering
    (verified NotImplementedError on this container) *and* draw from a
    hardware stream the scan reference cannot reproduce, so this stays a
    TPU-only stub.
    """
    if jax.default_backend() != "tpu":
        raise NotImplementedError(
            "hw_prng MH kernel requires a TPU backend; use "
            "mh_chain_pallas_fused (portable in-kernel counter RNG) or "
            "mh_chain_pallas with explicit randomness operands."
        )
    raise NotImplementedError(
        "TPU hw-PRNG path: seed pltpu.prng_seed(seed + program_id), draw "
        "nbits random words per step, threshold at p_bfr * 2^32, pack bit "
        "planes, and XOR-fold 2^stages draws for u. Not reachable in this "
        "CPU container."
    )
