"""Public jit'd wrappers for the fused MH kernel.

``mh_sample`` is the raw kernel entry (randomness as operands).
``mh_sample_with_rng`` generates the paper-faithful randomness — biased flip
words from pseudo-read bit-planes, uniforms via the MSXOR kernel — and runs
the fused chain.  ``sample_tokens_fused`` is the serving-path entry: one
chain per batch row over that row's logits (softmax-free token sampling).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bitcell
from repro.kernels.mh.mh import mh_chain_pallas
from repro.kernels.msxor import ops as msxor_ops


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def mh_sample(table, init, flips, u, nbits: int, block_c: int = 256):
    """Pad the chain axis to a lane multiple and run the fused kernel.

    Emits every step of the chunk; the engine's shared chunk scheduler
    (``_drive_pallas_chunks``) slices what its collection mode keeps
    into a preallocated stream buffer (DESIGN.md §Collection)."""
    b, c = init.shape
    bc = min(block_c, _round_up(c, 128))
    c_pad = _round_up(c, bc)
    if c_pad != c:
        pad = c_pad - c
        init = jnp.pad(init, ((0, 0), (0, pad)))
        flips = jnp.pad(flips, ((0, 0), (0, 0), (0, pad)))
        u = jnp.pad(u, ((0, 0), (0, 0), (0, pad)), constant_values=1.0)
    samples, accept = mh_chain_pallas(
        table, init, flips, u, nbits=nbits, block_c=bc, interpret=not _on_tpu()
    )
    return samples[:, :, :c], accept[:, :c]


class MHRandomness(NamedTuple):
    flips: jnp.ndarray  # (K, B, C) uint32 biased flip words
    u: jnp.ndarray      # (K, B, C) float32 MSXOR-debiased uniforms


def generate_randomness(
    key,
    n_steps: int,
    batch: int,
    chains: int,
    p_bfr: float,
    rng_stages: int = 3,
) -> MHRandomness:
    """Paper-faithful randomness: pseudo-read bit-planes + MSXOR uniforms.

    Materialises the full (K, B, C) operand block up front — fine for
    kernel tests/benchmarks, but long chains should stream chunks via
    ``repro.samplers.CIMRandomness`` instead (DESIGN.md §2)."""
    k_flip, k_u = jax.random.split(key)
    flips = bitcell.raw_random_words(
        k_flip, p_bfr, (n_steps, batch, chains), nbits=32
    )
    g = 1 << rng_stages
    m = n_steps * batch * chains
    raw_u = bitcell.raw_random_words(k_u, p_bfr, (g, m), nbits=32)
    u = msxor_ops.msxor_uniform(raw_u, n_stages=rng_stages).reshape(
        n_steps, batch, chains
    )
    return MHRandomness(flips=flips, u=u)


def mh_sample_with_rng(
    key,
    table,
    n_steps: int,
    chains: int = 1,
    p_bfr: float = 0.45,
    rng_stages: int = 3,
    init: jnp.ndarray | None = None,
    nbits: int | None = None,
):
    """End-to-end fused sampling from a (B, V) log-prob table."""
    b, vocab = table.shape
    if nbits is None:
        nbits = max(1, math.ceil(math.log2(vocab)))
    if init is None:
        init = jnp.broadcast_to(
            jnp.argmax(table, axis=-1).astype(jnp.uint32)[:, None], (b, chains)
        )
    rnd = generate_randomness(key, n_steps, b, chains, p_bfr, rng_stages)
    return mh_sample(table, init, rnd.flips, rnd.u, nbits=nbits)


def sample_tokens_fused(
    key,
    logits,
    n_steps: int = 64,
    temperature: float = 1.0,
    p_bfr: float = 0.45,
    prev_tokens: jnp.ndarray | None = None,
):
    """Serving-path token sampler: one fused MH chain per batch row.

    Thin wrapper over the unified engine with pallas execution forced —
    kept so kernel-level callers keep a one-call entry.  Returns
    (tokens (B,) int32, acceptance_rate scalar).
    """
    from repro import samplers  # deferred: samplers imports this module

    engine = samplers.MHEngine(
        samplers.EngineConfig(p_bfr=p_bfr, execution="pallas")
    )
    tokens, result = engine.sample_tokens(
        key,
        logits,
        n_steps=n_steps,
        temperature=temperature,
        init_tokens=prev_tokens,
    )
    return tokens, result.acceptance_rate
