"""Public jit'd wrappers for the fused MH kernel.

``mh_sample`` is the raw kernel entry (randomness as operands).
``mh_sample_with_rng`` generates the paper-faithful randomness — biased flip
words from pseudo-read bit-planes, uniforms via the MSXOR kernel — and runs
the fused chain.  ``sample_tokens_fused`` is the serving-path entry: one
chain per batch row over that row's logits (softmax-free token sampling).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import rng
from repro.kernels.mh.mh import mh_chain_pallas, mh_chain_pallas_fused


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def mh_sample(table, init, flips, u, nbits: int, block_c: int = 256):
    """Pad the chain axis to a lane multiple and run the fused kernel.

    Emits every step of the chunk; the engine's shared chunk scheduler
    (``_drive_pallas_chunks``) slices what its collection mode keeps
    into a preallocated stream buffer (DESIGN.md §Collection)."""
    b, c = init.shape
    bc = min(block_c, _round_up(c, 128))
    c_pad = _round_up(c, bc)
    if c_pad != c:
        pad = c_pad - c
        init = jnp.pad(init, ((0, 0), (0, pad)))
        flips = jnp.pad(flips, ((0, 0), (0, 0), (0, pad)))
        u = jnp.pad(u, ((0, 0), (0, 0), (0, pad)), constant_values=1.0)
    samples, accept = mh_chain_pallas(
        table, init, flips, u, nbits=nbits, block_c=bc, interpret=not _on_tpu()
    )
    return samples[:, :, :c], accept[:, :c]


def mh_sample_fused(
    table, init, k0c, k1c, *, n_steps: int, t0, nbits: int,
    p_bfr: float, cc: int, block_c: int = 256,
):
    """In-kernel-RNG edition of ``mh_sample`` (randomness="fused"): the
    chunk's randomness never exists as an operand — ``k0c``/``k1c`` are
    the per-column chain-key words (8 bytes per column per chunk, vs
    8 bytes per site per *step* for shipped operands) and the kernel
    derives each step's flip word + uniform from the ``(t0 + k, site)``
    counter (DESIGN.md §Randomness).  ``t0`` is an int or per-column
    (C,) int32 array — a runtime operand, so columns at different
    absolute steps (packed serving slots, successive chunks) share one
    compiled program.  ``cc`` is the per-chain column count (the solo
    chain width; multi-chain callers fold chains chain-major).  Padding
    columns carry zero keys; their chains evolve under the zero-key
    stream and are sliced off like the operand path's u=1.0 padding."""
    b, c = init.shape
    t0c = jnp.broadcast_to(jnp.asarray(t0, jnp.int32), (c,))
    bc = min(block_c, _round_up(c, 128))
    c_pad = _round_up(c, bc)
    if c_pad != c:
        pad = c_pad - c
        init = jnp.pad(init, ((0, 0), (0, pad)))
        k0c = jnp.pad(k0c, (0, pad))
        k1c = jnp.pad(k1c, (0, pad))
        t0c = jnp.pad(t0c, (0, pad))
    samples, accept = mh_chain_pallas_fused(
        table, init, k0c, k1c, t0c, nbits=nbits, n_steps=n_steps, cc=cc,
        p_u32=rng.threshold_u32(p_bfr), block_c=bc, interpret=not _on_tpu(),
    )
    return samples[:, :, :c], accept[:, :c]


class MHRandomness(NamedTuple):
    flips: jnp.ndarray  # (K, B, C) uint32 biased flip words
    u: jnp.ndarray      # (K, B, C) float32 MSXOR-debiased uniforms


def generate_randomness(
    key,
    n_steps: int,
    batch: int,
    chains: int,
    p_bfr: float,
    rng_stages: int = 3,
) -> MHRandomness:
    """Paper-faithful randomness: pseudo-read bit-planes + MSXOR uniforms.

    Thin materialising wrapper over ``samplers.CIMRandomness`` — the one
    place the pseudo-read + MSXOR operand recipe (and its
    ``(k_flip, k_u)`` step-key split) lives, so kernel-level callers and
    the engine draw the *same* stream.  Materialises the full (K, B, C)
    operand block up front — fine for kernel tests/benchmarks, but long
    chains should stream chunks through the backend (DESIGN.md §2)."""
    from repro.samplers.randomness import (  # deferred: samplers imports us
        CIMRandomness,
    )

    backend = CIMRandomness(
        p_bfr=p_bfr, rng_p_bfr=p_bfr, rng_bit_width=32,
        rng_stages=rng_stages,
    )
    flips, u = backend.chunk(key, 0, n_steps, (batch, chains), nbits=32)
    return MHRandomness(flips=flips, u=u)


def mh_sample_with_rng(
    key,
    table,
    n_steps: int,
    chains: int = 1,
    p_bfr: float = 0.45,
    rng_stages: int = 3,
    init: jnp.ndarray | None = None,
    nbits: int | None = None,
):
    """End-to-end fused sampling from a (B, V) log-prob table."""
    b, vocab = table.shape
    if nbits is None:
        nbits = max(1, math.ceil(math.log2(vocab)))
    if init is None:
        init = jnp.broadcast_to(
            jnp.argmax(table, axis=-1).astype(jnp.uint32)[:, None], (b, chains)
        )
    rnd = generate_randomness(key, n_steps, b, chains, p_bfr, rng_stages)
    return mh_sample(table, init, rnd.flips, rnd.u, nbits=nbits)


def sample_tokens_fused(
    key,
    logits,
    n_steps: int = 64,
    temperature: float = 1.0,
    p_bfr: float = 0.45,
    prev_tokens: jnp.ndarray | None = None,
):
    """Serving-path token sampler: one fused MH chain per batch row.

    Thin wrapper over the unified engine with pallas execution forced —
    kept so kernel-level callers keep a one-call entry.  Returns
    (tokens (B,) int32, acceptance_rate scalar).
    """
    from repro import samplers  # deferred: samplers imports this module

    engine = samplers.MHEngine(
        samplers.EngineConfig(p_bfr=p_bfr, execution="pallas")
    )
    tokens, result = engine.sample_tokens(
        key,
        logits,
        n_steps=n_steps,
        temperature=temperature,
        init_tokens=prev_tokens,
    )
    return tokens, result.acceptance_rate
