"""Pure-jnp oracle for the fused MH chain kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mh_chain_ref(
    table: jnp.ndarray,   # (B, V) float log-probs (unnormalised)
    init: jnp.ndarray,    # (B, C) uint32 initial words
    flips: jnp.ndarray,   # (K, B, C) uint32 biased flip words
    u: jnp.ndarray,       # (K, B, C) float32 uniforms
    nbits: int,
):
    """Reference MH semantics, bit-exact w.r.t. the kernel.

    Returns (samples (K, B, C) uint32, accept_count (B, C) int32).
    """
    vocab = table.shape[-1]
    mask = jnp.uint32((1 << nbits) - 1)
    neg_inf = jnp.asarray(-jnp.inf, dtype=table.dtype)

    def lookup(words):
        safe = jnp.minimum(words, jnp.uint32(vocab - 1)).astype(jnp.int32)
        vals = jnp.take_along_axis(table, safe, axis=-1)
        return jnp.where(words < vocab, vals, neg_inf)

    init = init.astype(jnp.uint32)
    logp0 = lookup(init)

    def body(carry, xs):
        state, logp, acc = carry
        flip, uu = xs
        cand = jnp.bitwise_xor(state, flip & mask)
        logp_cand = lookup(cand)
        delta = (logp_cand - logp).astype(jnp.float32)
        accept = jnp.logical_and(
            uu < jnp.exp(jnp.minimum(delta, 0.0)), jnp.isfinite(logp_cand)
        )
        state = jnp.where(accept, cand, state)
        logp = jnp.where(accept, logp_cand, logp)
        return (state, logp, acc + accept.astype(jnp.int32)), state

    (state, logp, acc), samples = jax.lax.scan(
        body, (init, logp0, jnp.zeros(init.shape, jnp.int32)), (flips, u)
    )
    return samples, acc
