"""Public jit'd wrapper for the fused checkerboard Gibbs kernel.

``gibbs_sweep`` is the engine-facing entry (randomness as operands),
mirroring ``kernels.mh.ops.mh_sample``.  A periodic lattice cannot be
zero-padded the way the MH chain axis can (padding would change every
edge site's neighbourhood), so no padding happens here: compiled TPU
execution wants W as a multiple of the 128-wide lane, while interpret
mode (CPU) takes any lattice shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gibbs.gibbs import (
    gibbs_chain_pallas,
    gibbs_chain_pallas_fused,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gibbs_sweep(init, u, logit_fn, parity0=0, consts: tuple = ()):
    """Run K fused checkerboard half-sweeps from ``init`` (B, H, W).

    ``logit_fn`` is the model's per-site conditional logit (e.g.
    ``IsingModel.conditional_logit``) — the same function the scan
    executor steps, traced into the kernel.  ``u`` is the (K, B, H, W)
    accurate-[0,1] uniform stream (one draw per site per half-sweep —
    inactive-colour draws are discarded, matching the scan executor so
    the streams stay aligned).  ``consts`` carries a model's array
    parameters (spin-glass couplings) as kernel operands —
    ``logit_fn(state, *consts)`` — since the kernel trace cannot capture
    array closures.  Returns (samples (K, B, H, W) uint32, flip_count
    (B, H, W) int32).

    Gibbs reads no flip words, so the engine sources ``u`` through the
    operand-lean ``RandomnessBackend.chunk(..., need_flips=False)`` path
    (same u stream, no pseudo-read planes) and its shared chunk
    scheduler keeps/drops the returned samples per its collection mode
    (DESIGN.md §Collection) — this wrapper always emits the full chunk.

    ``parity0`` may be a python int or a per-lattice ``(B,)`` array —
    it is a runtime operand of the kernel, so heterogeneous-offset
    lattices (packed serving slots) share one compiled program.
    """
    b = init.shape[0]
    parity0b = jnp.broadcast_to(jnp.asarray(parity0, jnp.int32), (b,))
    return gibbs_chain_pallas(
        init,
        u,
        logit_fn,
        parity0=parity0b,
        interpret=not _on_tpu(),
        consts=tuple(consts),
    )


def gibbs_sweep_fused(
    init, k0b, k1b, logit_fn, *, n_steps: int, t0, lat_b: int,
    consts: tuple = (),
):
    """In-kernel-RNG edition of ``gibbs_sweep`` (randomness="fused"): no
    uniform operand planes — ``k0b``/``k1b`` are the per-lattice
    chain-key words (8 bytes per lattice per chunk, vs 4 bytes per site
    per *step* shipped under host/cim) and the kernel derives every
    half-sweep's site uniforms from the ``(t0 + k, site)`` counter
    (DESIGN.md §Randomness).  ``t0`` — an int or per-lattice ``(B,)``
    array, a runtime operand — is the absolute step of the first
    half-sweep (it carries the checkerboard parity); ``lat_b`` the
    per-chain lattice-batch size (solo callers pass init.shape[0])."""
    b = init.shape[0]
    t0b = jnp.broadcast_to(jnp.asarray(t0, jnp.int32), (b,))
    return gibbs_chain_pallas_fused(
        init,
        k0b,
        k1b,
        t0b,
        logit_fn,
        n_steps=int(n_steps),
        lat_b=int(lat_b),
        interpret=not _on_tpu(),
        consts=tuple(consts),
    )
