"""Pallas TPU kernel: fused checkerboard Gibbs sweep over a 2-D MRF.

The K-half-sweep Gibbs loop runs inside one kernel invocation with the
whole lattice resident in VREG/VMEM — the Gibbs analogue of the fused MH
chain (kernels/mh/mh.py).  Per half-sweep:

  * conditional logit from the model's ``logit_fn`` (e.g. the Ising
    4-neighbour coupling, periodic boundary via rolls) — the *same*
    function the scan executor calls, traced into the kernel as a static
    closure, so scan/pallas share one conditional implementation,
  * conditional flip  = u < sigmoid(logit)  (accurate [0,1] RNG operand —
    the same uniform stream the MH accept test consumes),
  * only the active checkerboard colour is rewritten (the two-colour
    sweep keeps every update's neighbourhood fixed, so all sites of one
    colour flip in parallel exactly as the macro's compartments do).

Random inputs are kernel *operands* on CPU/interpret, exactly like the MH
kernel; the in-kernel hw-PRNG variant remains TPU-only future work.

Grid: (B,) — B independent lattices, one (H, W) block each.  W rides the
128-wide lane axis; a periodic lattice cannot be zero-padded, so compiled
TPU execution wants W as a lane multiple while interpret mode (CPU) takes
any shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import rng


def _gibbs_kernel(
    init_ref,     # (1, H, W) uint32 {0,1} spins
    u_ref,        # (K, 1, H, W) float32
    parity_ref,   # (1, 1) int32 this lattice's starting parity
    *rest,        # n_consts broadcast model refs, then the two outputs:
                  #   samples (K, 1, H, W) uint32, flips (1, H, W) int32
    logit_fn,
    n_steps: int,
    n_consts: int,
):
    const_refs, (samples_ref, flips_ref) = rest[:n_consts], rest[n_consts:]
    consts = tuple(ref[...] for ref in const_refs)
    state0 = init_ref[0]
    parity0 = parity_ref[0, 0]
    h, w = state0.shape
    row = jax.lax.broadcasted_iota(jnp.int32, (h, w), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (h, w), 1)
    checker = (row + col) % 2

    def body(k, carry):
        state, nflips = carry
        parity = (parity0 + k) % 2
        new = (
            u_ref[k, 0] < jax.nn.sigmoid(logit_fn(state, *consts))
        ).astype(jnp.uint32)
        nxt = jnp.where(checker == parity, new, state)
        samples_ref[k, 0] = nxt
        return nxt, nflips + (nxt != state).astype(jnp.int32)

    _, nflips = jax.lax.fori_loop(
        0, n_steps, body, (state0, jnp.zeros_like(state0, jnp.int32))
    )
    flips_ref[0] = nflips


@functools.partial(
    jax.jit, static_argnames=("logit_fn", "interpret")
)
def gibbs_chain_pallas(
    init: jnp.ndarray,  # (B, H, W) uint32 {0,1} spins
    u: jnp.ndarray,     # (K, B, H, W) float32
    logit_fn,           # (H, W) state [, *consts] -> (H, W) logit of s=1
    parity0=0,          # int or (B,) int32 starting checkerboard parity
    interpret: bool = True,
    consts: tuple = (),
):
    """Fused K-half-sweep checkerboard Gibbs over B independent lattices.

    ``logit_fn`` must be hashable (it rides a jit static argument) — a
    bound method of a frozen model dataclass qualifies.  Models whose
    conditional closes over *array* parameters (e.g. spin-glass bond
    couplings) cannot capture them in the kernel trace; they arrive as
    ``consts`` operands instead, broadcast to every grid step, and
    ``logit_fn(state, *consts)`` threads them back into the one shared
    conditional implementation (DESIGN.md §Tempering).

    ``parity0`` is a runtime operand (scalar or per-lattice ``(B,)``),
    so lattices at different absolute steps — packed serving slots —
    share one compiled program.
    """
    b, h, w = init.shape
    k_steps = u.shape[0]
    if u.shape != (k_steps, b, h, w):
        raise ValueError(
            f"shape mismatch: init={init.shape} u={u.shape}"
        )
    parity0b = jnp.broadcast_to(jnp.asarray(parity0, jnp.int32), (b,))
    kernel = functools.partial(
        _gibbs_kernel,
        logit_fn=logit_fn,
        n_steps=k_steps,
        n_consts=len(consts),
    )
    const_specs = [
        pl.BlockSpec(c.shape, functools.partial(lambda nd, i: (0,) * nd, c.ndim))
        for c in consts
    ]
    samples, flips = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((k_steps, 1, h, w), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            *const_specs,
        ],
        out_specs=[
            pl.BlockSpec((k_steps, 1, h, w), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k_steps, b, h, w), jnp.uint32),
            jax.ShapeDtypeStruct((b, h, w), jnp.int32),
        ],
        interpret=interpret,
    )(init.astype(jnp.uint32), u, parity0b.reshape(b, 1), *consts)
    return samples, flips


def _gibbs_fused_kernel(
    init_ref,     # (1, H, W) uint32 {0,1} spins
    k0_ref,       # (1, 1) uint32 this lattice's chain-key word 0
    k1_ref,       # (1, 1) uint32 this lattice's chain-key word 1
    t0_ref,       # (1, 1) int32 this lattice's absolute-step base
    *rest,        # n_consts broadcast model refs, then the two outputs:
                  #   samples (K, 1, H, W) uint32, flips (1, H, W) int32
    logit_fn,
    n_steps: int,
    lat_b: int,
    n_consts: int,
):
    """In-kernel-RNG checkerboard Gibbs (DESIGN.md §Randomness): no
    uniform operand planes — the kernel carries this lattice's two
    chain-key words and derives the site uniforms for absolute step
    ``t0 + k`` with the shared counter cipher (kernels/rng), exactly the
    draws the scan-side ``FusedRandomness`` reference makes.  ``lat_b``
    is the per-chain lattice-batch size (chains fold into the batch
    grid axis, DESIGN.md §Chains-axis), so lattice ``i`` covers sites
    ``(i % lat_b) * H * W + h * W + w``.  The absolute-step base ``t0``
    is a per-lattice *operand* — lattices at different stream offsets
    (packed serving slots, successive chunks) share one compiled
    program, and both the counter and the checkerboard parity
    (absolute step mod 2) derive from it in-kernel, so the stream is
    unchanged by construction."""
    const_refs, (samples_ref, flips_ref) = rest[:n_consts], rest[n_consts:]
    consts = tuple(ref[...] for ref in const_refs)
    state0 = init_ref[0]
    k0 = k0_ref[0, 0]
    k1 = k1_ref[0, 0]
    t0 = t0_ref[0, 0].astype(jnp.uint32)
    h, w = state0.shape
    row = jax.lax.broadcasted_iota(jnp.int32, (h, w), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (h, w), 1)
    checker = (row + col) % 2
    i = pl.program_id(0)
    site = ((i % lat_b) * h * w + row * w + col).astype(jnp.uint32)

    def body(k, carry):
        state, nflips = carry
        t = t0 + k.astype(jnp.uint32)
        parity = (t % 2).astype(jnp.int32)
        s0, s1 = rng.step_key(k0, k1, t)
        u = rng.uniform_at(s0, s1, site)
        new = (u < jax.nn.sigmoid(logit_fn(state, *consts))).astype(
            jnp.uint32
        )
        nxt = jnp.where(checker == parity, new, state)
        samples_ref[k, 0] = nxt
        return nxt, nflips + (nxt != state).astype(jnp.int32)

    _, nflips = jax.lax.fori_loop(
        0, n_steps, body, (state0, jnp.zeros_like(state0, jnp.int32))
    )
    flips_ref[0] = nflips


@functools.partial(
    jax.jit,
    static_argnames=("logit_fn", "n_steps", "lat_b", "interpret"),
)
def gibbs_chain_pallas_fused(
    init: jnp.ndarray,  # (B, H, W) uint32 {0,1} spins
    k0b: jnp.ndarray,   # (B,) uint32 per-lattice chain-key word 0
    k1b: jnp.ndarray,   # (B,) uint32 per-lattice chain-key word 1
    t0b: jnp.ndarray,   # (B,) int32 per-lattice absolute-step base
    logit_fn,           # (H, W) state [, *consts] -> (H, W) logit of s=1
    *,
    n_steps: int,
    lat_b: int,
    interpret: bool = True,
    consts: tuple = (),
):
    """Fused K-half-sweep Gibbs with in-kernel RNG: zero per-step
    randomness operands — only the per-lattice key words + step base
    (12 bytes/lattice/chunk) cross the kernel boundary.  ``t0b`` is the
    absolute step of the first half-sweep per lattice (parity =
    t0 % 2), a *runtime operand* so lattices at different stream
    offsets share one compiled program.  Same ``logit_fn``/``consts``
    contract as ``gibbs_chain_pallas``."""
    b, h, w = init.shape
    if k0b.shape != (b,) or k1b.shape != (b,) or t0b.shape != (b,):
        raise ValueError(
            f"per-lattice key/step words must be ({b},), got "
            f"{k0b.shape}/{k1b.shape}/{t0b.shape}"
        )
    kernel = functools.partial(
        _gibbs_fused_kernel,
        logit_fn=logit_fn,
        n_steps=n_steps,
        lat_b=lat_b,
        n_consts=len(consts),
    )
    const_specs = [
        pl.BlockSpec(c.shape, functools.partial(lambda nd, i: (0,) * nd, c.ndim))
        for c in consts
    ]
    samples, flips = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            *const_specs,
        ],
        out_specs=[
            pl.BlockSpec((n_steps, 1, h, w), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_steps, b, h, w), jnp.uint32),
            jax.ShapeDtypeStruct((b, h, w), jnp.int32),
        ],
        interpret=interpret,
    )(
        init.astype(jnp.uint32),
        k0b.reshape(b, 1),
        k1b.reshape(b, 1),
        t0b.astype(jnp.int32).reshape(b, 1),
        *consts,
    )
    return samples, flips
