"""Pure-jnp oracle for the fused checkerboard Gibbs kernel.

Exercises the same ``logit_fn`` the kernel traces, so a kernel-vs-ref
mismatch isolates pallas_call plumbing (grid, block specs, fori_loop
refs) rather than conditional math.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gibbs_chain_ref(
    init: jnp.ndarray,  # (B, H, W) uint32 {0,1} spins
    u: jnp.ndarray,     # (K, B, H, W) float32 uniforms
    logit_fn,           # (..., H, W) state -> (..., H, W) conditional logit
    parity0: int = 0,
):
    """Reference checkerboard Gibbs semantics, bit-exact w.r.t. the kernel.

    Returns (samples (K, B, H, W) uint32, flip_count (B, H, W) int32).
    """
    h, w = init.shape[-2:]
    row = jax.lax.broadcasted_iota(jnp.int32, (h, w), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (h, w), 1)
    checker = (row + col) % 2
    init = init.astype(jnp.uint32)

    def body(carry, xs):
        state, nflips = carry
        u_t, t = xs
        parity = (parity0 + t) % 2
        new = (u_t < jax.nn.sigmoid(logit_fn(state))).astype(jnp.uint32)
        nxt = jnp.where(checker == parity, new, state)
        return (nxt, nflips + (nxt != state).astype(jnp.int32)), nxt

    steps = jnp.arange(u.shape[0], dtype=jnp.int32)
    (_, nflips), samples = jax.lax.scan(
        body, (init, jnp.zeros(init.shape, jnp.int32)), (u, steps)
    )
    return samples, nflips
