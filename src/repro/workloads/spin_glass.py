"""±J spin-glass / MAX-CUT workload — combinatorial optimisation on the
engine (the p-bit coprocessor benchmark family, arXiv:2109.14801).

A 2-D Edwards-Anderson model on a periodic lattice: every bond carries
its own coupling J_ij (bimodal ±J by default), so the landscape is
frustrated and multimodal — the workload class that motivates the
tempering subsystem (repro/tempering): annealing descends to ground
states, replica exchange keeps mixing across the barriers that trap a
single chain.  One site is still one 1-bit compartment word and one
engine step one checkerboard half-sweep; heterogeneous couplings don't
break the two-colour decomposition, but periodic boundaries make the
lattice bipartite only for even H and W, so this model *requires* even
dimensions (the ferromagnetic ``IsingModel`` shares the constraint
implicitly; here frustration makes an odd wrap-around genuinely change
the measure, so it is enforced).

MAX-CUT rides the standard reduction J = -w: the antiferromagnetic
ground state of ``SpinGlass.maxcut`` weights is the maximum cut, and
``cut_value`` converts any spin configuration to its cut weight.
Small instances (H·W <= 20) are exhaustively solvable with
``exhaustive_ground_state`` — the ground-truth anchor the tempering
tests and benches assert against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import samplers

Array = jnp.ndarray


class SpinGlass:
    """2-D spin glass with per-bond couplings on a periodic H x W lattice.

    ``j_right[i, j]`` couples site (i, j) to (i, j+1 mod W);
    ``j_down[i, j]`` couples (i, j) to (i+1 mod H, j).  State words are
    {0, 1} (spin s = 2·word − 1), the measure is natural-units
    (temperature-absorbed) like ``IsingModel``:

        log p(s) = sum_bonds J_ij s_i s_j + field · sum_i s_i + const.

    A plain (identity-hashed) class, not a frozen dataclass — the
    coupling arrays ride jit static arguments by object identity exactly
    like ``TableTarget``.
    """

    nbits = 1
    table = None
    supports_fused_gibbs = True

    def __init__(self, j_right, j_down, field: float = 0.0):
        self.j_right = jnp.asarray(j_right, jnp.float32)
        self.j_down = jnp.asarray(j_down, jnp.float32)
        if (
            self.j_right.ndim != 2
            or self.j_right.shape != self.j_down.shape
        ):
            raise ValueError(
                f"couplings must be two equal (H, W) arrays, got "
                f"{self.j_right.shape} and {self.j_down.shape}"
            )
        self.height, self.width = map(int, self.j_right.shape)
        if (
            self.height < 2 or self.width < 2
            or self.height % 2 or self.width % 2
        ):
            raise ValueError(
                "periodic checkerboard Gibbs needs an even, >= 2x2 lattice "
                f"(odd wrap-around breaks bipartiteness), got "
                f"{self.height}x{self.width}"
            )
        self.field = float(field)
        self.maxcut_reduction = False  # set by the maxcut constructor

    @classmethod
    def bimodal(
        cls, key, height: int, width: int, j: float = 1.0,
        p_ferro: float = 0.5, field: float = 0.0,
    ) -> "SpinGlass":
        """±J couplings: each bond is +j with prob ``p_ferro``, else -j."""
        k_r, k_d = jax.random.split(key)

        def sign(k):
            planes = jax.random.bernoulli(k, p_ferro, (height, width))
            return 2.0 * planes.astype(jnp.float32) - 1.0

        return cls(j * sign(k_r), j * sign(k_d), field=field)

    @classmethod
    def maxcut(
        cls, key, height: int, width: int, max_weight: int = 3,
        signed: bool = True,
    ) -> "SpinGlass":
        """(Signed) MAX-CUT on the lattice graph: J = -w, zero field,
        ``cut_value`` enabled.  Integer weight magnitudes in
        [1, max_weight]; ``signed`` draws a random sign per edge —
        essential for a non-trivial instance, because the even periodic
        lattice graph is bipartite and unsigned MAX-CUT on a bipartite
        graph is trivially the checkerboard partition."""
        k_r, k_d, k_sr, k_sd = jax.random.split(key, 4)

        def weights(k_mag, k_sign):
            w = jax.random.randint(
                k_mag, (height, width), 1, max_weight + 1
            ).astype(jnp.float32)
            if signed:
                flip = jax.random.bernoulli(k_sign, 0.5, (height, width))
                w = jnp.where(flip, -w, w)
            return w

        model = cls(-weights(k_r, k_sr), -weights(k_d, k_sd), field=0.0)
        model.maxcut_reduction = True
        return model

    # --- gibbs update-rule contract ------------------------------------
    #
    # One math body serves both executors: the scan step calls
    # ``conditional_logit`` (couplings closed over), the fused kernel
    # traces ``fused_logit`` with the couplings as ``fused_consts``
    # operands — kernel traces cannot capture array closures
    # (DESIGN.md §Tempering).

    @property
    def fused_consts(self) -> tuple:
        return (self.j_right, self.j_down)

    def fused_logit(self, state: Array, j_right, j_down) -> Array:
        """Per-site logit of s_i = +1 given the neighbours:
        2 (sum_j J_ij s_j + field), each incident bond with its own J."""
        s = 2.0 * state.astype(jnp.float32) - 1.0
        nb = (
            j_right * jnp.roll(s, -1, -1)
            + jnp.roll(j_right, 1, -1) * jnp.roll(s, 1, -1)
            + j_down * jnp.roll(s, -1, -2)
            + jnp.roll(j_down, 1, -2) * jnp.roll(s, 1, -2)
        )
        return 2.0 * (nb + self.field)

    def conditional_logit(self, state: Array) -> Array:
        return self.fused_logit(state, self.j_right, self.j_down)

    def update_mask(self, shape: tuple, parity) -> Array:
        """Checkerboard colour active at this half-sweep parity."""
        row = jax.lax.broadcasted_iota(jnp.int32, shape[-2:], 0)
        col = jax.lax.broadcasted_iota(jnp.int32, shape[-2:], 1)
        return ((row + col) % 2) == parity

    def decode(self, words: Array) -> Array:
        return words

    # --- observables / optimisation ------------------------------------

    def energy(self, states: Array) -> Array:
        """Natural-units energy, p ∝ exp(-E), each bond counted once:
        E(s) = -(sum J_r s s_right + sum J_d s s_down + field sum s)."""
        s = 2.0 * states.astype(jnp.float32) - 1.0
        bonds = (
            self.j_right * s * jnp.roll(s, -1, -1)
            + self.j_down * s * jnp.roll(s, -1, -2)
        )
        return -(
            bonds.sum(axis=(-2, -1)) + self.field * s.sum(axis=(-2, -1))
        )

    def cut_value(self, states: Array) -> Array:
        """Cut weight of the ±1 partition under the MAX-CUT reduction
        w = -J (requires antiferromagnetic couplings and zero field):
        cut(s) = (W_total - E(s)) / 2, maximal at the ground state."""
        if not self.maxcut_reduction or self.field != 0.0:
            raise ValueError(
                "cut_value needs a zero-field MAX-CUT model "
                "(use SpinGlass.maxcut)"
            )
        w_total = -(self.j_right.sum() + self.j_down.sum())
        return 0.5 * (w_total - self.energy(states))

    def random_init(self, key, batch: int) -> Array:
        """Infinite-temperature start: i.i.d. fair spins, (B, H, W)."""
        return jax.random.bernoulli(
            key, 0.5, (batch, self.height, self.width)
        ).astype(jnp.uint32)


def exhaustive_ground_state(
    model: SpinGlass, chunk: int = 1 << 14
) -> tuple[float, np.ndarray]:
    """Brute-force (ground energy, one ground state) for H·W <= 20 sites
    — the exact anchor for annealing/tempering correctness tests."""
    n = model.height * model.width
    if n > 20:
        raise ValueError(
            f"exhaustive enumeration capped at 20 sites, got {n}"
        )
    bit = np.arange(n, dtype=np.int64)
    best_e = np.inf
    best_state = None
    for start in range(0, 1 << n, chunk):
        words = np.arange(start, min(start + chunk, 1 << n), dtype=np.int64)
        states = ((words[:, None] >> bit) & 1).astype(np.uint32).reshape(
            -1, model.height, model.width
        )
        e = np.asarray(model.energy(jnp.asarray(states)))
        i = int(np.argmin(e))
        if e[i] < best_e:
            best_e = float(e[i])
            best_state = states[i]
    return best_e, best_state


def build(
    key,
    randomness: str = "cim",
    backend: str = "auto",
    smoke: bool = False,
    height: int | None = None,
    width: int | None = None,
    batch: int | None = None,
    j: float = 1.0,
    p_ferro: float = 0.5,
    field: float = 0.0,
    maxcut: bool = False,
    n_steps: int | None = None,
    chunk_steps: int = 32,
    num_chains: int = 1,
    collect: str = "all",
):
    """Assemble the spin-glass workload (see workloads.WorkloadRun).

    The plain WorkloadRun samples the glass at fixed couplings (the
    energy series feeds the chain diagnostics); the ground-state hunt is
    the tempering subsystem's job — ``launch/sample --ladder/--anneal``
    wraps this same target.  ``maxcut`` swaps the ±J bimodal couplings
    for a signed MAX-CUT instance (J = -w, ``cut_value`` enabled).
    Couplings come from a dedicated split of the build key; inits stay
    counter-derived per chain (``random_init(chain_key(k, c))``) so
    chain 0 of a C-chain build is bit-identical to a solo build,
    matching the other zoo builders.
    """
    from repro import workloads  # deferred: workloads imports this module

    height = height or (4 if smoke else 8)
    width = width or (4 if smoke else 8)
    batch = batch or (2 if smoke else 4)
    n_steps = n_steps or (48 if smoke else 768)
    k_bonds, k_init = jax.random.split(key)
    if maxcut:
        model = SpinGlass.maxcut(k_bonds, height, width)
    else:
        model = SpinGlass.bimodal(
            k_bonds, height, width, j=j, p_ferro=p_ferro, field=field
        )
    engine = samplers.MHEngine(
        samplers.EngineConfig(
            update="gibbs",
            randomness=randomness,
            execution=backend,
            chunk_steps=chunk_steps,
            num_chains=num_chains,
            collect=collect,
        )
    )
    init = jax.vmap(
        lambda k: model.random_init(k, batch)
    )(samplers.chain_keys(k_init, num_chains))
    return workloads.WorkloadRun(
        name="spin_glass",
        engine=engine,
        target=model,
        init_words=init[0] if num_chains == 1 else init,
        n_steps=n_steps,
        burn_in=n_steps // 4,
        series_fn=model.energy,
        meta={
            "lattice": f"{height}x{width}",
            "batch": batch,
            "num_chains": num_chains,
            "maxcut": maxcut,
            "j": j,
            "p_ferro": p_ferro,
            "field": field,
            "nbits": 1,
            "statistic": "energy",
        },
    )
