"""2-D Ising / Markov-random-field workload — checkerboard Gibbs sampling.

The MRF inference workload of Bashizade et al. (PAPERS.md) phrased for
the CIM macro: each lattice site is one 1-bit compartment word, the
4-neighbour coupling is the MRF edge potential, and one engine step is
one checkerboard half-sweep (all sites of one colour update in parallel
— their neighbourhoods are frozen, so the parallel update is exact
Gibbs).  The conditional flip consumes the macro's accurate-[0,1]
uniform: p(s_i = +1 | neighbours) = sigmoid(2 (beta * sum_j s_j + h)).

``IsingModel`` is the engine's first *conditional* target: instead of a
``log_prob`` over words it exposes ``conditional_logit`` +
``update_mask``, the contract of the ``gibbs`` update rule (DESIGN.md
§2/§Workloads).  ``conditional_logit`` is the one implementation of the
conditional — the scan executor steps it directly and the fused kernel
(kernels/gibbs/gibbs.py) traces the very same bound method — which is
what makes scan/pallas parity an array-equality test.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import samplers

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class IsingModel:
    """Ferromagnetic 2-D Ising model on a periodic H x W lattice.

    State words are {0, 1} (spin s = 2 * word - 1).  MRF convention:
    the Gibbs measure is parameterised directly in natural
    (temperature-absorbed) units,

        log p(s) = beta * sum_<ij> s_i s_j + field * sum_i s_i + const,

    i.e. ``beta`` is the bond coupling J/kT and ``field`` the per-site
    bias h/kT — at beta = 0 the field still acts (i.i.d. spins with
    p(+1) = sigmoid(2 * field)).  The 2-D zero-field critical point sits
    at beta_c = ln(1 + sqrt(2))/2 ~ 0.4407.
    """

    height: int
    width: int
    beta: float = 0.35
    field: float = 0.0

    nbits = 1
    table = None
    supports_fused_gibbs = True

    def __post_init__(self):
        if self.height < 2 or self.width < 2:
            raise ValueError(
                f"lattice must be at least 2x2, got {self.height}x{self.width}"
            )

    # --- gibbs update-rule contract ------------------------------------

    def conditional_logit(self, state: Array) -> Array:
        """Per-site logit of s_i = +1 given the current neighbours:
        2 (beta * neighbour-spin sum + field).

        This bound method is the single conditional implementation — the
        scan executor steps it and the fused kernel traces it (it rides
        a jit static argument, hence the frozen dataclass).
        """
        s = 2.0 * state.astype(jnp.float32) - 1.0
        nb = (
            jnp.roll(s, 1, -2)
            + jnp.roll(s, -1, -2)
            + jnp.roll(s, 1, -1)
            + jnp.roll(s, -1, -1)
        )
        return 2.0 * (self.beta * nb + self.field)

    def update_mask(self, shape: tuple, parity) -> Array:
        """Checkerboard colour active at this half-sweep parity."""
        row = jax.lax.broadcasted_iota(jnp.int32, shape[-2:], 0)
        col = jax.lax.broadcasted_iota(jnp.int32, shape[-2:], 1)
        return ((row + col) % 2) == parity

    def decode(self, words: Array) -> Array:
        return words

    # --- observables ----------------------------------------------------

    def magnetization(self, states: Array) -> Array:
        """Mean spin per lattice: (..., H, W) words -> (...,) in [-1, 1]."""
        s = 2.0 * states.astype(jnp.float32) - 1.0
        return s.mean(axis=(-2, -1))

    def energy(self, states: Array) -> Array:
        """Lattice energy in the measure's natural units — p(s) is
        proportional to exp(-energy(s)), consistent with
        ``conditional_logit``:

            energy(s) = -(beta * sum_<ij> s_i s_j + field * sum_i s_i),

        each periodic bond counted once (right + down neighbours)."""
        s = 2.0 * states.astype(jnp.float32) - 1.0
        bonds = s * jnp.roll(s, -1, -2) + s * jnp.roll(s, -1, -1)
        return -(
            self.beta * bonds.sum(axis=(-2, -1))
            + self.field * s.sum(axis=(-2, -1))
        )

    def random_init(self, key, batch: int) -> Array:
        """Infinite-temperature start: i.i.d. fair spins, (B, H, W)."""
        return jax.random.bernoulli(
            key, 0.5, (batch, self.height, self.width)
        ).astype(jnp.uint32)


def build(
    key,
    randomness: str = "cim",
    backend: str = "auto",
    smoke: bool = False,
    height: int | None = None,
    width: int | None = None,
    batch: int | None = None,
    beta: float | None = None,
    field: float = 0.0,
    n_steps: int | None = None,
    chunk_steps: int = 32,
    num_chains: int = 1,
    collect: str = "all",
):
    """Assemble the Ising workload (see workloads.WorkloadRun).

    ``num_chains`` runs C independent chains in one device program
    (DESIGN.md §Chains-axis); inits are counter-derived per chain —
    ``random_init(chain_key(key, c))`` — so chain c of a C-chain build
    is bit-identical to a solo build, inits included.  ``collect``
    (all | thin:<k> | last, DESIGN.md §Collection) flows to the engine;
    diagnostics consume whatever stream survives.
    """
    from repro import workloads  # deferred: workloads imports this module

    height = height or (8 if smoke else 16)
    width = width or (8 if smoke else 16)
    batch = batch or (2 if smoke else 4)
    n_steps = n_steps or (48 if smoke else 1024)
    model = IsingModel(
        height=height,
        width=width,
        beta=0.35 if beta is None else beta,
        field=field,
    )
    engine = samplers.MHEngine(
        samplers.EngineConfig(
            update="gibbs",
            randomness=randomness,
            execution=backend,
            chunk_steps=chunk_steps,
            num_chains=num_chains,
            collect=collect,
        )
    )
    init = jax.vmap(
        lambda k: model.random_init(k, batch)
    )(samplers.chain_keys(key, num_chains))
    return workloads.WorkloadRun(
        name="ising",
        engine=engine,
        target=model,
        init_words=init[0] if num_chains == 1 else init,
        n_steps=n_steps,
        burn_in=n_steps // 4,
        series_fn=model.magnetization,
        meta={
            "lattice": f"{height}x{width}",
            "batch": batch,
            "num_chains": num_chains,
            "beta": model.beta,
            "field": field,
            "nbits": 1,
            "statistic": "magnetization",
        },
    )
