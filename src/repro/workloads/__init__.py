"""The probabilistic-model zoo (DESIGN.md §Workloads).

Every workload is the same three-piece contract riding the unified
sampler engine:

  * a **target** (log-prob table/callable for ``mh``, conditional lattice
    model for ``gibbs``),
  * an **update rule** + engine config (randomness/execution axes flow
    straight through, so every workload gets host-vs-cim and scan-vs-
    pallas for free),
  * a **scalar statistic** of the sample stream that
    ``repro.diagnostics`` judges (tau / ESS / split-R-hat).

``build(name, key, ...)`` assembles a ``WorkloadRun``; the registry is
what ``python -m repro.launch.sample`` and ``benchmarks.bench_workloads``
iterate over.  Adding a workload = one module exposing ``build`` plus a
registry line.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro import diagnostics, samplers
from repro.workloads import gmm, ising


@dataclasses.dataclass
class WorkloadRun:
    """One assembled workload: engine + target + chain layout + statistic."""

    name: str
    engine: samplers.MHEngine
    target: object
    init_words: object
    n_steps: int
    burn_in: int
    series_fn: Callable          # samples (K, *chain) -> (K, n_chains) stat
    meta: dict

    def run(self, key) -> samplers.EngineResult:
        return self.engine.run(key, self.target, self.n_steps, self.init_words)

    def diagnostics(self, result: samplers.EngineResult) -> dict:
        """Chain diagnostics over the post-burn-in scalar statistic."""
        series = np.asarray(self.series_fn(result.samples))
        series = series.reshape(series.shape[0], -1)
        return diagnostics.summarize(
            series[self.burn_in:],
            acceptance_rate=float(result.acceptance_rate),
        )


WORKLOADS = {
    "ising": ising.build,
    "gmm": gmm.build,
}


def build(name: str, key, **kwargs) -> WorkloadRun:
    """Assemble a registered workload by name."""
    try:
        builder = WORKLOADS[name]
    except KeyError:
        raise ValueError(
            f"unknown workload {name!r} (have {sorted(WORKLOADS)})"
        ) from None
    return builder(key, **kwargs)
