"""The probabilistic-model zoo (DESIGN.md §Workloads).

Every workload is the same three-piece contract riding the unified
sampler engine:

  * a **target** (log-prob table/callable for ``mh``, conditional lattice
    model for ``gibbs``),
  * an **update rule** + engine config (randomness/execution axes flow
    straight through, so every workload gets host-vs-cim and scan-vs-
    pallas for free),
  * a **scalar statistic** of the sample stream that
    ``repro.diagnostics`` judges (tau / ESS / split-R-hat).

``build(name, key, ...)`` assembles a ``WorkloadRun``; the registry is
what ``python -m repro.launch.sample`` and ``benchmarks.bench_workloads``
iterate over.  Adding a workload = one module exposing ``build`` plus a
registry line.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro import diagnostics, samplers
from repro.workloads import gmm, ising, spin_glass


@dataclasses.dataclass
class WorkloadRun:
    """One assembled workload: engine + target + chain layout + statistic."""

    name: str
    engine: samplers.MHEngine
    target: object
    init_words: object
    n_steps: int
    burn_in: int
    series_fn: Callable          # samples (K, *chain) -> (K, n_chains) stat
    meta: dict

    def plan(self, key, mesh=None, **overrides) -> samplers.RunPlan:
        """The workload's ``RunPlan`` (DESIGN.md §Run-API) — the spec
        ``run`` submits; callers needing resume/checkpoint semantics take
        this and drive it themselves (e.g. checkpoint.run_resumable)."""
        spec = dict(
            target=self.target,
            n_steps=self.n_steps,
            init_words=self.init_words,
            key=key,
            mesh=mesh,
        )
        spec.update(overrides)
        return samplers.RunPlan(**spec)

    def run(self, key, mesh=None) -> samplers.EngineResult:
        """Run the chains; ``mesh`` shards the engine's chains axis
        (DESIGN.md §Chains-axis) and is a no-op for solo runs."""
        return self.engine.submit(self.plan(key, mesh=mesh)).result

    def series(self, result: samplers.EngineResult) -> np.ndarray:
        """(T, n_columns) scalar-statistic block; a multi-chain run's
        chains contribute their columns side by side."""
        num_chains = self.engine.config.num_chains
        if num_chains == 1:
            series = np.asarray(self.series_fn(result.samples))
            return series.reshape(series.shape[0], -1)
        cols = [
            np.asarray(self.series_fn(result.samples[c])).reshape(
                result.samples.shape[1], -1
            )
            for c in range(num_chains)
        ]
        return np.concatenate(cols, axis=1)

    @property
    def rate_key(self) -> str:
        """THE canonical label for the engine's accept/flip rate — Gibbs
        has no reject, so its count is a flip count (DESIGN.md §2):
        ``acceptance_rate`` for mh, ``flip_rate`` for gibbs.  Diagnostics,
        the CLI, and the bench tables all spell it through here (bench
        rows keep a legacy ``acceptance`` alias column for old readers)."""
        return (
            "flip_rate" if self.engine.config.update == "gibbs"
            else "acceptance_rate"
        )

    def rate_entry(self, result: samplers.EngineResult) -> tuple[str, float]:
        """(canonical label, value) for the engine's accept/flip rate."""
        return self.rate_key, round(float(result.acceptance_rate), 4)

    # pre-rename spelling, kept for external callers
    _rate_entry = rate_entry

    def kept_burn_in(self) -> int:
        """``burn_in`` translated to the collected stream's row index:
        under ``thin:k`` the kept steps (step0 = 0) are t = 0, k, 2k, …,
        so ceil(burn_in / k) kept rows fall inside the burn-in window."""
        mode, k = samplers.parse_collect(self.engine.config.collect)
        if mode == "thin":
            return -(-self.burn_in // k)
        return self.burn_in

    def diagnostics(self, result: samplers.EngineResult) -> dict:
        """Chain diagnostics over the post-burn-in scalar statistic.

        Multi-chain runs feed the (T, C·m) block through
        ``diagnostics.StreamingChainStats`` in ``chunk_steps``-sized
        chunks.  Here the block already sits in host memory (the engine
        collects every state), so this exercises the streaming
        estimators' contract on every run rather than saving memory; the
        O(chunk) benefit is realised by producers that feed the
        accumulator chunk-by-chunk without materialising T (see
        DESIGN.md §Chains-axis).

        The collection axis flows through (DESIGN.md §Collection): under
        ``thin:k`` the estimators consume the kept stream (burn-in
        translated to kept rows — note tau/ESS then measure the *thinned*
        series); under ``last`` there is no series, so only the
        accept/flip rate is reported.
        """
        mode, _ = samplers.parse_collect(self.engine.config.collect)
        if mode == "last":
            label, value = self._rate_entry(result)
            return {"n_steps": 0, label: value}
        series = self.series(result)[self.kept_burn_in():]
        if self.engine.config.num_chains == 1:
            out = diagnostics.summarize(
                series, acceptance_rate=float(result.acceptance_rate)
            )
        else:
            chunk = max(1, self.engine.config.chunk_steps)
            out = diagnostics.summarize_stream(
                (
                    series[s : s + chunk]
                    for s in range(0, series.shape[0], chunk)
                ),
                num_chains=series.shape[1],
                total_steps=series.shape[0],
                acceptance_rate=float(result.acceptance_rate),
            )
        # Gibbs has no reject — the engine's accept_count is a flip
        # count (DESIGN.md §2); _rate_entry owns the label rule
        label, _ = self._rate_entry(result)
        if label != "acceptance_rate":
            out[label] = out.pop("acceptance_rate")
        return out


WORKLOADS = {
    "ising": ising.build,
    "gmm": gmm.build,
    "spin_glass": spin_glass.build,
}


def build(name: str, key, **kwargs) -> WorkloadRun:
    """Assemble a registered workload by name."""
    try:
        builder = WORKLOADS[name]
    except KeyError:
        raise ValueError(
            f"unknown workload {name!r} (have {sorted(WORKLOADS)})"
        ) from None
    return builder(key, **kwargs)
