"""Gaussian-mixture posterior workload — MC²RAM's in-SRAM benchmark.

The Bayesian-inference workload MC²RAM (PAPERS.md) runs directly in
SRAM: draw posterior samples from a Gaussian mixture by MH over the
discretized sample space.  Here the mixture is the paper's Fig. 17(a)
4-component GMM, the sample space is a ``GridCodec`` lattice of 2^nbits
cells, and the chain is the unified engine's ``mh`` update rule.

The canonical target is a ``CallableTarget`` over the discretized space
(``make_callable_target``) — density evaluated at the decoded grid point
per step, any nbits.  ``build`` materialises it into a ``TableTarget``
(one density evaluation per grid cell, done once) so the same workload
runs under both executors: the table rows are *by construction* the
callable's values, and TableTarget lookup is bit-exact w.r.t. the fused
kernel's VMEM lookup, so scan/pallas parity carries over from PR 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import samplers
from repro.core.targets import GaussianMixture, GridCodec, reference_grid_probs

Array = jnp.ndarray


def default_model() -> tuple[GaussianMixture, GridCodec]:
    """The paper's Fig. 17(a) mixture on the Fig. 17 grid box."""
    return (
        GaussianMixture.paper_gmm(),
        GridCodec(nbits=8, dim=1, lo=(-10.0,), hi=(10.0,)),
    )


def make_callable_target(
    gmm: GaussianMixture, codec: GridCodec
) -> samplers.CallableTarget:
    """The workload's defining form: log p over words = log density at the
    decoded grid point (scan execution, any nbits)."""

    def log_prob(words: Array) -> Array:
        # decode gives (..., dim); the mixture's log_prob consumes dim
        return gmm.log_prob(codec.decode(words))

    return samplers.CallableTarget(log_prob, codec.nbits)


def make_table_target(
    gmm: GaussianMixture, codec: GridCodec
) -> samplers.TableTarget:
    """The callable target materialised cell-by-cell into a (1, 2^nbits)
    table — the fused-kernel-eligible form of the same distribution."""
    words = jnp.arange(1 << codec.nbits, dtype=jnp.uint32)
    table = gmm.log_prob(codec.decode(words))[None, :]
    return samplers.TableTarget(table, nbits=codec.nbits)


def build(
    key,
    randomness: str = "cim",
    backend: str = "auto",
    smoke: bool = False,
    nbits: int | None = None,
    chains: int | None = None,
    n_steps: int | None = None,
    chunk_steps: int = 32,
    num_chains: int = 1,
    collect: str = "all",
):
    """Assemble the GMM posterior workload (see workloads.WorkloadRun).

    ``chains`` is the macro's lock-step compartment axis (one table, C
    columns); ``num_chains`` is the engine's independent-chains axis
    (DESIGN.md §Chains-axis), with counter-derived per-chain inits.
    ``collect`` (all | thin:<k> | last) is the engine's collection axis
    (DESIGN.md §Collection).
    """
    from repro import workloads  # deferred: workloads imports this module

    nbits = nbits or 8
    chains = chains or (16 if smoke else 64)
    n_steps = n_steps or (96 if smoke else 2048)
    gmm = GaussianMixture.paper_gmm()
    codec = GridCodec(nbits=nbits, dim=1, lo=(-10.0,), hi=(10.0,))
    target = make_table_target(gmm, codec)
    engine = samplers.MHEngine(
        samplers.EngineConfig(
            update="mh",
            randomness=randomness,
            execution=backend,
            chunk_steps=chunk_steps,
            num_chains=num_chains,
            collect=collect,
        )
    )
    init = jax.vmap(
        lambda k: jax.random.randint(
            k, (1, chains), 0, 1 << nbits, dtype=jnp.int32
        ).astype(jnp.uint32)
    )(samplers.chain_keys(key, num_chains))
    if num_chains == 1:
        init = init[0]

    def series_fn(samples: Array) -> Array:
        # (K, 1, C) words -> (K, C) decoded x coordinates
        x = codec.decode(samples)[..., 0]
        return x.reshape(x.shape[0], -1)

    return workloads.WorkloadRun(
        name="gmm",
        engine=engine,
        target=target,
        init_words=init,
        n_steps=n_steps,
        burn_in=n_steps // 4,
        series_fn=series_fn,
        meta={
            "nbits": nbits,
            "chains": chains,
            "num_chains": num_chains,
            "components": len(gmm.weights),
            "statistic": "x",
        },
    )


def reference_probs(nbits: int = 8):
    """Exact normalised cell probabilities (for TV-distance checks)."""
    gmm = GaussianMixture.paper_gmm()
    codec = GridCodec(nbits=nbits, dim=1, lo=(-10.0,), hi=(10.0,))
    return reference_grid_probs(gmm, codec)
