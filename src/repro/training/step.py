"""Step factories: train (grad-accum, ZeRO, compressed cross-pod DP), serve.

``make_train_step`` builds the jit-able update the launcher (and the
multi-pod dry-run) lowers:

    (params, opt_state, batch[, err_state]) ->
        (params', opt_state', metrics[, err_state'])

* **Microbatching / gradient accumulation**: the global batch splits into
  ``n_micro`` sequential microbatches under ``lax.scan`` with an f32
  gradient accumulator — the standard activation-memory lever (per-step
  activation footprint scales 1/n_micro while arithmetic is unchanged).
* **Compressed cross-pod DP** (optional): the whole grad computation moves
  inside a partial-auto ``shard_map`` manual over "pod"; intra-pod
  reduction stays GSPMD-auto over "data" while the inter-pod hop uses the
  int8 error-feedback psum from ``repro.distributed.compression``.
* **ZeRO-1**: optimizer moments carry sharding constraints over
  ("pod","data") via the axes tree (see repro.optim.adamw).

Serving: ``make_prefill_step`` / ``make_decode_step`` close over the config;
``make_decode_sample_step`` fuses the paper's CIM-MCMC token sampler into
the decode step (softmax-free sampling on the last-token logits).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import token_sampler
from repro.distributed.compression import compressed_pmean
from repro.models import lm
from repro.optim import AdamWConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    n_micro: int = 1
    compress_pods: bool = False
    pod_axis: str = "pod"


def _accumulated_grads(loss_fn, vals, batch, n_micro: int):
    """Mean loss/grads over ``n_micro`` sequential microbatches."""
    if n_micro <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            vals, batch
        )
        return loss, metrics, grads

    micro = jax.tree.map(
        lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]),
        batch,
    )
    g0 = jax.tree.map(lambda v: jnp.zeros(v.shape, jnp.float32), vals)
    zero_metrics = {
        "ce_loss": jnp.zeros((), jnp.float32),
        "aux_loss": jnp.zeros((), jnp.float32),
        "tokens": jnp.zeros((), jnp.float32),
    }

    def body(carry, mb):
        g_acc, loss_acc, m_acc = carry
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            vals, mb
        )
        g_acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) / n_micro, g_acc, grads
        )
        m_acc = jax.tree.map(lambda a, m: a + m / n_micro, m_acc, metrics)
        return (g_acc, loss_acc + loss / n_micro, m_acc), None

    (grads, loss, metrics), _ = jax.lax.scan(
        body, (g0, jnp.zeros((), jnp.float32), zero_metrics), micro
    )
    # tokens were averaged; undo to keep the count semantic
    metrics = dict(metrics, tokens=metrics["tokens"] * n_micro)
    return loss, metrics, grads


def make_train_step(
    cfg,
    axes_tree,
    opt_cfg: AdamWConfig = AdamWConfig(),
    schedule_fn: Callable | None = None,
    step_cfg: TrainStepConfig = TrainStepConfig(),
    mesh=None,
):
    """Returns train_step(vals, opt_state, batch[, err_state])."""

    def loss_fn(vals, batch):
        return lm.train_loss(vals, cfg, batch)

    def _update(vals, opt_state, loss, metrics, grads):
        lr_scale = (
            schedule_fn(opt_state["step"]) if schedule_fn is not None else 1.0
        )
        new_vals, new_opt, opt_metrics = adamw_update(
            grads, opt_state, vals, opt_cfg, lr_scale, axes_tree
        )
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_vals, new_opt, out_metrics

    if not step_cfg.compress_pods:

        def train_step(vals, opt_state, batch):
            loss, metrics, grads = _accumulated_grads(
                loss_fn, vals, batch, step_cfg.n_micro
            )
            return _update(vals, opt_state, loss, metrics, grads)

        return train_step

    if mesh is None or step_cfg.pod_axis not in mesh.axis_names:
        raise ValueError("compress_pods requires a mesh with a 'pod' axis")

    def train_step(vals, opt_state, batch, err_state):
        def pod_local(vals_, batch_, err_flat_tuple):
            loss, metrics, grads = _accumulated_grads(
                loss_fn, vals_, batch_, step_cfg.n_micro
            )
            err_ = jax.tree.unflatten(jax.tree.structure(vals_), list(err_flat_tuple))
            grads, new_err = compressed_pmean(grads, err_, axis=step_cfg.pod_axis)
            loss = jax.lax.pmean(loss, step_cfg.pod_axis)
            metrics = jax.tree.map(
                lambda m: jax.lax.pmean(m, step_cfg.pod_axis), metrics
            )
            return loss, metrics, grads, tuple(jax.tree.leaves(new_err))

        n_leaves = len(jax.tree.leaves(vals))
        loss, metrics, grads, new_err_flat = jax.shard_map(
            pod_local,
            mesh=mesh,
            in_specs=(P(), P(step_cfg.pod_axis), tuple(P() for _ in range(n_leaves))),
            out_specs=(P(), P(), P(), tuple(P() for _ in range(n_leaves))),
            axis_names={step_cfg.pod_axis},
            check_vma=False,
        )(vals, batch, tuple(jax.tree.leaves(err_state)))
        new_err = jax.tree.unflatten(jax.tree.structure(err_state), list(new_err_flat))
        new_vals, new_opt, out_metrics = _update(
            vals, opt_state, loss, metrics, grads
        )
        return new_vals, new_opt, out_metrics, new_err

    return train_step


# --- serving -------------------------------------------------------------------


def make_prefill_step(cfg):
    def prefill_step(vals, batch, cache):
        return lm.prefill(vals, cfg, batch, cache)

    return prefill_step


def make_decode_step(cfg):
    def decode_step(vals, tokens, cache):
        return lm.decode_step(vals, cfg, tokens, cache)

    return decode_step


def make_decode_sample_step(cfg, sampler_cfg: token_sampler.TokenSamplerConfig | None = None):
    """Decode + the paper's CIM-MCMC token sampler, fused into one step.

    The accept test uses logit differences only — no softmax normaliser is
    ever computed over the vocabulary (the macro's alpha = p(x*)/p(x)
    simplification, applied to LLM decode).
    """
    scfg = sampler_cfg or token_sampler.TokenSamplerConfig(
        vocab_size=cfg.vocab_size, n_steps=32
    )

    def decode_sample_step(vals, tokens, cache, key):
        logits, new_cache = lm.decode_step(vals, cfg, tokens, cache)
        result = token_sampler._sample_tokens_impl(
            key, logits[:, : cfg.vocab_size], scfg, init_tokens=tokens[:, 0]
        )
        return result.tokens[:, None], new_cache, result.acceptance_rate

    return decode_sample_step
