from repro.training.step import (  # noqa: F401
    TrainStepConfig,
    make_train_step,
    make_prefill_step,
    make_decode_step,
    make_decode_sample_step,
)
