"""Replica exchange (parallel tempering) over the engine's chain-id axis.

R replicas sample p^beta_r through the unified engine; every
``swap_every`` steps adjacent pairs propose to exchange configurations
with the standard PT accept test

    u < exp(min((beta_r - beta_{r+1}) · (f(x_{r+1}) - f(x_r)), 0)),

f the beta=1 log-prob per independent chain element — the same accept
expression as the MH step (DESIGN.md §1), because a swap *is* an MH move
in replica space.  Even/odd adjacent pairs alternate between swap
events, so accepted swaps never contend for a replica.

Determinism contract (DESIGN.md §Tempering):

  * replica r's sampling stream is chain slot ``chain_id + r``
    (``chain_key``) — the chains-axis derivation, so tempered runs
    inherit every chains-axis parity property;
  * segments between swap points run with ``step0 = <absolute step>``,
    so the concatenated per-replica stream is bit-identical to one
    unsegmented engine run (which is also why a 1-replica ladder — no
    swaps — reproduces a plain run bit-for-bit).  The engine's
    *collection* axis (DESIGN.md §Collection) inherits this for free:
    its kept set is defined on absolute steps, so an engine configured
    with ``collect="thin:k"`` yields exactly the thinned monolithic
    stream, and ``collect="last"`` runs the whole tempered ensemble in
    O(state) sample memory (``TemperedResult.samples`` is then the
    (R, 0, ...) placeholder — swaps only ever read final states);
  * swap decisions are keyed on the *absolute* step index: the pair
    parity is ``(step // swap_every - 1) % 2`` and the swap uniforms are
    drawn from the run's own ``RandomnessBackend`` at that step (a
    dedicated chain-id slot far outside any replica range), so the whole
    tempered run is a pure function of (key, config) — invariant to
    engine ``chunk_steps`` and executor, and host-vs-cim comparisons
    carry exactly as they do for the within-replica moves.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.diagnostics import SwapStats
from repro.samplers import MHEngine, RunPlan, chain_key, parse_collect
from repro.samplers.engine import resolve_execution
from repro.tempering.ladder import Ladder, base_log_prob

Array = jnp.ndarray

# chain-id slot of the swap-uniform stream: spells "SWAP", far outside
# any plausible replica range so it never collides with chain_key(·, r)
SWAP_STREAM_ID = 0x53574150


@dataclasses.dataclass
class TemperedResult:
    """One replica-exchange run.  Slot-major layout: index r of every
    field is the replica *slot* holding beta_r throughout the run (swaps
    exchange configurations between slots, never the betas)."""

    samples: Array          # (R, n_steps, *chain_shape) uint32
    accept_count: Array     # (R, *chain_shape) int32 within-replica moves
    acceptance_rate: Array  # scalar float32, pooled over replicas
    final_words: Array      # (R, *chain_shape) uint32
    final_logp: Array       # (R, *elem) float32 beta=1 log-prob
    swap: SwapStats
    n_steps: int
    betas: tuple[float, ...]

    @property
    def cold_samples(self) -> Array:
        """The beta = betas[0] (target-measure) sample stream."""
        return self.samples[0]


@partial(
    jax.jit, static_argnames=("engine", "target", "n_steps", "chain_id")
)
def _scan_segment(key, init, step0, *, engine, target, n_steps, chain_id):
    """One replica segment under scan execution, jitted with a *traced*
    step0 — every segment of a run shares one trace per replica.  Launches
    through the RunPlan surface like every call site (DESIGN.md
    §Run-API); plans tolerate traced offsets."""
    plan = RunPlan(
        target=target, n_steps=n_steps, init_words=init, key=key,
        chain_id=chain_id, step0=step0,
    )
    return engine.submit(plan).result


@dataclasses.dataclass(frozen=True)
class ReplicaExchange:
    """Parallel-tempering driver: ``ladder`` replicas of ``engine``'s
    update rule with even/odd adjacent swaps every ``swap_every`` steps."""

    ladder: Ladder
    engine: MHEngine
    swap_every: int = 16

    def __post_init__(self):
        if self.swap_every < 1:
            raise ValueError(
                f"swap_every must be >= 1, got {self.swap_every}"
            )
        if self.engine.config.num_chains != 1:
            raise ValueError(
                "replica exchange occupies the chain-id axis (replica r = "
                "chain slot chain_id + r); run independent tempered "
                "ensembles by batching the target/init instead of "
                f"num_chains={self.engine.config.num_chains}"
            )

    def run(
        self, key, target, n_steps: int, init_words, *, chain_id: int = 0
    ) -> TemperedResult:
        """Run ``n_steps`` per replica from ``init_words`` (leading
        (num_replicas,) axis, required explicitly like the engine's
        chains axis) and swap at every interior multiple of
        ``swap_every``."""
        if n_steps < 1:
            raise ValueError(f"n_steps must be >= 1, got {n_steps}")
        num_replicas = self.ladder.num_replicas
        init = jnp.asarray(init_words)
        if init.ndim == 0 or init.shape[0] != num_replicas:
            raise ValueError(
                f"tempered init_words must carry a leading "
                f"(num_replicas={num_replicas},) axis, got {init.shape}; "
                f"broadcast a shared init with "
                f"jnp.broadcast_to(init, ({num_replicas}, *init.shape))"
            )
        engine = self.engine
        targets = self.ladder.targets(target)
        scan_exec = all(
            resolve_execution(engine.config.execution, t, engine.config.update)
            == "scan"
            for t in targets
        )
        # thin's kept count is shape-static, so thin segments take the
        # concrete-step0 path (one trace per offset) even under scan
        if parse_collect(engine.config.collect)[0] == "thin":
            scan_exec = False
        elem_shape = tuple(base_log_prob(target, init[0]).shape)
        stats = SwapStats(num_replicas, elem_shape)

        states = [init[r] for r in range(num_replicas)]
        pieces = [[] for _ in range(num_replicas)]
        acc = [None] * num_replicas
        step = 0
        while step < n_steps:
            seg = min(self.swap_every, n_steps - step)
            with telemetry.span(
                "tempering.segment",
                step0=step, seg=seg, replicas=num_replicas,
            ):
                for r in range(num_replicas):
                    if scan_exec:
                        res = _scan_segment(
                            key, states[r], jnp.int32(step), engine=engine,
                            target=targets[r], n_steps=seg,
                            chain_id=chain_id + r,
                        )
                    else:  # pallas: step0 rides as a kernel operand, so
                        # traces cache on the target alone; eager is fine
                        res = engine.submit(
                            RunPlan(
                                target=targets[r], n_steps=seg,
                                init_words=states[r], key=key,
                                chain_id=chain_id + r, step0=step,
                            )
                        ).result
                    states[r] = res.final_words
                    pieces[r].append(res.samples)
                    acc[r] = (
                        res.accept_count if acc[r] is None
                        else acc[r] + res.accept_count
                    )
            step += seg
            if step < n_steps and num_replicas > 1:
                with telemetry.span(
                    "tempering.swap",
                    abs_step=step,
                    parity=(step // self.swap_every - 1) % 2,
                ):
                    states = self._swap(key, target, states, step, stats)
                telemetry.counter(
                    "tempering_swap_rounds_total", "swap sweeps run"
                ).inc()

        samples = jnp.stack(
            [p[0] if len(p) == 1 else jnp.concatenate(p, 0) for p in pieces]
        )
        accept_count = jnp.stack(acc)
        final_words = jnp.stack(states)
        total = jnp.float32(n_steps) * jnp.float32(max(1, final_words.size))
        return TemperedResult(
            samples=samples,
            accept_count=accept_count,
            acceptance_rate=(
                jnp.sum(accept_count).astype(jnp.float32) / total
            ),
            final_words=final_words,
            final_logp=jnp.stack(
                [base_log_prob(target, s) for s in states]
            ).astype(jnp.float32),
            swap=stats,
            n_steps=n_steps,
            betas=self.ladder.betas,
        )

    def _swap(self, key, target, states, abs_step: int, stats: SwapStats):
        """One even/odd adjacent-pair swap sweep at absolute step
        ``abs_step`` (a multiple of swap_every)."""
        num_replicas = len(states)
        betas = jnp.asarray(self.ladder.betas, jnp.float32)
        f = jnp.stack(
            [base_log_prob(target, s) for s in states]
        ).astype(jnp.float32)                                 # (R, *elem)
        elem_ndim = f.ndim - 1
        expand = (slice(None),) + (None,) * elem_ndim
        delta = (betas[:-1] - betas[1:])[expand] * (f[1:] - f[:-1])

        # operand-lean draw: the swap test consumes only the uniform, so
        # flip-plane generation is skipped (u stream unchanged, §Collection)
        swap_key = chain_key(key, SWAP_STREAM_ID)
        _, u = self.engine.randomness.chunk(
            swap_key, abs_step, 1, (num_replicas - 1, *f.shape[1:]), 1,
            need_flips=False,
        )
        parity = (abs_step // self.swap_every - 1) % 2
        active = (jnp.arange(num_replicas - 1) % 2) == parity  # (R-1,)
        # the MH accept expression (DESIGN.md §1): -inf/-inf pairs give a
        # NaN delta and both comparisons false — never swap dead states
        accept = active[expand] & (u[0] < jnp.exp(jnp.minimum(delta, 0.0)))

        stacked = jnp.stack(states)                    # (R, *state_shape)
        pad = jnp.zeros((1, *accept.shape[1:]), bool)
        up = jnp.concatenate([accept, pad], 0)         # slot r <- r+1
        down = jnp.concatenate([pad, accept], 0)       # slot r <- r-1
        # broadcast the per-element decision over the trailing state dims
        # (a lattice element is a whole (H, W) configuration)
        trail = stacked.ndim - 1 - elem_ndim
        up_b = up.reshape(*up.shape, *([1] * trail))
        down_b = down.reshape(*down.shape, *([1] * trail))
        nxt = jnp.concatenate([stacked[1:], stacked[-1:]], 0)
        prv = jnp.concatenate([stacked[:1], stacked[:-1]], 0)
        swapped = jnp.where(up_b, nxt, jnp.where(down_b, prv, stacked))

        stats.record(np.asarray(active), np.asarray(accept))
        return [swapped[r] for r in range(num_replicas)]
