"""Simulated annealing on the engine — a monotone beta schedule plus a
best-state tracker.

Annealing is the 1-replica limit of tempering: one chain samples
p(x)^beta_k through the engine while beta_k rises stage by stage
(cooling), turning the sampler into an optimizer — by the end the Gibbs
conditionals / MH accepts are nearly greedy and the chain settles into
low-energy states.  The driver reuses the tempering determinism contract
(DESIGN.md §Tempering): each stage is an engine segment launched with
``step0 = <absolute step>``, so the full annealed stream is a pure
function of (key, schedule) — invariant to engine ``chunk_steps`` and
executor, and a 1-stage schedule at beta = 1 is exactly a plain engine
run.

The best-state tracker is streaming: per independent chain element it
keeps only (best words, best beta=1 log-prob) across *every* visited
state, O(state) memory regardless of ``n_steps`` — combinatorial
optimisation cares about the best configuration ever touched, not the
final one.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.samplers import MHEngine, RunPlan
from repro.tempering.ladder import base_log_prob, scaled_target

Array = jnp.ndarray


@dataclasses.dataclass
class AnnealResult:
    best_words: Array       # (*chain_shape,) best state ever visited
    best_logp: Array        # (*elem,) its beta=1 log-prob (-energy)
    final_words: Array      # (*chain_shape,) end-of-schedule state
    accept_count: Array     # (*chain_shape,) pooled over stages
    acceptance_rate: Array  # scalar float32
    n_steps: int
    betas: tuple[float, ...]

    @property
    def best_energy(self) -> Array:
        """Natural-units energy of the best state (lattice targets)."""
        return -self.best_logp


def _stage_best(samples: Array, f: Array):
    """Per-element argmax of f over a stage's (T, *elem[, *site]) block."""
    t = f.shape[0]
    elem_shape = f.shape[1:]
    site_shape = samples.shape[f.ndim:]
    flat_f = f.reshape(t, -1)
    idx = jnp.argmax(flat_f, axis=0)                       # (E,)
    cols = jnp.arange(flat_f.shape[1])
    best_f = flat_f[idx, cols].reshape(elem_shape)
    flat_s = samples.reshape(t, flat_f.shape[1], -1)
    best_words = flat_s[idx, cols].reshape(*elem_shape, *site_shape)
    return best_words, best_f


@dataclasses.dataclass(frozen=True)
class Annealer:
    """Monotone (non-decreasing) beta schedule, ``steps_per_beta`` engine
    steps per stage; ``betas[-1]`` is the coldest/greediest stage."""

    betas: tuple[float, ...]
    steps_per_beta: int

    def __post_init__(self):
        if len(self.betas) < 1:
            raise ValueError("annealing schedule needs at least one beta")
        if self.steps_per_beta < 1:
            raise ValueError(
                f"steps_per_beta must be >= 1, got {self.steps_per_beta}"
            )
        for b in self.betas:
            if not (math.isfinite(b) and b > 0.0):
                raise ValueError(f"betas must be finite and > 0, got {b}")
        for cur, nxt in zip(self.betas, self.betas[1:]):
            if nxt < cur:
                raise ValueError(
                    "annealing betas must be non-decreasing (cooling), "
                    f"got {self.betas}"
                )

    @property
    def n_steps(self) -> int:
        return len(self.betas) * self.steps_per_beta

    @classmethod
    def geometric(
        cls, num_stages: int, steps_per_beta: int,
        beta_min: float = 0.25, beta_max: float = 4.0,
    ) -> "Annealer":
        if num_stages < 1:
            raise ValueError(f"num_stages must be >= 1, got {num_stages}")
        if num_stages == 1:
            return cls((beta_max,), steps_per_beta)
        r = (beta_max / beta_min) ** (1.0 / (num_stages - 1))
        return cls(
            tuple(beta_min * r**i for i in range(num_stages)), steps_per_beta
        )

    @classmethod
    def linear(
        cls, num_stages: int, steps_per_beta: int,
        beta_min: float = 0.25, beta_max: float = 4.0,
    ) -> "Annealer":
        if num_stages < 1:
            raise ValueError(f"num_stages must be >= 1, got {num_stages}")
        if num_stages == 1:
            return cls((beta_max,), steps_per_beta)
        step = (beta_max - beta_min) / (num_stages - 1)
        return cls(
            tuple(beta_min + step * i for i in range(num_stages)),
            steps_per_beta,
        )

    def run(
        self, key, target, init_words, *, engine: MHEngine, chain_id: int = 0
    ) -> AnnealResult:
        """Anneal from ``init_words`` through the schedule; returns the
        best state ever visited alongside the final one."""
        if engine.config.num_chains != 1:
            raise ValueError(
                "annealing drives a single chain per element; batch the "
                "target/init instead of "
                f"num_chains={engine.config.num_chains}"
            )
        state = jnp.asarray(init_words)
        best_words = None
        best_f = None
        acc = None
        step = 0
        for beta in self.betas:
            # the best tracker folds over every visited state, so stage
            # runs pin collect="all" whatever the engine's default is
            res = engine.submit(
                RunPlan(
                    target=scaled_target(target, beta),
                    n_steps=self.steps_per_beta, init_words=state, key=key,
                    chain_id=chain_id, step0=step, collect="all",
                )
            ).result
            f = base_log_prob(target, res.samples).astype(jnp.float32)
            stage_words, stage_f = _stage_best(res.samples, f)
            if best_f is None:
                best_words, best_f = stage_words, stage_f
            else:
                better = stage_f > best_f
                best_f = jnp.where(better, stage_f, best_f)
                trail = best_words.ndim - better.ndim
                best_words = jnp.where(
                    better.reshape(*better.shape, *([1] * trail)),
                    stage_words, best_words,
                )
            state = res.final_words
            acc = res.accept_count if acc is None else acc + res.accept_count
            step += self.steps_per_beta
        total = jnp.float32(self.n_steps) * jnp.float32(max(1, state.size))
        return AnnealResult(
            best_words=best_words,
            best_logp=best_f,
            final_words=state,
            accept_count=acc,
            acceptance_rate=jnp.sum(acc).astype(jnp.float32) / total,
            n_steps=self.n_steps,
            betas=self.betas,
        )
