# Parallel tempering / simulated annealing (DESIGN.md §Tempering) — the
# algorithm tier above the sampler engine that MC²A and the p-bit
# coprocessor benchmarks (PAPERS.md) put on probabilistic hardware:
#
#   Ladder          beta schedules + per-replica scaled targets (p^beta
#                   by scaling logits/conditional logits — the engine
#                   datapath is untouched)
#   ReplicaExchange even/odd adjacent-pair swaps at absolute-step
#                   boundaries, uniforms from the run's own
#                   RandomnessBackend => tempered runs are bit-identical
#                   across executors/chunkings, and a 1-replica ladder
#                   degenerates to a plain engine run
#   Annealer        monotone cooling schedules with a streaming
#                   best-state tracker (combinatorial optimisation:
#                   spin-glass ground states, MAX-CUT)

from repro.tempering.anneal import AnnealResult, Annealer  # noqa: F401
from repro.tempering.exchange import (  # noqa: F401
    ReplicaExchange,
    TemperedResult,
)
from repro.tempering.ladder import (  # noqa: F401
    Ladder,
    TemperedLattice,
    base_log_prob,
    scaled_target,
)
