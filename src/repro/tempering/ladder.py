"""Temperature ladders — the beta axis above the sampler engine.

Tempering never touches the engine datapath (DESIGN.md §Tempering): a
replica at inverse temperature ``beta`` samples the flattened measure
p(x)^beta, obtained purely by scaling the target's logits — the table /
callable log-prob under ``mh``, the conditional logit under ``gibbs``
(p^beta's single-site conditional logit is exactly beta times the base
one).  ``Ladder`` owns the beta schedule and builds the per-replica
scaled targets; the exchange/anneal drivers then run each replica as one
slot of the engine's chain-id axis, so all four engine axes (and their
bit-parity contracts) carry over to tempered runs unchanged.

``scaled_target(target, 1.0)`` returns the base target itself —
degeneration to the untempered engine is by identity, not by an
algebraic coincidence — which is what makes a 1-replica ladder
bit-identical to a plain engine run (tests/test_tempering.py).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax.numpy as jnp

from repro import samplers


@dataclasses.dataclass(frozen=True)
class TemperedLattice:
    """A conditional lattice model flattened to p^beta.

    ``conditional_logit`` is the only override — beta times the base
    logit, the exact conditional of the tempered Gibbs measure.  Both
    executors trace this same bound method (the fused kernel takes it as
    a static closure), so scan/pallas parity is inherited; everything
    else (``update_mask``, ``energy``, observables) delegates to the
    base model.
    """

    base: object
    beta: float

    nbits = 1
    table = None

    def __post_init__(self):
        if not (math.isfinite(self.beta) and self.beta > 0.0):
            raise ValueError(f"beta must be finite and > 0, got {self.beta}")

    @property
    def supports_fused_gibbs(self) -> bool:
        return getattr(self.base, "supports_fused_gibbs", False)

    def conditional_logit(self, state):
        return jnp.float32(self.beta) * self.base.conditional_logit(state)

    def fused_logit(self, state, *consts):
        """Fused-kernel path for bases whose couplings ride as operands
        (``fused_consts``, delegated via ``__getattr__``)."""
        return jnp.float32(self.beta) * self.base.fused_logit(state, *consts)

    def __getattr__(self, name):
        # update_mask / energy / decode / observables pass through
        if name == "base":  # not yet set (unpickling): avoid recursion
            raise AttributeError(name)
        return getattr(self.base, name)


class _ScaledTable(samplers.TableTarget):
    """A log-prob table flattened to p^beta: the scaled table is what both
    executors consume (VMEM lookup and scan gather read the same rows),
    and ``decode`` keeps the base's word mapping (e.g. TopKTarget ids)."""

    def __init__(self, base, beta: float):
        super().__init__(beta * base.table, nbits=base.nbits)
        self.base = base

    def decode(self, words):
        return self.base.decode(words)


def scaled_target(target, beta: float):
    """The beta-tempered view of ``target``: samples p^beta.

    ``beta == 1.0`` returns ``target`` itself so untempered replicas
    share jit trace caches (and bit-identity) with plain engine runs.
    """
    beta = float(beta)
    if not (math.isfinite(beta) and beta > 0.0):
        raise ValueError(f"beta must be finite and > 0, got {beta}")
    if beta == 1.0:
        return target
    if hasattr(target, "conditional_logit"):
        return TemperedLattice(target, beta)
    if getattr(target, "table", None) is not None:
        return _ScaledTable(target, beta)
    return samplers.CallableTarget(
        lambda words: beta * target.log_prob(words), target.nbits
    )


def base_log_prob(target, words):
    """Joint beta=1 log-prob per *independent chain element* — the swap
    and best-state statistic.

    Log-prob targets score each word independently, so the element shape
    is the state shape.  Conditional lattice models have no per-site
    joint; they must expose ``energy`` (natural units, p ∝ exp(-E), the
    convention of ``IsingModel.energy``), and the element is the whole
    lattice — one (H, W) configuration swaps as a unit.
    """
    if hasattr(target, "conditional_logit"):
        energy = getattr(target, "energy", None)
        if energy is None:
            raise ValueError(
                "tempering a lattice model needs a joint ``energy`` method "
                "(natural units, p ∝ exp(-E)); "
                f"{type(target).__name__} has none"
            )
        return -energy(words)
    return target.log_prob(words)


@dataclasses.dataclass(frozen=True)
class Ladder:
    """An inverse-temperature ladder; ``betas[0]`` is the cold/target
    replica, later entries are progressively flatter (non-increasing)."""

    betas: tuple[float, ...]

    def __post_init__(self):
        if len(self.betas) < 1:
            raise ValueError("ladder needs at least one beta")
        for b in self.betas:
            if not (math.isfinite(b) and b > 0.0):
                raise ValueError(f"betas must be finite and > 0, got {b}")
        for hot, hotter in zip(self.betas, self.betas[1:]):
            if hotter > hot:
                raise ValueError(
                    "ladder betas must be non-increasing (betas[0] is the "
                    f"cold/target replica), got {self.betas}"
                )

    @property
    def num_replicas(self) -> int:
        return len(self.betas)

    @classmethod
    def geometric(
        cls, num_replicas: int, beta_min: float = 0.25, beta_max: float = 1.0
    ) -> "Ladder":
        """Geometric spacing — the standard PT default (uniform
        log-beta gaps give roughly uniform swap rates for energy
        distributions whose width scales with temperature)."""
        if num_replicas < 1:
            raise ValueError(f"num_replicas must be >= 1, got {num_replicas}")
        if num_replicas == 1:
            return cls((beta_max,))
        r = (beta_min / beta_max) ** (1.0 / (num_replicas - 1))
        return cls(tuple(beta_max * r**i for i in range(num_replicas)))

    @classmethod
    def linear(
        cls, num_replicas: int, beta_min: float = 0.25, beta_max: float = 1.0
    ) -> "Ladder":
        if num_replicas < 1:
            raise ValueError(f"num_replicas must be >= 1, got {num_replicas}")
        if num_replicas == 1:
            return cls((beta_max,))
        step = (beta_max - beta_min) / (num_replicas - 1)
        return cls(tuple(beta_max - step * i for i in range(num_replicas)))

    def targets(self, base_target) -> tuple:
        """Per-replica scaled targets, cached per (ladder, base) — table
        and callable wrappers are identity-hashed like their bases, so
        handing back the *same* instances across runs is what lets the
        per-segment jit caches hit on a warm second run."""
        return _cached_targets(self, base_target)


@functools.lru_cache(maxsize=64)
def _cached_targets(ladder: Ladder, base_target) -> tuple:
    return tuple(scaled_target(base_target, b) for b in ladder.betas)
