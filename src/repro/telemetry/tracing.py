"""The structured tracing core (DESIGN.md §Telemetry).

Zero-dependency, host-side-only tracing: a ``span("engine.segment",
step0=..., chunk=...)`` context manager measures wall time between the
host-side dispatch boundaries of the runtime layers (engine submit,
serving segments, tempering swaps, checkpoint saves) and records one
structured event per span into an in-process ring buffer.  The buffer
drains through two exporters:

  * **JSONL** — one event object per line (schema below), the format
    ``python -m repro.launch.monitor`` tails/validates and the CI smoke
    checks;
  * **Chrome trace** — the ``chrome://tracing`` / Perfetto JSON object
    format (``ph="X"`` complete events in µs), so a ``--trace out.json``
    run drops straight into a flame view.

Clock discipline: every event timestamps against ONE ``perf_counter``
epoch captured when the tracer is created/reset (``ts_us`` = µs since
epoch, float).  Spans measure *host* wall time between dispatches — JAX
dispatch is asynchronous, so a span around an un-blocked device call
measures dispatch cost, not device time; instrumentation sites that want
device time block first (the bench harness) or accept dispatch semantics
(the serving segment spans, where the donation boundary forces the sync
anyway).  Events carry a process-unique ``seq`` so equal-timestamp
events keep their emission order.

Overhead contract: telemetry is OFF by default and the disabled path is
one module-attribute check returning a shared no-op context manager —
no allocation, no clock read.  The enabled path is host-side and
per-chunk/per-segment (never per chain step).  The disabled-mode cost of
the instrumentation sites is bench-gated < 2%
(benchmarks/bench_telemetry.py + check_regression).

Event schema (JSONL, one object per line; ``schema`` = 1):

  {"kind": "trace_meta", "schema": 1, "dropped": N, "events": N}   header
  {"kind": "span",    "name": str, "ts_us": float, "dur_us": float,
   "tid": int, "depth": int, "seq": int, "meta": {...}}
  {"kind": "instant", "name": str, "ts_us": float,
   "tid": int, "depth": int, "seq": int, "meta": {...}}

``kind``/``name``/``ts_us``/``seq`` are required on every event; spans
additionally require ``dur_us >= 0``.  ``meta`` values are JSON scalars
(non-scalars are repr()'d at record time, so exports never fail late).
"""

from __future__ import annotations

import dataclasses
import json
import logging
import threading
import time
from collections import deque

SCHEMA_VERSION = 1
DEFAULT_CAPACITY = 65536

_LOG = logging.getLogger("repro.telemetry")


def _clean_meta(meta: dict) -> dict:
    """JSON-scalar-only metadata: exporters must never fail on a value
    recorded deep inside a run."""
    out = {}
    for k, v in meta.items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[str(k)] = v
        else:
            out[str(k)] = repr(v)
    return out


@dataclasses.dataclass
class TraceEvent:
    """One recorded event (span or instant)."""

    kind: str            # "span" | "instant"
    name: str
    ts_us: float         # µs since the tracer's epoch
    dur_us: float        # span duration (0.0 for instants)
    tid: int             # thread id (small per-tracer ordinal)
    depth: int           # span-nesting depth at record time
    seq: int             # process-wide emission order
    meta: dict

    def to_json(self) -> dict:
        obj = {
            "kind": self.kind,
            "name": self.name,
            "ts_us": round(self.ts_us, 3),
            "tid": self.tid,
            "depth": self.depth,
            "seq": self.seq,
        }
        if self.kind == "span":
            obj["dur_us"] = round(self.dur_us, 3)
        if self.meta:
            obj["meta"] = self.meta
        return obj


class _NullSpan:
    """The shared disabled-path context manager — no state, no clock."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **meta):  # parity with _Span: late metadata is a no-op
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records on exit so the buffer sees complete events."""

    __slots__ = ("_tracer", "_name", "_meta", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, meta: dict):
        self._tracer = tracer
        self._name = name
        self._meta = meta

    def set(self, **meta):
        """Attach metadata discovered mid-span (e.g. a jit-cache verdict
        known only after the dispatch returns)."""
        self._meta.update(meta)
        return self

    def __enter__(self):
        self._depth = self._tracer._push()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self._tracer._pop()
        self._tracer._record(
            "span", self._name, self._t0, t1 - self._t0, self._depth,
            self._meta,
        )
        return False


class Tracer:
    """The in-process ring buffer of trace events.

    ``capacity`` bounds memory for arbitrarily long runs; on overflow the
    OLDEST event is dropped (a trace tail is worth more than its head —
    the live end is what post-mortems read) and ``dropped`` counts the
    evictions, surfaced in the export header so a truncated trace is
    never mistaken for a complete one.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.enabled = False
        self.dropped = 0
        self._events: deque[TraceEvent] = deque()
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()
        self._seq = 0
        self._tids: dict[int, int] = {}         # thread ident -> ordinal
        self._depths = threading.local()        # per-thread nesting depth

    # -- lifecycle ------------------------------------------------------
    def reset(self, capacity: int | None = None) -> None:
        """Drop all events and restart the clock epoch."""
        with self._lock:
            if capacity is not None:
                if capacity < 1:
                    raise ValueError(
                        f"capacity must be >= 1, got {capacity}"
                    )
                self.capacity = int(capacity)
            self._events.clear()
            self.dropped = 0
            self._epoch = time.perf_counter()
            self._seq = 0
            self._tids.clear()

    def clock(self) -> float:
        """Seconds since this tracer's epoch — the one timebase every
        event (and the serving tier's latency stamps) shares."""
        return time.perf_counter() - self._epoch

    # -- recording ------------------------------------------------------
    def _push(self) -> int:
        d = getattr(self._depths, "d", 0)
        self._depths.d = d + 1
        return d

    def _pop(self) -> None:
        self._depths.d = getattr(self._depths, "d", 1) - 1

    def _record(self, kind, name, t0, dur_s, depth, meta) -> None:
        ev_meta = _clean_meta(meta) if meta else {}
        with self._lock:
            tid = self._tids.setdefault(
                threading.get_ident(), len(self._tids)
            )
            if len(self._events) >= self.capacity:
                self._events.popleft()
                self.dropped += 1
            self._events.append(
                TraceEvent(
                    kind=kind,
                    name=str(name),
                    ts_us=(t0 - self._epoch) * 1e6,
                    dur_us=dur_s * 1e6,
                    tid=tid,
                    depth=depth,
                    seq=self._seq,
                    meta=ev_meta,
                )
            )
            self._seq += 1

    def span(self, name: str, **meta):
        """Context manager timing one host-side section.  Disabled-mode
        fast path: one attribute check, a shared no-op object back."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, meta)

    def instant(self, name: str, **meta) -> None:
        """A point event (zero duration)."""
        if not self.enabled:
            return
        self._record(
            "instant", name, time.perf_counter(), 0.0,
            getattr(self._depths, "d", 0), meta,
        )

    def log(self, name: str, **fields) -> None:
        """A structured log line: recorded as an instant event when
        tracing is enabled AND always offered to python logging at INFO
        (logger ``repro.telemetry``) — killed-run forensics read these
        without a trace file (checkpoint/resume.py)."""
        if self.enabled:
            self._record(
                "instant", name, time.perf_counter(), 0.0,
                getattr(self._depths, "d", 0), fields,
            )
        if _LOG.isEnabledFor(logging.INFO):
            _LOG.info(
                "%s %s", name, json.dumps(_clean_meta(fields), sort_keys=True)
            )

    # -- reading / export ----------------------------------------------
    def events(self) -> list[TraceEvent]:
        """A snapshot of the buffer (oldest first)."""
        with self._lock:
            return list(self._events)

    def _header(self, n_events: int) -> dict:
        return {
            "kind": "trace_meta",
            "schema": SCHEMA_VERSION,
            "events": n_events,
            "dropped": self.dropped,
            "capacity": self.capacity,
        }

    def export_jsonl(self, path: str) -> int:
        """Write header + one event per line; returns the event count."""
        events = self.events()
        with open(path, "w") as f:
            f.write(json.dumps(self._header(len(events))) + "\n")
            for ev in events:
                f.write(json.dumps(ev.to_json()) + "\n")
        return len(events)

    def export_chrome_trace(self, path: str) -> int:
        """Write the Chrome-trace (chrome://tracing / Perfetto) JSON
        object format; returns the event count."""
        events = self.events()
        out = []
        for ev in events:
            obj = {
                "name": ev.name,
                "ts": round(ev.ts_us, 3),
                "pid": 0,
                "tid": ev.tid,
                "args": dict(ev.meta, seq=ev.seq),
            }
            if ev.kind == "span":
                obj["ph"] = "X"
                obj["dur"] = round(ev.dur_us, 3)
            else:
                obj["ph"] = "i"
                obj["s"] = "t"
            out.append(obj)
        with open(path, "w") as f:
            json.dump(
                {
                    "traceEvents": out,
                    "displayTimeUnit": "ms",
                    "otherData": self._header(len(events)),
                },
                f,
            )
        return len(events)

    def export(self, path: str) -> int:
        """Format by extension: ``.json``/``.trace`` -> Chrome trace,
        anything else (the ``.trace.jsonl`` convention) -> JSONL."""
        if path.endswith((".json", ".trace")):
            return self.export_chrome_trace(path)
        return self.export_jsonl(path)


# --- the process-default tracer --------------------------------------------
#
# One tracer per process is the common case (the CLI flags, the bench
# harness); tests build private Tracer instances.

TRACER = Tracer()


def enable(capacity: int | None = None) -> Tracer:
    """Reset and switch on the default tracer."""
    TRACER.reset(capacity=capacity)
    TRACER.enabled = True
    return TRACER


def disable() -> None:
    TRACER.enabled = False


def enabled() -> bool:
    return TRACER.enabled


def span(name: str, **meta):
    return TRACER.span(name, **meta)


def instant(name: str, **meta) -> None:
    TRACER.instant(name, **meta)


def log(name: str, **fields) -> None:
    TRACER.log(name, **fields)


def clock() -> float:
    return TRACER.clock()


# --- JSONL schema validation ------------------------------------------------
#
# The checker the CI telemetry smoke runs (via repro.launch.monitor
# --check): every line must parse and carry the schema's required
# fields.  Kept here so exporter and checker can never drift apart.

_REQUIRED = {"kind", "name", "ts_us", "seq"}
_KINDS = {"span", "instant"}


def validate_event(obj: dict) -> str | None:
    """None if ``obj`` is a valid trace event/header, else the problem."""
    if not isinstance(obj, dict):
        return f"event is not an object: {type(obj).__name__}"
    kind = obj.get("kind")
    if kind == "trace_meta":
        if obj.get("schema") != SCHEMA_VERSION:
            return f"unsupported schema {obj.get('schema')!r}"
        return None
    if kind not in _KINDS:
        return f"unknown kind {kind!r}"
    missing = _REQUIRED - obj.keys()
    if missing:
        return f"missing fields {sorted(missing)}"
    if not isinstance(obj["name"], str) or not obj["name"]:
        return f"bad name {obj.get('name')!r}"
    if not isinstance(obj["ts_us"], (int, float)):
        return f"bad ts_us {obj.get('ts_us')!r}"
    if kind == "span":
        dur = obj.get("dur_us")
        if not isinstance(dur, (int, float)) or dur < 0:
            return f"span needs dur_us >= 0, got {dur!r}"
    meta = obj.get("meta", {})
    if not isinstance(meta, dict):
        return f"meta must be an object, got {type(meta).__name__}"
    return None


def validate_jsonl(path: str) -> list[str]:
    """All schema problems in a JSONL trace file (empty = valid).
    Problems are ``line N: <what>`` strings."""
    problems = []
    n_lines = 0
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            n_lines += 1
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                problems.append(f"line {i}: not JSON ({e})")
                continue
            err = validate_event(obj)
            if err:
                problems.append(f"line {i}: {err}")
    if n_lines == 0:
        problems.append("empty trace file")
    return problems
