"""The metrics registry (DESIGN.md §Telemetry).

Counters, gauges and histograms with label sets — the workload-level
quantities the scheduler, executor and ``run_resumable`` publish between
chunks (requests admitted/retired, wait/service time, segments run,
checkpoint bytes).  Zero dependencies; three read surfaces:

  * ``snapshot()`` — a plain dict, the programmatic API and what the
    JSONL flusher serialises;
  * ``flush_jsonl(path)`` — append one timestamped snapshot line
    (periodic flushing = calling this between chunks via
    ``JsonlFlusher``, which rate-limits to ``interval_s``);
  * ``prometheus_text()`` — the one-shot Prometheus exposition-format
    dump (``# TYPE`` headers, ``name{k="v"} value`` samples,
    ``_bucket``/``_sum``/``_count`` histogram series) for scrape-style
    consumers without running a server.

Metrics are additive bookkeeping on host-side paths that already run
per-chunk; they are always live (no enable flag) because their cost is
one dict update per event — the tracing ring buffer is the part that
needs an off switch (tracing.py's overhead contract).
"""

from __future__ import annotations

import json
import threading
import time

DEFAULT_BUCKETS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
    5.0, 10.0, 30.0, 60.0,
)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: tuple) -> str:
    return ",".join(f"{k}={v}" for k, v in key)


def _prom_labels(key: tuple, extra: tuple = ()) -> str:
    pairs = [f'{k}="{v}"' for k, v in (*key, *extra)]
    return "{" + ",".join(pairs) + "}" if pairs else ""


class Counter:
    """A monotonically increasing sum per label set."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: dict[tuple, float] = {}
        self._lock = threading.Lock()

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counters only go up, got {value}")
        key = _label_key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + value

    def value(self, **labels) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def snapshot(self) -> dict:
        return {_label_str(k): v for k, v in sorted(self._values.items())}

    def prometheus(self) -> list[str]:
        lines = [f"# TYPE {self.name} counter"]
        if self.help:
            lines.insert(0, f"# HELP {self.name} {self.help}")
        for key, v in sorted(self._values.items()):
            lines.append(f"{self.name}{_prom_labels(key)} {v:g}")
        return lines


class Gauge:
    """A point-in-time value per label set."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: dict[tuple, float] = {}
        self._lock = threading.Lock()

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._values[_label_key(labels)] = float(value)

    def value(self, **labels) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def snapshot(self) -> dict:
        return {_label_str(k): v for k, v in sorted(self._values.items())}

    def prometheus(self) -> list[str]:
        lines = [f"# TYPE {self.name} gauge"]
        if self.help:
            lines.insert(0, f"# HELP {self.name} {self.help}")
        for key, v in sorted(self._values.items()):
            lines.append(f"{self.name}{_prom_labels(key)} {v:g}")
        return lines


class Histogram:
    """Cumulative-bucket histogram per label set (Prometheus semantics:
    ``le`` buckets are cumulative counts, plus ``sum``/``count``)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "", buckets=DEFAULT_BUCKETS):
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError(f"buckets must be ascending, got {buckets}")
        self.name = name
        self.help = help
        self.buckets = tuple(float(b) for b in buckets)
        # per label set: [per-bucket counts..., +Inf count], sum
        self._values: dict[tuple, list] = {}
        self._lock = threading.Lock()

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            entry = self._values.get(key)
            if entry is None:
                entry = [[0] * (len(self.buckets) + 1), 0.0]
                self._values[key] = entry
            counts, _ = entry
            for i, b in enumerate(self.buckets):
                if value <= b:
                    counts[i] += 1
                    break
            else:
                counts[-1] += 1
            entry[1] += float(value)

    def _stats(self, entry) -> dict:
        counts, total = entry
        n = sum(counts)
        return {
            "count": n,
            "sum": round(total, 9),
            "mean": round(total / n, 9) if n else 0.0,
            "buckets": {
                **{f"le_{b:g}": c for b, c in zip(self.buckets, counts)},
                "le_inf": counts[-1],
            },
        }

    def snapshot(self) -> dict:
        return {
            _label_str(k): self._stats(e)
            for k, e in sorted(self._values.items())
        }

    def prometheus(self) -> list[str]:
        lines = [f"# TYPE {self.name} histogram"]
        if self.help:
            lines.insert(0, f"# HELP {self.name} {self.help}")
        for key, (counts, total) in sorted(self._values.items()):
            cum = 0
            for b, c in zip(self.buckets, counts):
                cum += c
                lines.append(
                    f"{self.name}_bucket"
                    f"{_prom_labels(key, (('le', f'{b:g}'),))} {cum}"
                )
            cum += counts[-1]
            lines.append(
                f"{self.name}_bucket"
                f"{_prom_labels(key, (('le', '+Inf'),))} {cum}"
            )
            lines.append(f"{self.name}_sum{_prom_labels(key)} {total:g}")
            lines.append(f"{self.name}_count{_prom_labels(key)} {cum}")
        return lines


class MetricsRegistry:
    """Named metric instruments, created on first use and type-checked
    on every reuse (a name is one instrument forever)."""

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, **kwargs):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help=help, **kwargs)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} is a {m.kind}, not a "
                    f"{cls.kind}"
                )
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(
        self, name: str, help: str = "", buckets=DEFAULT_BUCKETS
    ) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()

    def snapshot(self) -> dict:
        """{name: {"type": ..., "values": {label_str: value}}} — the
        programmatic read surface and the JSONL flush payload."""
        with self._lock:
            metrics = dict(self._metrics)
        return {
            name: {"type": m.kind, "values": m.snapshot()}
            for name, m in sorted(metrics.items())
        }

    def flush_jsonl(self, path: str) -> None:
        """Append one timestamped snapshot line (the periodic-flush
        primitive; ``JsonlFlusher`` rate-limits calls to it)."""
        line = {"ts_unix": round(time.time(), 3), "metrics": self.snapshot()}
        with open(path, "a") as f:
            f.write(json.dumps(line, sort_keys=True) + "\n")

    def prometheus_text(self) -> str:
        """One-shot Prometheus exposition-format dump."""
        with self._lock:
            metrics = dict(self._metrics)
        lines = []
        for _, m in sorted(metrics.items()):
            lines.extend(m.prometheus())
        return "\n".join(lines) + ("\n" if lines else "")


class JsonlFlusher:
    """Periodic JSONL flushing without threads: call ``maybe_flush()``
    wherever the host loop already runs between chunks; it writes at
    most once per ``interval_s``.  ``close()`` writes the final
    snapshot unconditionally."""

    def __init__(
        self, registry: MetricsRegistry, path: str, interval_s: float = 5.0
    ):
        if interval_s < 0:
            raise ValueError(f"interval_s must be >= 0, got {interval_s}")
        self.registry = registry
        self.path = path
        self.interval_s = float(interval_s)
        self._last = float("-inf")

    def maybe_flush(self) -> bool:
        now = time.perf_counter()
        if now - self._last < self.interval_s:
            return False
        self._last = now
        self.registry.flush_jsonl(self.path)
        return True

    def close(self) -> None:
        self.registry.flush_jsonl(self.path)


# the process-default registry — what the runtime layers publish into
REGISTRY = MetricsRegistry()


def counter(name: str, help: str = "") -> Counter:
    return REGISTRY.counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    return REGISTRY.gauge(name, help)


def histogram(name: str, help: str = "", buckets=DEFAULT_BUCKETS) -> Histogram:
    return REGISTRY.histogram(name, help, buckets=buckets)


def snapshot() -> dict:
    return REGISTRY.snapshot()
