# Unified telemetry (DESIGN.md §Telemetry): three zero-dependency pieces
# shared by every runtime layer —
#
#   tracing   span("engine.submit", ...) context managers -> an
#             in-process ring buffer -> JSONL / Chrome-trace exporters
#             (off by default; the disabled path is one attribute check)
#   metrics   counters/gauges/histograms with label sets, published by
#             the scheduler/executor/run_resumable; snapshot() dict,
#             periodic JSONL flush, one-shot Prometheus text export
#   health    threshold checks over the existing StreamingChainStats /
#             SwapStats / latency_summary accumulators -> structured
#             HealthAlert records + SamplerHealthWarning warnings
#
# Instrumentation sites are host-side and per-chunk/per-segment — never
# per chain step — and never touch the sampled stream (bit-parity with
# telemetry on vs off is asserted in tests/test_telemetry.py; the
# disabled-mode overhead is bench-gated in benchmarks/bench_telemetry.py).

from repro.telemetry.health import (
    HealthAlert,
    HealthMonitor,
    HealthThresholds,
    SamplerHealthWarning,
)
from repro.telemetry.metrics import (
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    JsonlFlusher,
    MetricsRegistry,
    counter,
    gauge,
    histogram,
    snapshot,
)
from repro.telemetry.tracing import (
    SCHEMA_VERSION,
    TRACER,
    TraceEvent,
    Tracer,
    clock,
    disable,
    enable,
    enabled,
    instant,
    log,
    span,
    validate_event,
    validate_jsonl,
)

__all__ = [
    # tracing
    "Tracer",
    "TraceEvent",
    "TRACER",
    "SCHEMA_VERSION",
    "enable",
    "disable",
    "enabled",
    "span",
    "instant",
    "log",
    "clock",
    "validate_event",
    "validate_jsonl",
    # metrics
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "JsonlFlusher",
    "REGISTRY",
    "counter",
    "gauge",
    "histogram",
    "snapshot",
    # health
    "HealthMonitor",
    "HealthThresholds",
    "HealthAlert",
    "SamplerHealthWarning",
]
