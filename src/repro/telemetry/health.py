"""Sampler health monitoring (DESIGN.md §Telemetry).

A production sampler's failure modes are statistical, not crashes: an
acceptance rate that collapses when a proposal scale is wrong, chains
whose split-R-hat diverges because they never mixed, a tempering ladder
whose walkers stall at one temperature, a serving tier whose p99 quietly
blows its SLO.  ``HealthMonitor`` consumes the accumulators the repo
already maintains — ``WorkloadRun.diagnostics`` bundles
(``StreamingChainStats`` output), ``SwapStats``, the serving tier's
``latency_summary`` — between chunks / after runs, and turns threshold
breaches into *structured* alerts:

  * each alert is a ``HealthAlert`` (kind, severity, message, data) the
    caller can route;
  * each alert raises a ``SamplerHealthWarning`` through the stdlib
    ``warnings`` machinery (filterable, testable with ``pytest.warns``);
  * each alert is logged through the telemetry tracer (an instant event
    named ``health.<kind>`` when tracing is on) and counted in the
    metrics registry (``sampler_health_alerts_total`` by kind).

The monitor never touches device values — it reads host-side floats the
layers already computed, so health checking costs nothing on the
sampling path.
"""

from __future__ import annotations

import dataclasses
import math
import warnings

from repro.telemetry import metrics as _metrics
from repro.telemetry import tracing as _tracing


class SamplerHealthWarning(UserWarning):
    """Category for sampler-health alerts (filter with the stdlib
    ``warnings`` machinery)."""


@dataclasses.dataclass(frozen=True)
class HealthThresholds:
    """Trigger levels; ``None`` disables the corresponding check."""

    # chain health
    min_acceptance: float | None = 0.01   # accept/flip-rate collapse
    max_acceptance: float | None = None   # e.g. 0.999: no-reject suspicion
    max_rhat: float | None = 1.2          # split-R-hat divergence
    # tempering health
    min_swap_rate: float | None = 0.02    # a ~0 pair splits the ladder
    stall_events: int = 8                 # swap events before walkers
    #                                       with zero round trips count
    #                                       as stalled
    # serving SLOs (None = not enforced)
    p99_latency_slo_s: float | None = None
    max_wait_slo_s: float | None = None

    def __post_init__(self):
        if self.stall_events < 1:
            raise ValueError(
                f"stall_events must be >= 1, got {self.stall_events}"
            )


@dataclasses.dataclass(frozen=True)
class HealthAlert:
    """One structured breach: machine-routable kind + evidence."""

    kind: str        # acceptance_collapse | rhat_divergence | ...
    severity: str    # "warn" | "critical"
    message: str
    data: dict

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class HealthMonitor:
    """Threshold checks over the existing accumulators.

    Alerts accumulate on the monitor (``monitor.alerts``) so a serve
    loop can poll them between chunks; every ``check_*`` also returns
    just the alerts it raised.  ``warn=False`` suppresses the stdlib
    warning (the CLI prints alerts itself).
    """

    def __init__(
        self,
        thresholds: HealthThresholds = HealthThresholds(),
        *,
        warn: bool = True,
    ):
        self.thresholds = thresholds
        self.warn = warn
        self.alerts: list[HealthAlert] = []

    # -- emission -------------------------------------------------------
    def _emit(
        self, kind: str, message: str, data: dict, severity: str = "warn"
    ) -> HealthAlert:
        alert = HealthAlert(
            kind=kind, severity=severity, message=message, data=data
        )
        self.alerts.append(alert)
        _tracing.log(f"health.{kind}", severity=severity, **data)
        _metrics.counter(
            "sampler_health_alerts_total",
            "sampler health alerts by kind",
        ).inc(kind=kind)
        if self.warn:
            warnings.warn(
                SamplerHealthWarning(f"[{kind}] {message}"), stacklevel=3
            )
        return alert

    # -- chain health ---------------------------------------------------
    def check_acceptance(
        self, rate: float, *, label: str = "acceptance_rate", where: str = ""
    ) -> list[HealthAlert]:
        """Accept/flip-rate collapse (and optional saturation)."""
        t = self.thresholds
        rate = float(rate)
        out = []
        if t.min_acceptance is not None and rate < t.min_acceptance:
            out.append(
                self._emit(
                    "acceptance_collapse",
                    f"{label} {rate:.4g} < {t.min_acceptance:g}"
                    + (f" ({where})" if where else ""),
                    {"rate": rate, "label": label, "where": where,
                     "threshold": t.min_acceptance},
                    severity="critical",
                )
            )
        if t.max_acceptance is not None and rate > t.max_acceptance:
            out.append(
                self._emit(
                    "acceptance_saturated",
                    f"{label} {rate:.4g} > {t.max_acceptance:g}"
                    + (f" ({where})" if where else ""),
                    {"rate": rate, "label": label, "where": where,
                     "threshold": t.max_acceptance},
                )
            )
        return out

    def check_chain_stats(self, stats, *, where: str = "") -> list[HealthAlert]:
        """R-hat divergence from a ``StreamingChainStats`` accumulator or
        an already-summarised diagnostics dict (the
        ``WorkloadRun.diagnostics`` bundle)."""
        t = self.thresholds
        out = []
        if isinstance(stats, dict):
            rhat = stats.get("split_rhat")
        else:  # a StreamingChainStats (or anything quacking like one)
            rhat = stats.split_rhat()
        if rhat is None or t.max_rhat is None:
            return out
        rhat = float(rhat)
        if not math.isfinite(rhat) or rhat > t.max_rhat:
            out.append(
                self._emit(
                    "rhat_divergence",
                    f"split-R-hat {rhat:.4g} > {t.max_rhat:g}"
                    + (f" ({where})" if where else ""),
                    {"split_rhat": rhat, "where": where,
                     "threshold": t.max_rhat},
                )
            )
        return out

    # -- tempering health -----------------------------------------------
    def check_swap_stats(self, swap, *, where: str = "") -> list[HealthAlert]:
        """Ladder bottlenecks + stalled walkers from a ``SwapStats``."""
        t = self.thresholds
        out = []
        rates = swap.pair_accept_rates()
        if t.min_swap_rate is not None:
            for pair, rate in enumerate(rates):
                if rate == rate and rate < t.min_swap_rate:  # NaN = untried
                    out.append(
                        self._emit(
                            "swap_bottleneck",
                            f"pair ({pair},{pair + 1}) swap rate "
                            f"{rate:.4g} < {t.min_swap_rate:g} — the "
                            "ladder is split at this temperature"
                            + (f" ({where})" if where else ""),
                            {"pair": pair, "rate": float(rate),
                             "where": where,
                             "threshold": t.min_swap_rate},
                        )
                    )
        if swap.events >= t.stall_events and swap.round_trips == 0:
            out.append(
                self._emit(
                    "stalled_walkers",
                    f"0 round trips after {swap.events} swap events — "
                    "walkers are not traversing the ladder"
                    + (f" ({where})" if where else ""),
                    {"events": int(swap.events), "round_trips": 0,
                     "where": where, "threshold": t.stall_events},
                )
            )
        return out

    # -- serving health --------------------------------------------------
    def check_serving(self, summary: dict, *, where: str = "") -> list[HealthAlert]:
        """SLO breaches from a ``latency_summary`` row."""
        t = self.thresholds
        out = []
        p99 = summary.get("p99_latency_s")
        if (
            t.p99_latency_slo_s is not None
            and p99 is not None
            and float(p99) > t.p99_latency_slo_s
        ):
            out.append(
                self._emit(
                    "latency_slo_breach",
                    f"p99 latency {float(p99):.4g}s > SLO "
                    f"{t.p99_latency_slo_s:g}s"
                    + (f" ({where})" if where else ""),
                    {"p99_latency_s": float(p99), "where": where,
                     "threshold": t.p99_latency_slo_s},
                    severity="critical",
                )
            )
        wait = summary.get("p99_wait_s", summary.get("mean_wait_s"))
        if (
            t.max_wait_slo_s is not None
            and wait is not None
            and float(wait) > t.max_wait_slo_s
        ):
            out.append(
                self._emit(
                    "wait_slo_breach",
                    f"queue wait {float(wait):.4g}s > SLO "
                    f"{t.max_wait_slo_s:g}s"
                    + (f" ({where})" if where else ""),
                    {"wait_s": float(wait), "where": where,
                     "threshold": t.max_wait_slo_s},
                )
            )
        return out
