"""Learning-rate schedules (scale factors multiplying AdamWConfig.lr)."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup_steps: int):
    step = jnp.asarray(step, jnp.float32)
    return jnp.minimum(1.0, (step + 1.0) / jnp.maximum(1.0, float(warmup_steps)))


def cosine_schedule(step, warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    """Linear warmup then cosine decay to final_frac of peak."""
    step = jnp.asarray(step, jnp.float32)
    warm = linear_warmup(step, warmup_steps)
    progress = jnp.clip(
        (step - warmup_steps) / jnp.maximum(1.0, float(total_steps - warmup_steps)),
        0.0,
        1.0,
    )
    cos = final_frac + (1.0 - final_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    return jnp.where(step < warmup_steps, warm, cos)
