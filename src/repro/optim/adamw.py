"""AdamW with global-norm clipping and ZeRO-1 style sharded states.

Built from scratch (no optax in this container).  Optimizer moments are
float32 regardless of the (usually bf16) param dtype; the first/second
moments inherit each param's logical axes *plus* a ZeRO extension: the
largest replicated dim divisible by the full DP extent is bound to
("pod", "data") via ``add_zero_axes``, so m/v/master shard over data
parallelism the way ZeRO-1 does.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import add_zero_axes, get_rules, shard
from repro.models.layers import LogicalAxes


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                 # peak; schedules multiply this
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    use_master: bool = False         # keep f32 master copies of bf16 params


def zero_axes_tree(params, axes_tree):
    """Extend each param's logical axes with the ZeRO DP axis."""

    def f(v, a):
        names = a.names if isinstance(a, LogicalAxes) else tuple(a)
        return LogicalAxes(add_zero_axes(names, v.shape))

    return jax.tree.map(f, params, axes_tree)


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()):
    """Returns opt_state pytree: {step, m, v[, master]}."""
    f32_zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(f32_zeros, params),
        "v": jax.tree.map(f32_zeros, params),
    }
    if cfg.use_master:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def opt_state_axes(params_shapes, axes_tree, cfg: AdamWConfig = AdamWConfig()):
    """Logical axes for the opt state (ZeRO-extended) for sharding specs."""
    zaxes = zero_axes_tree(params_shapes, axes_tree)
    state_axes = {"step": LogicalAxes(()), "m": zaxes, "v": zaxes}
    if cfg.use_master:
        state_axes["master"] = zaxes
    return state_axes


def global_norm(tree) -> jnp.ndarray:
    leaves = [
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads,
    opt_state,
    params,
    cfg: AdamWConfig = AdamWConfig(),
    lr_scale=1.0,
    axes_tree=None,
):
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    lr = cfg.lr * lr_scale

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    treedef = jax.tree.structure(params)
    p_list = jax.tree.leaves(params)
    g_list = jax.tree.leaves(grads)
    m_list = jax.tree.leaves(opt_state["m"])
    v_list = jax.tree.leaves(opt_state["v"])
    master_list = (
        jax.tree.leaves(opt_state["master"]) if "master" in opt_state else [None] * len(p_list)
    )
    if axes_tree is not None:
        za_list = jax.tree.leaves(
            zero_axes_tree(params, axes_tree),
            is_leaf=lambda x: isinstance(x, LogicalAxes),
        )
        rules = get_rules().replace(_zero=("pod", "data"))
    else:
        za_list = [None] * len(p_list)
        rules = None

    new_p, new_m, new_v, new_master = [], [], [], []
    for p, g, m, v, master, za in zip(
        p_list, g_list, m_list, v_list, master_list, za_list
    ):
        g = g.astype(jnp.float32) * clip
        m_n = cfg.b1 * m + (1.0 - cfg.b1) * g
        v_n = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        if za is not None:
            m_n = shard(m_n, za.names, rules)
            v_n = shard(v_n, za.names, rules)
        update = (m_n / b1c) / (jnp.sqrt(v_n / b2c) + cfg.eps)
        p32 = (master if master is not None else p).astype(jnp.float32)
        p32_n = p32 - lr * (update + cfg.weight_decay * p32)
        if master is not None:
            if za is not None:
                p32_n = shard(p32_n, za.names, rules)
            new_master.append(p32_n)
        new_p.append(p32_n.astype(p.dtype))
        new_m.append(m_n)
        new_v.append(v_n)

    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = {
        "step": step,
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
    }
    if "master" in opt_state:
        new_state["master"] = jax.tree.unflatten(treedef, new_master)
    metrics = {"grad_norm": gn, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, new_state, metrics
