from repro.data.pipeline import (  # noqa: F401
    DataConfig,
    MarkovSource,
    SyntheticTokenPipeline,
    UniformSource,
)
