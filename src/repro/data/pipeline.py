"""Deterministic, host-sharded synthetic token pipeline.

Every global batch is a pure function of ``(seed, step)`` — any restart,
reshard, or elastic rescale replays *identical* global data (the property
the fault-tolerance layer relies on).  Host-sharding: a host materialises
only its slice ``[host_id * per_host, (host_id+1) * per_host)`` of the
global batch; slices are carved from the same stateless stream so the
global batch is invariant to the host count.

Two sources:

* ``UniformSource`` — i.i.d. uniform tokens (shape/perf testing).
* ``MarkovSource`` — tokens follow a fixed random first-order Markov chain
  over the vocabulary (a nod to the paper).  An LM fits the bigram
  structure, so training loss has real signal: loss -> H(chain) < log V.
  The stationary entropy is computable for validation.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "markov"          # markov | uniform
    branching: int = 16              # successors per state (markov)
    n_hosts: int = 1
    host_id: int = 0

    @property
    def per_host(self) -> int:
        if self.global_batch % self.n_hosts:
            raise ValueError(
                f"global_batch {self.global_batch} not divisible by "
                f"{self.n_hosts} hosts"
            )
        return self.global_batch // self.n_hosts


class UniformSource:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_rows(self, step: int, row_lo: int, row_hi: int):
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        # one key per global row so slices are host-count invariant
        rows = []
        for r in range(row_lo, row_hi):
            rk = jax.random.fold_in(key, r)
            rows.append(
                jax.random.randint(rk, (cfg.seq_len + 1,), 0, cfg.vocab_size)
            )
        return jnp.stack(rows).astype(jnp.int32)


class MarkovSource:
    """First-order Markov chain with ``branching`` successors per state."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed + 7919)
        v, b = cfg.vocab_size, min(cfg.branching, cfg.vocab_size)
        self.successors = jnp.asarray(
            rng.integers(0, v, size=(v, b)), dtype=jnp.int32
        )  # (V, B) allowed next-tokens per state
        logits = rng.normal(size=(v, b))
        self.probs = jnp.asarray(
            np.exp(logits) / np.exp(logits).sum(-1, keepdims=True),
            dtype=jnp.float32,
        )

    def entropy_per_token(self) -> float:
        """Mean conditional entropy (nats) — the achievable CE floor."""
        p = np.asarray(self.probs)
        return float(-(p * np.log(p)).sum(-1).mean())

    def _row(self, key):
        cfg = self.cfg
        k0, k1 = jax.random.split(key)
        state0 = jax.random.randint(k0, (), 0, cfg.vocab_size)

        def step_fn(state, k):
            nxt_idx = jax.random.categorical(k, jnp.log(self.probs[state]))
            nxt = self.successors[state, nxt_idx]
            return nxt, nxt

        keys = jax.random.split(k1, cfg.seq_len)
        _, toks = jax.lax.scan(step_fn, state0, keys)
        return jnp.concatenate([state0[None], toks]).astype(jnp.int32)

    def batch_rows(self, step: int, row_lo: int, row_hi: int):
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        row_keys = jnp.stack(
            [jax.random.fold_in(key, r) for r in range(row_lo, row_hi)]
        )
        return jax.vmap(self._row)(row_keys)


class SyntheticTokenPipeline:
    """Yields {tokens, labels} batches; deterministic in (seed, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.source = (
            MarkovSource(cfg) if cfg.source == "markov" else UniformSource(cfg)
        )

    def global_batch(self, step: int):
        rows = self.source.batch_rows(step, 0, self.cfg.global_batch)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    def host_batch(self, step: int):
        cfg = self.cfg
        lo = cfg.host_id * cfg.per_host
        rows = self.source.batch_rows(step, lo, lo + cfg.per_host)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.host_batch(step)
            step += 1
