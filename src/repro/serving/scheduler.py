"""Request queue + slot assignment (DESIGN.md §Serving).

``ServeRequest`` is the serving tier's unit of work: a workload name, a
step budget, a seed, a collection mode and an arrival time.  The
``Scheduler`` owns a FIFO of pending requests and one ``PackedExecutor``
per distinct workload name; between chunks it admits ready requests into
free slots (strict arrival order — the queue head blocks until its
workload group has a free slot) and collects retired ones.

Determinism contract: a request's sample stream is a function of its
``(workload, seed, n_steps, collect)`` alone — never of which slot it
lands in, when it was admitted, or who shares the batch.  The executor
guarantees this via per-request keys + the ``step0`` resume axis; the
scheduler only decides *when* work happens, so admission policy can
change without touching numerics.

Timestamps (``t_arrive``/``t_admit``/``t_done``) share one clock, the
scheduler's serve-loop timebase (seconds from loop start).  ``t_done``
is stamped when the host *materialises* the result — after the dispatch
pipeline's deferred finalize — so latency percentiles measure delivery,
not device completion.
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import deque

import numpy as np

from repro import telemetry
from repro.samplers.engine import parse_collect
from repro.serving.executor import PackedExecutor


@dataclasses.dataclass
class ServeRequest:
    """One sampling request, plus the result/latency fields the serving
    tier fills in as it moves through the system.

    ``n_steps=None`` means the workload group's default step budget;
    ``collect`` is the engine's collection axis per request ("last" is
    the serving default — most clients want the final state, and it
    keeps the packed batch O(state)).  ``t_arrive`` is an offset in
    seconds from the serve loop's start (0 = already waiting).
    """

    rid: int
    workload: str = "ising"
    n_steps: int | None = None
    seed: int = 0
    collect: str = "last"
    t_arrive: float = 0.0

    # filled in by the executor
    t_admit: float | None = None
    t_done: float | None = None
    slot: int | None = None
    samples: np.ndarray | None = None       # kept stream (K, *state) uint32
    final_words: np.ndarray | None = None
    final_logp: np.ndarray | None = None
    accept_count: np.ndarray | None = None  # per-site, summed over segments
    acceptance_rate: float | None = None
    rate_label: str = "acceptance_rate"     # "flip_rate" under gibbs

    def __post_init__(self):
        parse_collect(self.collect)  # fail at submission, not admission
        if self.n_steps is not None and self.n_steps < 1:
            raise ValueError(f"n_steps must be >= 1, got {self.n_steps}")

    @property
    def wait_s(self) -> float | None:
        """Queue wait: arrival -> slot admission."""
        return None if self.t_admit is None else self.t_admit - self.t_arrive

    @property
    def service_s(self) -> float | None:
        """In-slot time: admission -> result materialised on the host."""
        if self.t_done is None or self.t_admit is None:
            return None
        return self.t_done - self.t_admit

    @property
    def latency_s(self) -> float | None:
        """End-to-end: arrival -> result materialised on the host."""
        return None if self.t_done is None else self.t_done - self.t_arrive


class FIFOQueue:
    """Arrival-ordered FIFO with wall-clock gating.

    Items are served strictly in push order; ``pop_ready(now)`` returns
    the head only once its arrival time has passed (push in arrival
    order — gating is head-based).  ``push_front`` returns an item the
    caller could not place (full slot pool) without losing its turn.
    Shared by the engine scheduler and the legacy ``launch.serve``
    overflow queue.
    """

    def __init__(self):
        self._q: deque = deque()

    def push(self, item, t_arrive: float = 0.0) -> None:
        self._q.append((float(t_arrive), item))

    def push_front(self, item, t_arrive: float = 0.0) -> None:
        self._q.appendleft((float(t_arrive), item))

    def pop_ready(self, now: float = math.inf):
        """The head item if it has arrived by ``now``, else None."""
        if self._q and self._q[0][0] <= now:
            return self._q.popleft()[1]
        return None

    def next_arrival(self) -> float | None:
        return self._q[0][0] if self._q else None

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)


class Scheduler:
    """Packs a request stream into executor slots, FIFO, between chunks.

    One ``PackedExecutor`` per **shape class**, created/extended on
    first use with this scheduler's group settings (randomness /
    execution / smoke / builder kwargs).  Under scan execution every
    uint32-state workload shares ONE class — a new workload name joins
    the existing executor as another ``lax.switch`` member, so a mixed
    ising+gmm burst fills one compiled program's slot axis.  Under
    pallas execution a class is one workload's kernel geometry, so
    mixed bursts run one packed kernel program per workload (still one
    program per class, never one per slot).  Seed-dependent *targets*
    (spin_glass couplings) are fixed by the group — the service hosts
    one problem instance and requests are independent chains on it;
    per-request seeds drive the init and the chain stream (see
    ``PackedExecutor.for_workload``).

    ``mesh`` (a concrete ``jax.sharding.Mesh``) shards the class
    program's slot axis across devices through the "chains" sharding
    rule — slots never communicate, so sharded serving is bit-identical
    to unsharded (scan execution only).
    """

    def __init__(
        self,
        n_slots: int = 4,
        *,
        randomness: str = "cim",
        execution: str = "scan",
        smoke: bool = True,
        chunk_steps: int | None = None,
        pipeline_depth: int = 2,
        workload_kwargs: dict | None = None,
        mesh=None,
    ):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = int(n_slots)
        self.randomness = randomness
        self.execution = execution
        self.smoke = smoke
        self.chunk_steps = chunk_steps
        self.pipeline_depth = pipeline_depth
        self.workload_kwargs = dict(workload_kwargs or {})
        self.mesh = mesh
        self.pending = FIFOQueue()
        self.executors: dict[tuple, PackedExecutor] = {}   # by shape class
        self._by_workload: dict[str, PackedExecutor] = {}
        self.done: list[ServeRequest] = []
        self._t0: float | None = None
        # optional telemetry.JsonlFlusher — the serve loop calls
        # maybe_flush() between chunks (rate-limited, host-side only)
        self.metrics_flusher = None

    # -- clock: one timebase for every stamp ---------------------------
    def clock(self) -> float:
        if self._t0 is None:
            self._t0 = time.perf_counter()
        return time.perf_counter() - self._t0 + self._skip

    _skip: float = 0.0  # virtual fast-forward (non-realtime idle gaps)

    # -- queue + groups ------------------------------------------------
    def submit(self, request: ServeRequest) -> None:
        self.pending.push(request, request.t_arrive)

    def _class_key(self, workload: str) -> tuple:
        """The shape-class identity a workload's requests pack under:
        scan packs every uint32-state workload into one flat-state class
        program; pallas classes are one workload's kernel geometry."""
        if self.execution == "pallas":
            return ("pallas", workload)
        return ("scan", "uint32")

    def executor_for(self, workload: str) -> PackedExecutor:
        ex = self._by_workload.get(workload)
        if ex is not None:
            return ex
        key = self._class_key(workload)
        ex = self.executors.get(key)
        if ex is None:
            ex = PackedExecutor.for_workload(
                workload,
                n_slots=self.n_slots,
                randomness=self.randomness,
                execution=self.execution,
                smoke=self.smoke,
                chunk_steps=self.chunk_steps,
                pipeline_depth=self.pipeline_depth,
                clock=self.clock,
                mesh=self.mesh,
                **self.workload_kwargs,
            )
            self.executors[key] = ex
        else:
            ex.add_workload(
                workload,
                randomness=self.randomness,
                execution=self.execution,
                smoke=self.smoke,
                **self.workload_kwargs,
            )
        self._by_workload[workload] = ex
        return ex

    @property
    def shape_classes(self) -> int:
        """Distinct compiled class programs currently serving requests."""
        return len(self.executors)

    @property
    def compiled_programs(self) -> int:
        """Total compiled advance programs across all classes (jit-cache
        growth — the compiled-programs-per-burst number the serving
        bench gates)."""
        return sum(ex.advance_compiles for ex in self.executors.values())

    @property
    def active(self) -> int:
        return sum(ex.active_count for ex in self.executors.values())

    def admit_ready(self, now: float = math.inf) -> int:
        """Admit arrived requests into free slots, strict FIFO.  Stops at
        the first request whose group is full (head-of-line blocking is
        the policy, not an accident — arrival order is the fairness
        contract)."""
        admitted = 0
        while True:
            req = self.pending.pop_ready(now)
            if req is None:
                break
            ex = self.executor_for(req.workload)
            if not ex.has_free_slot():
                self.pending.push_front(req, req.t_arrive)
                break
            ex.admit(req)
            telemetry.counter(
                "serving_requests_admitted_total", "requests admitted"
            ).inc(workload=req.workload)
            admitted += 1
        return admitted

    def step(self) -> list[ServeRequest]:
        """Advance every group one chunk; returns requests retired this
        chunk (results materialise once the dispatch pipeline flushes)."""
        retired: list[ServeRequest] = []
        for ex in self.executors.values():
            retired.extend(ex.advance_chunk())
        self.done.extend(retired)
        return retired

    def drain(self) -> None:
        for ex in self.executors.values():
            ex.drain()

    # -- the serve loop ------------------------------------------------
    def serve(
        self, requests=(), *, realtime: bool = False
    ) -> list[ServeRequest]:
        """Drive submitted + given requests to completion.

        The loop alternates admit -> advance-one-chunk; when every slot
        is idle but arrivals are still due, it either sleeps until the
        next arrival (``realtime=True``) or fast-forwards the clock —
        latency stats are identical either way, the non-realtime path
        just doesn't burn wall time on synthetic arrival gaps.
        """
        for r in sorted(requests, key=lambda r: r.t_arrive):
            self.submit(r)
        while self.pending or self.active:
            self.admit_ready(self.clock())
            telemetry.gauge(
                "serving_queue_depth", "pending requests"
            ).set(len(self.pending))
            telemetry.gauge(
                "serving_active_slots", "occupied slots"
            ).set(self.active)
            if self.metrics_flusher is not None:
                self.metrics_flusher.maybe_flush()
            if self.active:
                self.step()
                continue
            nxt = self.pending.next_arrival()
            if nxt is None:  # pragma: no cover - loop condition guards this
                break
            gap = nxt - self.clock()
            if gap > 0:
                if realtime:
                    time.sleep(min(gap, 0.05))
                else:
                    self._skip += gap
        self.drain()
        return self.done


def latency_summary(requests) -> dict:
    """Throughput + latency percentiles over finished requests — the
    row shape ``bench_serving`` and ``serve_engine`` both report.

    Latency decomposes as wait (arrival -> admission, the queueing cost
    the *scheduler* controls) + service (admission -> host-materialised
    result, the cost the *executor* controls); the split is reported so
    an SLO breach points at the right layer.
    """
    done = [r for r in requests if r.t_done is not None]
    if not done:
        return {"n_requests": 0}
    lat = np.asarray([r.latency_s for r in done], np.float64)
    wait = np.asarray([r.wait_s for r in done], np.float64)
    service = np.asarray([r.service_s for r in done], np.float64)
    span = max(
        max(r.t_done for r in done) - min(r.t_arrive for r in done), 1e-9
    )
    return {
        "n_requests": len(done),
        "requests_per_s": round(len(done) / span, 2),
        "p50_latency_s": round(float(np.percentile(lat, 50)), 4),
        "p99_latency_s": round(float(np.percentile(lat, 99)), 4),
        "mean_wait_s": round(float(wait.mean()), 4),
        "p99_wait_s": round(float(np.percentile(wait, 99)), 4),
        "mean_service_s": round(float(service.mean()), 4),
        "p50_service_s": round(float(np.percentile(service, 50)), 4),
        "p99_service_s": round(float(np.percentile(service, 99)), 4),
    }
