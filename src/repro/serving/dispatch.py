"""Packed device programs + host/device overlap for the serving tier
(DESIGN.md §Serving).

Three pieces:

  * ``make_class_advance_fn`` builds THE packed-segment program for a
    *shape class* — the set of workload members whose requests share one
    compiled ``jit(vmap(...))``.  Slot state is stored flat (one padded
    uint32 vector per slot) and each slot carries a member index; inside
    the vmap a ``lax.switch`` over the class's member table reshapes the
    slot's vector into that member's state layout and runs its engine —
    so a mixed ising+gmm burst fills ONE program's slot axis instead of
    round-robining one program per workload group.  Per-slot *traced*
    ``step0`` offsets keep every request on its solo stream.  With a
    ``mesh``, the slot axis is sharded via the standard "chains"
    sharding rule (slots, like chains, never communicate — the sharded
    program is collective-free and bit-identical).
  * ``make_pallas_advance_fn`` is the pallas-execution edition: all
    slots fold into ONE batched fused-kernel grid (the §Chains-axis
    fold, with per-slot keys and per-slot operand ``step0`` — the fused
    kernels take the absolute-step base as a runtime operand, so
    heterogeneous slot offsets share one compiled kernel).  This
    replaces the historical per-slot solo-submit fallback.
  * ``SegmentPipeline`` bounds how far host-side finalisation may lag
    the device.  The executor pushes one finalize thunk per segment
    (with all needed device slices already enqueued); the pipeline runs
    the oldest thunk only once more than ``depth`` segments are in
    flight, so the host converts/retires segment k's results while the
    device runs segment k+1.

The carried slot state is **donated** segment-to-segment
(``donate_argnums``), so segment k+1's output reuses segment k's
allocation.  ``poison_donated`` enforces the executor-side contract that
retirement slices are enqueued *before* the next donating call: it
deletes the old carry buffers right after dispatch, so any stale read
raises deterministically instead of silently observing donated memory.
"""

from __future__ import annotations

from collections import deque
from functools import partial

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.samplers import RunPlan
from repro.samplers.engine import (
    _chains_fold_mh,
    _fused_gibbs_logit,
    _fused_key_cols,
)


def jit_cache_size(fn) -> int:
    """Compiled-program count of a jitted callable (0 when unknown) —
    the serving tier's compiled-programs-per-burst telemetry reads the
    delta across a burst, the same ``_cache_size`` verdict the Run-API's
    ``jit_cache`` span metadata is built on (samplers/plan.py)."""
    try:
        return int(fn._cache_size())
    except Exception:
        return 0


def poison_donated(*arrays) -> None:
    """Make the donation contract loud: delete the carry buffers that
    were just donated to an advance program.

    On backends that honor donation the inputs are already deleted and
    this is a no-op; on backends that silently copy, the stale values
    would remain readable and a bookkeeping bug (slicing retirement
    payloads *after* the next donating call) could hide indefinitely.
    After this, any read of an old carry reference raises
    RuntimeError deterministically on every backend.
    """
    for a in arrays:
        if a is None:
            continue
        delete = getattr(a, "delete", None)
        is_deleted = getattr(a, "is_deleted", None)
        if delete is None or is_deleted is None:
            continue
        try:
            if not a.is_deleted():
                delete()
        except RuntimeError:  # pragma: no cover - committed/tracer buffers
            pass


def _slot_axis_wrap(mesh, n_slots: int, n_in: int, n_out: int):
    """shard_map wrapper over the slot axis, or identity without a mesh.

    Slots resolve through the "chains" sharding rule (they are the same
    kind of axis: independent, never communicating), including the
    divisibility filter — a slot count the mesh doesn't divide runs
    replicated rather than padded.
    """
    if mesh is None:
        return lambda body: body
    from jax.experimental.shard_map import shard_map

    from repro.distributed import sharding

    spec = sharding.spec_for(("chains",), shape=(n_slots,), mesh=mesh)
    if spec is None or len(spec) == 0 or spec[0] is None:
        return lambda body: body
    p = jax.sharding.PartitionSpec(spec[0])
    return lambda body: shard_map(
        body,
        mesh=mesh,
        in_specs=tuple(p for _ in range(n_in)),
        out_specs=tuple(p for _ in range(n_out)),
        check_rep=False,
    )


def make_class_advance_fn(members, n_pad: int, n_slots: int, mesh=None):
    """The packed-segment program for one *shape class*.

    Returns ``advance(words, logp, keys, step0s, tidx, *, seg, collect)``
    -> ``(samples, words', logp', accept)``, each with a leading slot
    axis and flat padded state vectors of width ``n_pad``.  ``seg``
    (segment length) and ``collect`` are jit-static — a serving run
    touches only a handful of (seg, collect) signatures, and within one
    signature every segment of every member reuses the same trace.

    Slot s dispatches on ``tidx[s]`` via ``lax.switch`` over the class's
    member table: member m's branch unflattens ``words[s, :m.size]``
    into m's state layout and runs ``m.engine.run(keys[s], m.target,
    seg, ..., step0=step0s[s])`` — the exact solo-run call — then
    re-flattens and zero-pads back to ``n_pad``.  The packed batch is
    therefore bit-identical to per-request solo runs regardless of which
    members share the burst.  (Under vmap the switch lowers to a select
    over all branches — each slot pays every member's step math — which
    is the price of a single compiled program per class; single-member
    classes skip the switch entirely.)

    MH members carry (words, logp) across segments (``init_logp`` skips
    the boundary re-evaluation); Gibbs members read only words and
    return the final per-site conditional log-prob in the logp lane.
    Both buffers are donated either way so the slot pool never grows the
    heap.
    """
    members = list(members)

    def make_branch(m):
        size = m.size

        def branch(w_flat, lp_flat, k, s0, *, seg, collect):
            w = w_flat[:size].reshape(m.state_shape)
            kwargs = {}
            if m.carry_logp:
                kwargs["init_logp"] = lp_flat[:size].reshape(m.state_shape)
            res = m.engine.submit(
                RunPlan(
                    target=m.target, n_steps=seg, init_words=w, key=k,
                    step0=s0, collect=collect, **kwargs,
                )
            ).result
            pad = n_pad - size
            samples = res.samples.reshape(res.samples.shape[0], size)
            return (
                jnp.pad(samples, ((0, 0), (0, pad))),
                jnp.pad(res.final_words.reshape(size), (0, pad)),
                jnp.pad(
                    res.final_logp.astype(jnp.float32).reshape(size),
                    (0, pad),
                ),
                jnp.pad(res.accept_count.reshape(size), (0, pad)),
            )

        return branch

    branches = [make_branch(m) for m in members]
    wrap = _slot_axis_wrap(mesh, n_slots, n_in=5, n_out=4)

    @partial(
        jax.jit, static_argnames=("seg", "collect"), donate_argnums=(0, 1)
    )
    def advance(words, logp, keys, step0s, tidx, *, seg, collect):
        bound = [
            partial(b, seg=seg, collect=collect) for b in branches
        ]

        def one(w, lp, k, s0, ti):
            if len(bound) == 1:
                return bound[0](w, lp, k, s0)
            return jax.lax.switch(ti, bound, w, lp, k, s0)

        def body(w, lp, k, s0, ti):
            return jax.vmap(one)(w, lp, k, s0, ti)

        return wrap(body)(words, logp, keys, step0s, tidx)

    return advance


def make_pallas_advance_fn(engine, target, state_shape: tuple):
    """The packed pallas-segment program: one batched fused-kernel grid
    over ALL slots (no per-slot fallback).

    Returns ``advance(words, keys, step0s, *, seg, collect)`` ->
    ``(samples, words', logp', accept)``, each with a leading slot axis
    and the member's *shaped* state (pallas kernel geometry is per
    workload, so a pallas executor is a single-member class).  The fold
    is exactly the §Chains-axis fold with slots in place of chains —
    slot-major into the MH compartment axis (site = i·C + c stays the
    solo site index) or the Gibbs lattice-batch axis (i mod B stays the
    solo lattice index) — and the fused kernels take per-column /
    per-lattice key words AND the absolute-step base ``step0`` as
    runtime operands, so heterogeneous slot offsets (mid-flight joins)
    share one compiled program and every slot advances on its solo
    stream bit-for-bit.  Host/cim randomness ships per-slot operand
    chunks drawn at each slot's own offset instead.

    ``words`` is donated; MH re-derives the final log-prob from the
    table and Gibbs returns the final per-site conditional log-prob, so
    no logp carry crosses segments on this path.
    """
    from repro.samplers.randomness import chain_key

    backend = engine.randomness
    update = engine.config.update
    block_c = engine.config.block_c

    def _slot_chain_keys(keys):
        # engine.run derives every stream from chain_key(key, chain_id=0)
        # before touching the executors — replay that fold per slot so
        # the packed kernels read the exact solo streams
        return jax.vmap(lambda k: chain_key(k, 0))(keys)

    if update == "mh":
        from repro.kernels.mh import ops as mh_ops

        nbits = target.nbits
        b, c = state_shape

        @partial(
            jax.jit, static_argnames=("seg", "collect"), donate_argnums=(0,)
        )
        def advance(words, keys, step0s, *, seg, collect):
            s = words.shape[0]
            keys = _slot_chain_keys(keys)
            state0 = jnp.transpose(words, (1, 0, 2)).reshape(b, s * c)
            if backend.name == "fused":
                k0c, k1c = _fused_key_cols(keys, c)
                t0c = jnp.repeat(step0s.astype(jnp.int32), c)
                samples, acc = mh_ops.mh_sample_fused(
                    target.table, state0, k0c, k1c, n_steps=seg, t0=t0c,
                    nbits=nbits, p_bfr=backend.p_bfr, cc=c, block_c=block_c,
                )
            else:
                flips, u = jax.vmap(
                    lambda k, s0: backend.chunk(k, s0, seg, (b, c), nbits)
                )(keys, step0s)
                samples, acc = mh_ops.mh_sample(
                    target.table, state0, _chains_fold_mh(flips),
                    _chains_fold_mh(u), nbits=nbits, block_c=block_c,
                )
            # (seg, b, s*c) -> (s, seg, b, c); slot-major columns
            samples = jnp.moveaxis(samples.reshape(seg, b, s, c), 2, 0)
            acc = jnp.moveaxis(acc.reshape(b, s, c), 1, 0)
            words_out = samples[:, -1]
            logp = jax.vmap(
                lambda w: target.log_prob(w).astype(jnp.float32)
            )(words_out)
            if collect != "all":
                samples = samples[:, :0]
            return samples, words_out, logp, acc

    else:
        from repro.kernels.gibbs import ops as gibbs_ops

        logit_fn, consts = _fused_gibbs_logit(target)
        b, h, w = state_shape

        @partial(
            jax.jit, static_argnames=("seg", "collect"), donate_argnums=(0,)
        )
        def advance(words, keys, step0s, *, seg, collect):
            s = words.shape[0]
            keys = _slot_chain_keys(keys)
            state0 = words.reshape(s * b, h, w)
            if backend.name == "fused":
                k0b, k1b = _fused_key_cols(keys, b)
                t0b = jnp.repeat(step0s.astype(jnp.int32), b)
                samples, acc = gibbs_ops.gibbs_sweep_fused(
                    state0, k0b, k1b, logit_fn, n_steps=seg, t0=t0b,
                    lat_b=b, consts=consts,
                )
            else:
                u = jax.vmap(
                    lambda k, s0: backend.chunk(
                        k, s0, seg, (b, h, w), 1, need_flips=False
                    )[1]
                )(keys, step0s)
                u_fold = jnp.transpose(u, (1, 0, 2, 3, 4)).reshape(
                    seg, s * b, h, w
                )
                samples, acc = gibbs_ops.gibbs_sweep(
                    state0, u_fold, logit_fn,
                    parity0=jnp.repeat(step0s.astype(jnp.int32) % 2, b),
                    consts=consts,
                )
            # (seg, s*b, h, w) -> (s, seg, b, h, w); slot-major lattices
            samples = jnp.moveaxis(
                samples.reshape(seg, s, b, h, w), 1, 0
            )
            acc = acc.reshape(s, b, h, w)
            words_out = samples[:, -1]
            # the engine's Gibbs pseudo-likelihood of the final state
            logit = jax.vmap(target.conditional_logit)(words_out)
            logp = jnp.where(
                words_out == 1,
                jax.nn.log_sigmoid(logit),
                jax.nn.log_sigmoid(-logit),
            ).astype(jnp.float32)
            if collect != "all":
                samples = samples[:, :0]
            return samples, words_out, logp, acc

    return advance


class SegmentPipeline:
    """Run host finalize thunks at most ``depth`` segments behind the
    device.  ``push`` defers the thunk; once more than ``depth`` are
    pending the oldest runs (blocking on its device values only then).
    ``drain`` flushes everything — call it when the serve loop idles or
    ends."""

    def __init__(self, depth: int = 2):
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {depth}")
        self.depth = depth
        self._pending: deque = deque()

    def push(self, thunk) -> None:
        self._pending.append(thunk)
        while len(self._pending) > self.depth:
            # backpressure: the host is now > depth segments behind and
            # must block on the oldest segment's device values — the
            # span duration is the donation stall the pipeline absorbed
            with telemetry.span(
                "serving.pipeline_stall", pending=len(self._pending)
            ):
                self._pending.popleft()()

    def drain(self) -> None:
        while self._pending:
            self._pending.popleft()()
