"""Host/device overlap for the packed executor (DESIGN.md §Serving).

Two pieces:

  * ``make_advance_fn`` builds the jitted packed-segment program: a vmap
    of ``engine.run`` over the slot axis, with per-slot request keys and
    per-slot *traced* ``step0`` offsets (the scan executors accept traced
    stream offsets, so slots at different absolute steps advance in one
    device program).  The carried chain state is donated —
    ``donate_argnums`` on ``(words, logp)`` for the MH update (whose scan
    carry holds both) and on ``words`` for Gibbs — so segment k+1's
    output reuses segment k's allocation instead of growing the heap
    with the slot pool.
  * ``SegmentPipeline`` bounds how far host-side finalisation may lag
    the device.  The executor pushes one finalize thunk per segment
    (with all needed device slices already enqueued); the pipeline runs
    the oldest thunk only once more than ``depth`` segments are in
    flight, so the host converts/retires segment k's results while the
    device runs segment k+1 — JAX's async dispatch does the actual
    overlapping, the pipeline just keeps the lag bounded.
"""

from __future__ import annotations

from collections import deque
from functools import partial

import jax

from repro import telemetry
from repro.samplers import RunPlan


def make_advance_fn(engine, target):
    """The packed-segment program for one (engine, target) pair.

    Returns ``advance(words, logp, keys, step0s, *, seg, collect)`` ->
    ``(samples, words', logp', accept)``, each with a leading slot axis.
    ``seg`` (segment length) and ``collect`` are jit-static — a serving
    run touches only a handful of (seg, collect) signatures, and within
    one signature every segment reuses the same trace.

    Slot s runs ``engine.run(keys[s], target, seg, words[s],
    step0=step0s[s])`` — the exact solo-run call — so the packed batch
    is bit-identical to per-request solo runs (the §Chains-axis vmap
    argument, with per-request keys instead of counter-derived ones).
    """
    carry_logp = engine.config.update == "mh"

    if carry_logp:
        # the scan MH carry holds (words, logp): donate both, and hand
        # the carried logp back to the engine so the segment boundary
        # skips the target re-evaluation (engine.run ``init_logp``)
        @partial(
            jax.jit,
            static_argnames=("seg", "collect"),
            donate_argnums=(0, 1),
        )
        def advance(words, logp, keys, step0s, *, seg, collect):
            def one(k, w, lp, s0):
                # the RunPlan surface is traceable: per-slot traced
                # step0/state build a plan inside the vmap (§Run-API)
                res = engine.submit(
                    RunPlan(
                        target=target, n_steps=seg, init_words=w, key=k,
                        step0=s0, collect=collect, init_logp=lp,
                    )
                ).result
                return (
                    res.samples, res.final_words, res.final_logp,
                    res.accept_count,
                )

            return jax.vmap(one)(keys, words, logp, step0s)

    else:
        # the Gibbs carry holds only the lattice words; final_logp is
        # the conditional log-prob of the final state, recomputed by the
        # engine — the logp argument rides along unread for a uniform
        # executor-side calling convention
        @partial(
            jax.jit, static_argnames=("seg", "collect"), donate_argnums=(0,)
        )
        def advance(words, logp, keys, step0s, *, seg, collect):
            del logp

            def one(k, w, s0):
                res = engine.submit(
                    RunPlan(
                        target=target, n_steps=seg, init_words=w, key=k,
                        step0=s0, collect=collect,
                    )
                ).result
                return (
                    res.samples, res.final_words, res.final_logp,
                    res.accept_count,
                )

            return jax.vmap(one)(keys, words, step0s)

    return advance


class SegmentPipeline:
    """Run host finalize thunks at most ``depth`` segments behind the
    device.  ``push`` defers the thunk; once more than ``depth`` are
    pending the oldest runs (blocking on its device values only then).
    ``drain`` flushes everything — call it when the serve loop idles or
    ends."""

    def __init__(self, depth: int = 2):
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {depth}")
        self.depth = depth
        self._pending: deque = deque()

    def push(self, thunk) -> None:
        self._pending.append(thunk)
        while len(self._pending) > self.depth:
            # backpressure: the host is now > depth segments behind and
            # must block on the oldest segment's device values — the
            # span duration is the donation stall the pipeline absorbed
            with telemetry.span(
                "serving.pipeline_stall", pending=len(self._pending)
            ):
                self._pending.popleft()()

    def drain(self) -> None:
        while self._pending:
            self._pending.popleft()()
