"""Continuous-batching MCMC serving tier (DESIGN.md §Serving).

The first subsystem whose unit of work is a *request*, not a chain: a
sampling request names a workload, a step budget, a seed and a
collection mode, and the serving tier packs concurrent requests into the
chain axis of one engine program.  Three layers:

  * ``scheduler``  — the request queue + slot assignment
    (``ServeRequest``, ``FIFOQueue``, ``Scheduler``): requests wait in
    FIFO order, join free slots of the executor serving their workload,
    and retire between chunks.
  * ``executor``   — the packed batch program (``PackedExecutor``): all
    slots advance ``chunk_steps`` in one device program; per-slot
    ``step0`` offsets keep every request's randomness stream exactly the
    stream of its solo run, so joining mid-flight is bit-exact.  One
    executor is one *shape class*: under scan execution heterogeneous
    workloads join as ``lax.switch`` members of one flat-state program,
    under pallas all slots fold into one batched fused-kernel grid.
  * ``dispatch``   — the packed device programs + host/device overlap
    (``make_class_advance_fn``, ``make_pallas_advance_fn``,
    ``SegmentPipeline``, ``poison_donated``): the carried (words, logp)
    state is donated to the next segment — and poisoned after dispatch
    so stale reads fail loudly — while retirement bookkeeping for the
    previous segment runs on the host.

Entry points: ``python -m repro.launch.serve_engine`` (CLI) and
``benchmarks.bench_serving`` (requests/s + latency percentiles).
"""

from repro.serving.executor import PackedExecutor
from repro.serving.scheduler import (
    FIFOQueue,
    Scheduler,
    ServeRequest,
    latency_summary,
)

__all__ = [
    "FIFOQueue",
    "PackedExecutor",
    "Scheduler",
    "ServeRequest",
    "latency_summary",
]
