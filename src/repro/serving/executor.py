"""The packed batch program (DESIGN.md §Serving).

``PackedExecutor`` owns ``n_slots`` request slots and advances them in
lock-step ``chunk_steps`` segments.  Admission and retirement happen
only **between** chunks, and the packed batch is bit-identical to solo
runs because each slot replays exactly the solo call:

  * slot state is the engine carry with a leading slot axis, donated
    segment-to-segment (serving/dispatch.py) — stored *flat* (one
    zero-padded uint32 vector per slot) under scan execution so
    heterogeneous workload members share the pool, and shaped under
    pallas (kernel geometry is per workload);
  * each slot streams from its *request's* key (``PRNGKey(seed)`` split
    exactly as ``launch.sample`` does), so the stream belongs to the
    request, never to the slot — slot reuse after retirement is safe by
    construction;
  * each slot carries its absolute step as the engine's ``step0`` resume
    offset; both executors take it as a runtime value (the fused pallas
    kernels as a per-slot operand), so slots at different absolute steps
    advance in ONE device program and a request joining mid-flight
    continues the exact stream its solo run would produce.

**Shape classes**: one executor serves every workload member whose
requests can share its compiled advance program.  Under scan execution
the member table is open — ``add_member`` registers another workload
and the class program dispatches per-slot via ``lax.switch``
(dispatch.make_class_advance_fn), so a mixed ising+gmm burst fills one
program's slot axis.  Under pallas execution the executor is a
single-member class (one batched fused-kernel grid over all slots —
dispatch.make_pallas_advance_fn; the historical one-solo-submit-per-slot
fallback is gone).

Per-request collection: the segment program collects ``"all"`` iff any
active request keeps samples (else ``"last"`` — O(state) memory); a
``thin:k`` request then keeps the static strided slice of its slot's
rows on *absolute* steps ``(step0 + t) % k == 0``, bit-identical to the
engine's own ``thin`` stream (DESIGN.md §Collection).

Donation contract: retirement/collection slices MUST be enqueued before
the next donating advance — and the executor *enforces* it by poisoning
the donated carry buffers right after each dispatch
(dispatch.poison_donated), so a stale read raises instead of silently
observing reused memory.  ``advance_compiles`` counts compiled advance
programs (jit-cache growth), the compiled-programs-per-burst number the
serving benchmarks gate on.
"""

from __future__ import annotations

import dataclasses
import inspect
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry, workloads
from repro.samplers.engine import parse_collect, resolve_execution
from repro.serving import dispatch
from repro.serving.dispatch import SegmentPipeline

_DUMMY_KEY = np.zeros((2,), np.uint32)  # free slots advance discarded work


@dataclasses.dataclass(frozen=True)
class _Member:
    """One workload group inside a shape class: the (engine, target)
    pair plus the request plumbing and this member's slot-state layout.
    ``index`` is the member's branch position in the class program's
    ``lax.switch`` table."""

    name: str
    engine: object
    target: object
    state_shape: tuple
    request_init: object         # req -> (init_words, run_key, n_steps)
    default_steps: int | None
    index: int

    @property
    def size(self) -> int:
        return int(math.prod(self.state_shape))

    @property
    def carry_logp(self) -> bool:
        return self.engine.config.update == "mh"

    @property
    def rate_label(self) -> str:
        return (
            "flip_rate" if self.engine.config.update == "gibbs"
            else "acceptance_rate"
        )


@dataclasses.dataclass
class _Slot:
    """Executor-side bookkeeping for one admitted request."""

    req: object
    member: _Member
    remaining: int               # steps still to run
    mode: str                    # parsed collect mode: all | thin | last
    thin_k: int                  # stride under thin
    progress: int = 0            # absolute step == step0 of the next segment
    pieces: list = dataclasses.field(default_factory=list)  # device kept rows
    acc: object = None           # device per-site accept/flip accumulator
    final_words: object = None
    final_logp: object = None


def _workload_member_parts(
    name: str,
    *,
    randomness: str,
    execution: str,
    smoke: bool,
    **builder_kwargs,
):
    """(engine, target, state_shape, request_init, default_steps) for a
    workload group — engine + target built once (group key 0; for
    seed-dependent targets like spin_glass the group fixes the problem
    instance), requests supply per-request inits and streams.

    ``request_init`` replays the solo-run derivation of ``launch.sample``
    exactly: ``PRNGKey(seed)`` -> split -> (builder init from k_init,
    chain stream from k_run) — so a packed request reproduces
    ``engine.run(k_run, target, n, init)`` bit-for-bit.
    """
    builder = workloads.WORKLOADS[name]
    params = inspect.signature(builder).parameters
    kwargs = {
        k: v
        for k, v in dict(
            randomness=randomness,
            backend=execution,
            smoke=smoke,
            **builder_kwargs,
        ).items()
        if k in params and v is not None
    }
    template = workloads.build(name, jax.random.PRNGKey(0), **kwargs)

    def request_init(req):
        key = jax.random.PRNGKey(req.seed)
        k_init, k_run = jax.random.split(key)
        wl = workloads.build(name, k_init, **kwargs)
        n = req.n_steps if req.n_steps else wl.n_steps
        return wl.init_words, k_run, n

    return (
        template.engine,
        template.target,
        tuple(template.init_words.shape),
        request_init,
        template.n_steps,
    )


class PackedExecutor:
    """``n_slots`` heterogeneous requests packed into one device program.

    Construct via ``for_workload`` (the registry path the scheduler
    uses) or directly with an engine/target pair plus a
    ``request_init(req) -> (init_words, run_key, n_steps)`` callable
    (the hook tests use to pin exact solo references).  Additional
    workload members join a scan-execution executor via
    ``add_workload``/``add_member`` — the shape-class packing axis.
    """

    def __init__(
        self,
        engine,
        target,
        n_slots: int,
        state_shape: tuple,
        *,
        request_init,
        default_steps: int | None = None,
        chunk_steps: int | None = None,
        pipeline_depth: int = 2,
        clock=time.perf_counter,
        workload: str = "default",
        mesh=None,
    ):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self._check_engine(engine)
        self.n_slots = int(n_slots)
        self.chunk_steps = int(chunk_steps or engine.config.chunk_steps)
        self.clock = clock
        self.mesh = mesh
        self.execution = resolve_execution(
            engine.config.execution, target, engine.config.update
        )
        if mesh is not None and self.execution != "scan":
            raise ValueError(
                "mesh-sharded serving shards the slot axis of the scan "
                "class program — pallas execution folds slots into one "
                "kernel grid on a single device (use execution='scan' "
                "with a mesh)"
            )
        self.members: list[_Member] = [
            _Member(
                name=workload, engine=engine, target=target,
                state_shape=tuple(state_shape), request_init=request_init,
                default_steps=default_steps, index=0,
            )
        ]
        self.pipeline = SegmentPipeline(pipeline_depth)
        self.advance_compiles = 0    # compiled advance programs (cache growth)
        self._slots: list[_Slot | None] = [None] * self.n_slots
        self._keys: list = [_DUMMY_KEY] * self.n_slots
        if self.execution == "scan":
            self.n_pad = self.members[0].size
            self.words = jnp.zeros((self.n_slots, self.n_pad), jnp.uint32)
            self.logp = jnp.zeros((self.n_slots, self.n_pad), jnp.float32)
        else:
            self.n_pad = self.members[0].size
            self.words = jnp.zeros(
                (self.n_slots, *self.members[0].state_shape), jnp.uint32
            )
            self.logp = None
        self._rebuild_advance()

    @staticmethod
    def _check_engine(engine) -> None:
        if engine.config.num_chains != 1:
            raise ValueError(
                "the serving tier packs requests into the batch itself — "
                "configure the engine with num_chains=1 (got "
                f"{engine.config.num_chains})"
            )

    def _rebuild_advance(self) -> None:
        if self.execution == "scan":
            self._advance = dispatch.make_class_advance_fn(
                self.members, self.n_pad, self.n_slots, mesh=self.mesh
            )
        else:
            m = self.members[0]
            self._advance = dispatch.make_pallas_advance_fn(
                m.engine, m.target, m.state_shape
            )

    # -- construction from the workload registry -----------------------
    @classmethod
    def for_workload(
        cls,
        name: str,
        *,
        n_slots: int,
        randomness: str = "cim",
        execution: str = "scan",
        smoke: bool = True,
        chunk_steps: int | None = None,
        pipeline_depth: int = 2,
        clock=time.perf_counter,
        mesh=None,
        **builder_kwargs,
    ) -> "PackedExecutor":
        """An executor whose first member is workload ``name`` (see
        ``_workload_member_parts`` for the per-request derivation)."""
        engine, target, shape, request_init, default_steps = (
            _workload_member_parts(
                name, randomness=randomness, execution=execution,
                smoke=smoke, **builder_kwargs,
            )
        )
        return cls(
            engine,
            target,
            n_slots,
            shape,
            request_init=request_init,
            default_steps=default_steps,
            chunk_steps=chunk_steps,
            pipeline_depth=pipeline_depth,
            clock=clock,
            workload=name,
            mesh=mesh,
        )

    # -- shape-class membership ----------------------------------------
    def member_for(self, workload: str | None) -> _Member:
        """The member serving ``workload`` (single-member executors
        accept any name — the direct-construction test path)."""
        if len(self.members) == 1:
            return self.members[0]
        for m in self.members:
            if m.name == workload:
                return m
        raise KeyError(
            f"workload {workload!r} is not a member of this shape class "
            f"({[m.name for m in self.members]})"
        )

    def has_member(self, workload: str) -> bool:
        return any(m.name == workload for m in self.members)

    def add_member(
        self, name, engine, target, state_shape, request_init,
        default_steps=None,
    ) -> _Member:
        """Register another workload group in this shape class (scan
        execution only — pallas kernel geometry is per workload).  Live
        slots keep advancing: the flat pool re-pads in place if the new
        member's state is wider, and the class program is rebuilt with
        the extended ``lax.switch`` table."""
        if self.execution != "scan":
            raise ValueError(
                "pallas executors are single-member shape classes — the "
                "fused kernel grid is specialised to one workload's "
                "state geometry; mixed pallas bursts run one executor "
                "(one program) per workload"
            )
        self._check_engine(engine)
        if resolve_execution(
            engine.config.execution, target, engine.config.update
        ) != "scan":
            raise ValueError(
                "shape-class members must resolve to scan execution"
            )
        if self.has_member(name):
            return self.member_for(name)
        m = _Member(
            name=name, engine=engine, target=target,
            state_shape=tuple(state_shape), request_init=request_init,
            default_steps=default_steps, index=len(self.members),
        )
        self.members.append(m)
        if m.size > self.n_pad:
            grow = m.size - self.n_pad
            self.words = jnp.pad(self.words, ((0, 0), (0, grow)))
            self.logp = jnp.pad(self.logp, ((0, 0), (0, grow)))
            self.n_pad = m.size
        self._rebuild_advance()
        return m

    def add_workload(
        self,
        name: str,
        *,
        randomness: str = "cim",
        execution: str = "scan",
        smoke: bool = True,
        **builder_kwargs,
    ) -> _Member:
        """``add_member`` fed from the workload registry (the scheduler's
        shape-class path)."""
        parts = _workload_member_parts(
            name, randomness=randomness, execution=execution, smoke=smoke,
            **builder_kwargs,
        )
        return self.add_member(name, *parts)

    # -- primary-member views (single-workload API compatibility) ------
    @property
    def engine(self):
        return self.members[0].engine

    @property
    def target(self):
        return self.members[0].target

    @property
    def state_shape(self) -> tuple:
        return self.members[0].state_shape

    @property
    def request_init(self):
        return self.members[0].request_init

    @property
    def default_steps(self):
        return self.members[0].default_steps

    @property
    def rate_label(self) -> str:
        return self.members[0].rate_label

    # -- slot pool ------------------------------------------------------
    def has_free_slot(self) -> bool:
        return any(s is None for s in self._slots)

    @property
    def active_count(self) -> int:
        return sum(s is not None for s in self._slots)

    def admit(self, req) -> int:
        """Place a request in a free slot (between chunks only — callers
        never see a partially-advanced admission)."""
        try:
            slot = next(i for i, s in enumerate(self._slots) if s is None)
        except StopIteration:
            raise RuntimeError("no free slot — check has_free_slot()") from None
        member = self.member_for(getattr(req, "workload", None))
        init, k_run, n_steps = member.request_init(req)
        init = jnp.asarray(init)
        if tuple(init.shape) != member.state_shape:
            raise ValueError(
                f"request init shape {tuple(init.shape)} != member state "
                f"shape {member.state_shape} — one member serves one "
                f"workload group"
            )
        mode, k = parse_collect(req.collect)
        words0 = init.astype(jnp.uint32)
        if self.execution == "scan":
            flat = jnp.pad(
                words0.reshape(-1), (0, self.n_pad - member.size)
            )
            self.words = self.words.at[slot].set(flat)
            if member.carry_logp:
                lp0 = member.target.log_prob(words0).astype(jnp.float32)
                self.logp = self.logp.at[slot].set(
                    jnp.pad(lp0.reshape(-1), (0, self.n_pad - member.size))
                )
        else:
            self.words = self.words.at[slot].set(words0)
        self._keys[slot] = jnp.asarray(k_run, jnp.uint32)
        self._slots[slot] = _Slot(
            req=req, member=member, remaining=int(n_steps), mode=mode,
            thin_k=k,
        )
        req.slot = slot
        req.rate_label = member.rate_label
        req.t_admit = self.clock()
        return slot

    # -- the chunk loop -------------------------------------------------
    def advance_chunk(self) -> list:
        """Advance every active slot one segment; returns the requests
        that finished (their results materialise when the dispatch
        pipeline flushes — ``drain()`` forces it)."""
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return []
        # the segment never overshoots the shortest remaining budget, so
        # every retirement lands exactly on a chunk boundary
        seg = min(self.chunk_steps, *(self._slots[i].remaining for i in active))
        with telemetry.span(
            "serving.segment",
            seg=seg, active=len(active), execution=self.execution,
        ):
            if self.execution == "scan":
                retired = self._advance_scan(active, seg)
            else:
                retired = self._advance_pallas(active, seg)
        telemetry.counter(
            "serving_segments_total", "packed segments dispatched"
        ).inc(execution=self.execution)
        telemetry.counter(
            "serving_slot_steps_total", "slot-steps advanced"
        ).inc(seg * len(active))
        finished = []
        if retired:
            batch = []
            for i in retired:
                s = self._slots[i]
                self._slots[i] = None          # slot free for the next admit
                self._keys[i] = _DUMMY_KEY
                batch.append(s)
                finished.append(s.req)
            self.pipeline.push(
                lambda fs=batch: self._finalize_batch(fs)
            )
        return finished

    def _segment_inputs(self, active):
        collect = (
            "all"
            if any(self._slots[i].mode != "last" for i in active)
            else "last"
        )
        step0s = jnp.asarray(
            [s.progress if s else 0 for s in self._slots], jnp.int32
        )
        keys = jnp.stack([jnp.asarray(k, jnp.uint32) for k in self._keys])
        return collect, step0s, keys

    def _count_compiles(self, before: int) -> None:
        grew = dispatch.jit_cache_size(self._advance) - before
        if grew > 0:
            self.advance_compiles += grew
            telemetry.counter(
                "serving_advance_compiles_total",
                "compiled packed advance programs",
            ).inc(grew, execution=self.execution)

    def _advance_scan(self, active, seg: int) -> list:
        """One vmapped class program over all slots: flat donated
        (words, logp) carry, traced per-slot ``step0``, per-slot member
        dispatch (dispatch.make_class_advance_fn)."""
        collect, step0s, keys = self._segment_inputs(active)
        tidx = jnp.asarray(
            [s.member.index if s else 0 for s in self._slots], jnp.int32
        )
        old_words, old_logp = self.words, self.logp
        before = dispatch.jit_cache_size(self._advance)
        samples, words, logp, acc = self._advance(
            old_words, old_logp, keys, step0s, tidx, seg=seg, collect=collect
        )
        self._count_compiles(before)
        self.words, self.logp = words, logp
        # the donated carries are dead from here on — make stale reads loud
        dispatch.poison_donated(old_words, old_logp)

        def rows(i, m):
            return samples[i][:, :m.size].reshape(-1, *m.state_shape)

        def unflat(buf, i, m):
            return buf[i, :m.size].reshape(m.state_shape)

        return self._bookkeep(
            active, seg, collect, rows,
            lambda i, m: unflat(acc, i, m),
            lambda i, m: unflat(words, i, m),
            lambda i, m: unflat(logp, i, m),
        )

    def _advance_pallas(self, active, seg: int) -> list:
        """One batched fused-kernel grid over all slots: shaped donated
        words carry, per-slot key words and operand ``step0``
        (dispatch.make_pallas_advance_fn).  No per-slot fallback."""
        collect, step0s, keys = self._segment_inputs(active)
        old_words = self.words
        before = dispatch.jit_cache_size(self._advance)
        samples, words, logp, acc = self._advance(
            old_words, keys, step0s, seg=seg, collect=collect
        )
        self._count_compiles(before)
        self.words = words
        dispatch.poison_donated(old_words)
        return self._bookkeep(
            active, seg, collect,
            lambda i, m: samples[i],
            lambda i, m: acc[i],
            lambda i, m: words[i],
            lambda i, m: logp[i],
        )

    def _bookkeep(
        self, active, seg, collect, rows_of, acc_of, words_of, logp_of
    ) -> list:
        """Per-slot segment bookkeeping: slice retirement/collection
        payloads NOW (the donated inputs are already poisoned — these
        getters read the segment *outputs*), advance progress, collect
        retirees."""
        retired = []
        for i in active:
            s = self._slots[i]
            m = s.member
            if collect == "all" and s.mode != "last":
                r = rows_of(i, m)
                if s.mode == "all":
                    s.pieces.append(r)
                else:  # thin: static strided slice on absolute steps
                    i0 = (-s.progress) % s.thin_k
                    if i0 < seg:
                        s.pieces.append(r[i0::s.thin_k])
            a = acc_of(i, m)
            s.acc = a if s.acc is None else s.acc + a
            s.progress += seg
            s.remaining -= seg
            if s.remaining == 0:
                s.final_words = words_of(i, m)
                s.final_logp = logp_of(i, m)
                retired.append(i)
        return retired

    # -- retirement -----------------------------------------------------
    def _finalize_batch(self, batch: list) -> None:
        """Finalize a batch of retired slots under one span — the span
        duration IS the donation/materialisation stall the pipeline
        deferred (host blocks on device values here)."""
        with telemetry.span("serving.finalize", retired=len(batch)):
            for s in batch:
                self._finalize(s)
        telemetry.counter(
            "serving_requests_retired_total", "requests finalized"
        ).inc(len(batch))
        for s in batch:
            req = s.req
            wl = getattr(req, "workload", "?")
            wait = getattr(req, "wait_s", None)
            if wait is not None:
                telemetry.histogram(
                    "serving_wait_seconds", "arrival -> admission"
                ).observe(wait, workload=wl)
            service = getattr(req, "service_s", None)
            if service is not None:
                telemetry.histogram(
                    "serving_service_seconds", "admission -> materialised"
                ).observe(service, workload=wl)

    def _finalize(self, s: _Slot) -> None:
        """Host-side retirement: materialise the request's payload and
        stamp delivery time.  Runs deferred through the dispatch
        pipeline — by then the device values are usually already done."""
        req = s.req
        if s.pieces:
            req.samples = np.concatenate(
                [np.asarray(p) for p in s.pieces], axis=0
            )
        else:
            req.samples = np.zeros((0, *s.member.state_shape), np.uint32)
        req.final_words = np.asarray(s.final_words)
        req.final_logp = np.asarray(s.final_logp)
        req.accept_count = np.asarray(s.acc)
        total = max(1, s.progress * int(np.prod(s.member.state_shape)))
        req.acceptance_rate = float(req.accept_count.sum()) / total
        req.t_done = self.clock()

    def drain(self) -> None:
        """Flush the deferred finalize pipeline (every retired request's
        result is host-materialised after this returns)."""
        self.pipeline.drain()
