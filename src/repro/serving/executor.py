"""The packed batch program (DESIGN.md §Serving).

``PackedExecutor`` owns ``n_slots`` request slots and advances them in
lock-step ``chunk_steps`` segments.  Admission and retirement happen
only **between** chunks, and the packed batch is bit-identical to solo
runs because each slot replays exactly the solo call:

  * slot state is the engine carry ``(words, logp)`` with a leading
    slot axis, donated segment-to-segment (serving/dispatch.py);
  * each slot streams from its *request's* key (``PRNGKey(seed)`` split
    exactly as ``launch.sample`` does), so the stream belongs to the
    request, never to the slot — slot reuse after retirement is safe by
    construction;
  * each slot carries its absolute step as the engine's ``step0`` resume
    offset; the scan executors take it traced, so slots at different
    absolute steps advance in one device program, and a request joining
    mid-flight continues the exact stream its solo run would produce.

Per-request collection: the segment program collects ``"all"`` iff any
active request keeps samples (else ``"last"`` — O(state) memory); a
``thin:k`` request then keeps the static strided slice of its slot's
rows on *absolute* steps ``(step0 + t) % k == 0``, bit-identical to the
engine's own ``thin`` stream (DESIGN.md §Collection).  Pallas execution
bakes chunk schedules and Gibbs parity statically, so that path runs
one solo ``engine.run`` per active slot with a concrete ``step0``
instead of the vmapped single program.
"""

from __future__ import annotations

import dataclasses
import inspect
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry, workloads
from repro.samplers.engine import parse_collect, resolve_execution
from repro.samplers.plan import RunPlan
from repro.serving.dispatch import SegmentPipeline, make_advance_fn

_DUMMY_KEY = np.zeros((2,), np.uint32)  # free slots advance discarded work


@dataclasses.dataclass
class _Slot:
    """Executor-side bookkeeping for one admitted request."""

    req: object
    remaining: int               # steps still to run
    mode: str                    # parsed collect mode: all | thin | last
    thin_k: int                  # stride under thin
    progress: int = 0            # absolute step == step0 of the next segment
    pieces: list = dataclasses.field(default_factory=list)  # device kept rows
    acc: object = None           # device per-site accept/flip accumulator
    final_words: object = None
    final_logp: object = None


class PackedExecutor:
    """``n_slots`` heterogeneous requests packed into one engine program.

    Construct via ``for_workload`` (the registry path the scheduler
    uses) or directly with an engine/target pair plus a
    ``request_init(req) -> (init_words, run_key, n_steps)`` callable
    (the hook tests use to pin exact solo references).
    """

    def __init__(
        self,
        engine,
        target,
        n_slots: int,
        state_shape: tuple,
        *,
        request_init,
        default_steps: int | None = None,
        chunk_steps: int | None = None,
        pipeline_depth: int = 2,
        clock=time.perf_counter,
    ):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if engine.config.num_chains != 1:
            raise ValueError(
                "the serving tier packs requests into the batch itself — "
                "configure the engine with num_chains=1 (got "
                f"{engine.config.num_chains})"
            )
        self.engine = engine
        self.target = target
        self.n_slots = int(n_slots)
        self.state_shape = tuple(state_shape)
        self.request_init = request_init
        self.default_steps = default_steps
        self.chunk_steps = int(chunk_steps or engine.config.chunk_steps)
        self.clock = clock
        self.execution = resolve_execution(
            engine.config.execution, target, engine.config.update
        )
        self.rate_label = (
            "flip_rate" if engine.config.update == "gibbs"
            else "acceptance_rate"
        )
        # the carried logp feeds engine.run(init_logp=...) only on the
        # scan MH path; gibbs and pallas re-derive it themselves
        self._carry_logp = (
            engine.config.update == "mh" and self.execution == "scan"
        )
        self.pipeline = SegmentPipeline(pipeline_depth)
        self._advance = (
            make_advance_fn(engine, target) if self.execution == "scan"
            else None
        )
        self._slots: list[_Slot | None] = [None] * self.n_slots
        self._keys: list = [_DUMMY_KEY] * self.n_slots
        self.words = jnp.zeros((self.n_slots, *self.state_shape), jnp.uint32)
        self.logp = jnp.zeros((self.n_slots, *self.state_shape), jnp.float32)

    # -- construction from the workload registry -----------------------
    @classmethod
    def for_workload(
        cls,
        name: str,
        *,
        n_slots: int,
        randomness: str = "cim",
        execution: str = "scan",
        smoke: bool = True,
        chunk_steps: int | None = None,
        pipeline_depth: int = 2,
        clock=time.perf_counter,
        **builder_kwargs,
    ) -> "PackedExecutor":
        """One executor per workload *group*: engine + target built once
        (group key 0 — for seed-dependent targets like spin_glass the
        group fixes the problem instance), requests supply per-request
        inits and streams.

        ``request_init`` replays the solo-run derivation of
        ``launch.sample`` exactly: ``PRNGKey(seed)`` -> split ->
        (builder init from k_init, chain stream from k_run) — so a
        packed request reproduces ``engine.run(k_run, target, n, init)``
        bit-for-bit.
        """
        builder = workloads.WORKLOADS[name]
        params = inspect.signature(builder).parameters
        kwargs = {
            k: v
            for k, v in dict(
                randomness=randomness,
                backend=execution,
                smoke=smoke,
                **builder_kwargs,
            ).items()
            if k in params and v is not None
        }
        template = workloads.build(name, jax.random.PRNGKey(0), **kwargs)

        def request_init(req):
            key = jax.random.PRNGKey(req.seed)
            k_init, k_run = jax.random.split(key)
            wl = workloads.build(name, k_init, **kwargs)
            n = req.n_steps if req.n_steps else wl.n_steps
            return wl.init_words, k_run, n

        return cls(
            template.engine,
            template.target,
            n_slots,
            tuple(template.init_words.shape),
            request_init=request_init,
            default_steps=template.n_steps,
            chunk_steps=chunk_steps,
            pipeline_depth=pipeline_depth,
            clock=clock,
        )

    # -- slot pool ------------------------------------------------------
    def has_free_slot(self) -> bool:
        return any(s is None for s in self._slots)

    @property
    def active_count(self) -> int:
        return sum(s is not None for s in self._slots)

    def admit(self, req) -> int:
        """Place a request in a free slot (between chunks only — callers
        never see a partially-advanced admission)."""
        try:
            slot = next(i for i, s in enumerate(self._slots) if s is None)
        except StopIteration:
            raise RuntimeError("no free slot — check has_free_slot()") from None
        init, k_run, n_steps = self.request_init(req)
        init = jnp.asarray(init)
        if tuple(init.shape) != self.state_shape:
            raise ValueError(
                f"request init shape {tuple(init.shape)} != executor state "
                f"shape {self.state_shape} — one executor serves one "
                f"workload group"
            )
        mode, k = parse_collect(req.collect)
        words0 = init.astype(jnp.uint32)
        self.words = self.words.at[slot].set(words0)
        if self._carry_logp:
            self.logp = self.logp.at[slot].set(
                self.target.log_prob(words0).astype(jnp.float32)
            )
        self._keys[slot] = jnp.asarray(k_run, jnp.uint32)
        self._slots[slot] = _Slot(
            req=req, remaining=int(n_steps), mode=mode, thin_k=k
        )
        req.slot = slot
        req.rate_label = self.rate_label
        req.t_admit = self.clock()
        return slot

    # -- the chunk loop -------------------------------------------------
    def advance_chunk(self) -> list:
        """Advance every active slot one segment; returns the requests
        that finished (their results materialise when the dispatch
        pipeline flushes — ``drain()`` forces it)."""
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return []
        # the segment never overshoots the shortest remaining budget, so
        # every retirement lands exactly on a chunk boundary
        seg = min(self.chunk_steps, *(self._slots[i].remaining for i in active))
        with telemetry.span(
            "serving.segment",
            seg=seg, active=len(active), execution=self.execution,
        ):
            if self.execution == "scan":
                retired = self._advance_scan(active, seg)
            else:
                retired = self._advance_pallas(active, seg)
        telemetry.counter(
            "serving_segments_total", "packed segments dispatched"
        ).inc(execution=self.execution)
        telemetry.counter(
            "serving_slot_steps_total", "slot-steps advanced"
        ).inc(seg * len(active))
        finished = []
        if retired:
            batch = []
            for i in retired:
                s = self._slots[i]
                self._slots[i] = None          # slot free for the next admit
                self._keys[i] = _DUMMY_KEY
                batch.append(s)
                finished.append(s.req)
            self.pipeline.push(
                lambda fs=batch: self._finalize_batch(fs)
            )
        return finished

    def _advance_scan(self, active, seg: int) -> list:
        """One vmapped device program over all slots, traced per-slot
        ``step0``; donated (words, logp) carry."""
        collect = (
            "all"
            if any(self._slots[i].mode != "last" for i in active)
            else "last"
        )
        step0s = jnp.asarray(
            [s.progress if s else 0 for s in self._slots], jnp.int32
        )
        keys = jnp.stack([jnp.asarray(k, jnp.uint32) for k in self._keys])
        samples, words, logp, acc = self._advance(
            self.words, self.logp, keys, step0s, seg=seg, collect=collect
        )
        # slice retirement/collection payloads NOW — before the next
        # segment donates (words, logp) back into the device program
        retired = []
        for i in active:
            s = self._slots[i]
            if collect == "all" and s.mode != "last":
                if s.mode == "all":
                    s.pieces.append(samples[i])
                else:  # thin: static strided slice on absolute steps
                    i0 = (-s.progress) % s.thin_k
                    if i0 < seg:
                        s.pieces.append(samples[i, i0::s.thin_k])
            s.acc = acc[i] if s.acc is None else s.acc + acc[i]
            s.progress += seg
            s.remaining -= seg
            if s.remaining == 0:
                s.final_words = words[i]
                s.final_logp = logp[i]
                retired.append(i)
        self.words, self.logp = words, logp
        return retired

    def _advance_pallas(self, active, seg: int) -> list:
        """Pallas fallback: one solo ``engine.run`` per active slot.  The
        fused kernels bake the chunk schedule and checkerboard parity
        statically, so ``step0`` must be a concrete int per slot — the
        slots still share the between-chunks admission contract, just
        not a single device program."""
        retired = []
        words = self.words
        for i in active:
            s = self._slots[i]
            collect = (
                "all" if s.mode == "all"
                else f"thin:{s.thin_k}" if s.mode == "thin"
                else "last"
            )
            res = self.engine.submit(
                RunPlan(
                    target=self.target, n_steps=seg, init_words=words[i],
                    key=self._keys[i], step0=int(s.progress),
                    collect=collect,
                )
            ).result
            if s.mode != "last" and res.samples.shape[0]:
                s.pieces.append(res.samples)
            s.acc = (
                res.accept_count if s.acc is None
                else s.acc + res.accept_count
            )
            words = words.at[i].set(res.final_words)
            s.progress += seg
            s.remaining -= seg
            if s.remaining == 0:
                s.final_words = res.final_words
                s.final_logp = res.final_logp
                retired.append(i)
        self.words = words
        return retired

    # -- retirement -----------------------------------------------------
    def _finalize_batch(self, batch: list) -> None:
        """Finalize a batch of retired slots under one span — the span
        duration IS the donation/materialisation stall the pipeline
        deferred (host blocks on device values here)."""
        with telemetry.span("serving.finalize", retired=len(batch)):
            for s in batch:
                self._finalize(s)
        telemetry.counter(
            "serving_requests_retired_total", "requests finalized"
        ).inc(len(batch))
        for s in batch:
            req = s.req
            wl = getattr(req, "workload", "?")
            wait = getattr(req, "wait_s", None)
            if wait is not None:
                telemetry.histogram(
                    "serving_wait_seconds", "arrival -> admission"
                ).observe(wait, workload=wl)
            service = getattr(req, "service_s", None)
            if service is not None:
                telemetry.histogram(
                    "serving_service_seconds", "admission -> materialised"
                ).observe(service, workload=wl)

    def _finalize(self, s: _Slot) -> None:
        """Host-side retirement: materialise the request's payload and
        stamp delivery time.  Runs deferred through the dispatch
        pipeline — by then the device values are usually already done."""
        req = s.req
        if s.pieces:
            req.samples = np.concatenate(
                [np.asarray(p) for p in s.pieces], axis=0
            )
        else:
            req.samples = np.zeros((0, *self.state_shape), np.uint32)
        req.final_words = np.asarray(s.final_words)
        req.final_logp = np.asarray(s.final_logp)
        req.accept_count = np.asarray(s.acc)
        total = max(1, s.progress * int(np.prod(self.state_shape)))
        req.acceptance_rate = float(req.accept_count.sum()) / total
        req.t_done = self.clock()

    def drain(self) -> None:
        """Flush the deferred finalize pipeline (every retired request's
        result is host-materialised after this returns)."""
        self.pipeline.drain()
