"""Paper Fig. 16(a) + §6.4: per-operation energy and per-sample energy.

Every number in the paper's energy section, derived from the calibrated
model, side by side with the paper's quoted values.
"""

from repro.core import energy


def run() -> list[dict]:
    rows = [
        {
            "bench": "fig16a_op_energy",
            "op": "write (4b)",
            "model_fj": energy.E_WRITE_FJ_PER_4B,
            "paper_fj": 372.6,
        },
        {
            "bench": "fig16a_op_energy",
            "op": "read (4b)",
            "model_fj": energy.E_READ_FJ_PER_4B,
            "paper_fj": 343.1,
        },
        {
            "bench": "fig16a_op_energy",
            "op": "block RNG (4b)",
            "model_fj": energy.E_BLOCK_RNG_FJ_PER_4B,
            "paper_fj": 79.1,
        },
        {
            "bench": "fig16a_op_energy",
            "op": "in-memory copy (4b)",
            "model_fj": energy.E_COPY_FJ_PER_4B,
            "paper_fj": 47.5,
        },
        {
            "bench": "fig16a_op_energy",
            "op": "[0,1] RNG (8b)",
            "model_fj": energy.E_UNIFORM_RNG_FJ_PER_8B,
            "paper_fj": 234.6,
        },
        {
            "bench": "sec64_sample_energy",
            "case": "accepted",
            "model_pj": round(energy.energy_accepted_fj(4) / 1e3, 4),
            "paper_pj": 0.5065,
        },
        {
            "bench": "sec64_sample_energy",
            "case": "rejected",
            "model_pj": round(energy.energy_rejected_fj(4) / 1e3, 4),
            "paper_pj": 0.5547,
        },
    ]
    for ar in (0.30, 0.35, 0.40):
        rows.append(
            {
                "bench": "sec64_sample_energy",
                "case": f"acceptance {ar:.0%}",
                "model_pj": round(energy.energy_per_sample_fj(ar, 4) / 1e3, 4),
                "paper_pj": "0.5331-0.5402",
            }
        )
    return rows
