"""Paper Fig. 9(d)/(e): MSXOR debias error vs p_BFR and stage count.

Analytic lambda recursion + Monte-Carlo validation through the actual
Pallas MSXOR kernel, plus the corner-simulation bound (lambda_3 >=
0.4999993981 for CVDD disturbed to 0.6 V -> p >= 0.4).
"""

import jax
import numpy as np

from repro.core import bitcell, msxor
from repro.kernels.msxor import ops as msxor_ops


def run() -> list[dict]:
    rows = []
    for p in (0.30, 0.35, 0.40, 0.45, 0.50):
        for n in (1, 2, 3, 4):
            rows.append(
                {
                    "bench": "fig9d_msxor_analytic",
                    "p_bfr": p,
                    "stages": n,
                    "lambda_n": msxor.lambda_recursion(p, n),
                    "error": msxor.debias_error(p, n),
                }
            )
    # paper's exemplar + corner bound
    rows.append(
        {
            "bench": "fig9d_paper_example",
            "p_bfr": 0.4,
            "stages": 3,
            "lambda_n": msxor.lambda_recursion(0.4, 3),
            "paper_value": 0.49999872,
            "passes_1e-5": msxor.debias_error(0.4, 3) < 1e-5,
        }
    )
    # Monte-Carlo through the kernel: empirical per-bit bias after 3 stages
    key = jax.random.PRNGKey(1)
    for p in (0.40, 0.45):
        raw = bitcell.raw_random_words(key, p, (8, 400_000), nbits=32)
        out = np.asarray(msxor_ops.msxor_fold(raw))
        bit_means = [(float(((out >> b) & 1).mean())) for b in range(32)]
        rows.append(
            {
                "bench": "fig9_kernel_montecarlo",
                "p_bfr": p,
                "empirical_lambda_mean": float(np.mean(bit_means)),
                "worst_bit_bias": float(np.max(np.abs(np.array(bit_means) - 0.5))),
            }
        )
    return rows
