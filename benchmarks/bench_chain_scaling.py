"""Chain-scaling benchmark: throughput + ESS-per-joule vs num_chains.

The CIM macro's 166.7 M samples/s comes from block-parallel random
number generation — which maps onto many *independent chains* advancing
in one device program (DESIGN.md §Chains-axis).  This table measures how
the engine's chains axis actually scales: for C in {1, 4, 16}, run each
zoo workload, report aggregate site-step throughput (all chains count)
and cross-chain ESS per joule.  Ideal scaling doubles ESS/J with every
doubling of C at flat wall-clock; the gap from ideal is the batching
overhead the hardware story needs to know about.
"""

from __future__ import annotations

from benchmarks.bench_workloads import bench_workload

CHAIN_COUNTS = (1, 4, 16)


def presets(smoke: bool = False):
    if smoke:
        return (
            ("ising", "scan", dict(height=6, width=6, batch=1, n_steps=96)),
            ("gmm", "pallas", dict(chains=16, n_steps=576)),
        )
    return (
        ("ising", "scan", dict(height=8, width=8, batch=2, n_steps=192)),
        ("gmm", "pallas", dict(chains=16, n_steps=384)),
    )


def run(smoke: bool = False) -> list[dict]:
    rows = []
    for name, execution, kwargs in presets(smoke):
        for num_chains in CHAIN_COUNTS:
            row = bench_workload(
                name, execution, num_chains=num_chains,
                repeats=5 if smoke else 1, **kwargs,
            )
            row["bench"] = "chain_scaling"
            rows.append(row)
    return rows
