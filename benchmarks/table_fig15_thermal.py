"""Paper Fig. 15: bit flip rate vs temperature at CVDD = 0.5 V.

Commercial range (0-70 C) must hold ~45 %; below -20 C the BFR drops
(less thermal noise) which per the paper only extends burn-in.  We also
verify the downstream claim: a lower p_BFR chain still converges, just
slower (longer burn-in to the same TV distance).
"""

import jax
import numpy as np

from repro.core import bitcell, metropolis, targets


def _tv_after(p_bfr: float, burn_in: int) -> float:
    rng_logp = np.random.default_rng(0).normal(size=32)
    log_prob = targets.table_target(np.asarray(rng_logp, dtype=np.float32))
    cfg = metropolis.MHConfig(nbits=5, p_bfr=p_bfr, rng_p_bfr=0.45, burn_in=burn_in)
    res = metropolis.run_chain(
        jax.random.PRNGKey(3), log_prob, cfg, n_samples=800, chain_shape=(32,)
    )
    counts = np.bincount(np.asarray(res.samples).reshape(-1), minlength=32)
    emp = counts / counts.sum()
    ref = np.exp(rng_logp - rng_logp.max())
    ref /= ref.sum()
    return float(0.5 * np.abs(emp - ref).sum())


def run() -> list[dict]:
    rows = []
    for t in (-40.0, -20.0, 0.0, 25.0, 70.0, 85.0):
        rows.append(
            {
                "bench": "fig15_thermal",
                "temp_c": t,
                "bfr_at_0p5v": round(float(bitcell.bit_flip_rate(0.5, t)), 4),
            }
        )
    # burn-in extension claim: cold chain (p=0.36) vs nominal (p=0.45)
    for label, p in (("nominal_25C", 0.45), ("cold_-40C", 0.36)):
        rows.append(
            {
                "bench": "fig15_burnin_effect",
                "condition": label,
                "p_bfr": p,
                "tv_burn100": round(_tv_after(p, 100), 4),
                "tv_burn500": round(_tv_after(p, 500), 4),
            }
        )
    return rows
