"""Collection-axis benchmark: engine traffic proportional to what is kept.

The paper's core argument is that RNG and state *movement* — not
arithmetic — dominate MCMC cost (0.53 pJ/sample comes from never
shipping operands off the sub-array).  The engine's collection axis
(DESIGN.md §Collection) is the software edition: ``collect="all"``
materialises every post-step state, ``"thin:16"`` keeps every 16th
absolute step, ``"last"`` keeps only the final state.  This table
measures steps/s and the engine's peak operand/output footprint across
collect x update-rule x randomness, on the scan executor (the substrate
every CPU/GPU run actually uses; the collection logic upstream of the
kernels is shared with the pallas executors).

The headline row pair is the long-chain Gibbs run: under ``"all"`` the
(K, B, H, W) sample buffer dominates the run, under ``"last"`` the same
chain runs in O(state) output memory and >= 1.5x the steps/s.  The cim
rows additionally carry the operand-lean u-only win: Gibbs never reads
flip words, so ``need_flips=False`` skips pseudo-read plane generation
entirely (visible as the gibbs/cim throughput gain over the pre-axis
baseline in BENCH_workloads.json).

Every row also reports the randomness bytes crossing the sampling-kernel
boundary per step under the pallas executors (DESIGN.md §Randomness) —
``operand_bytes_per_step`` analytically, ``measured_operand_bytes_per_
step`` from the nbytes of the arrays the executor actually ships for one
chunk.  host/cim stream O(sites) operand planes each step; fused ships
only the per-column/per-lattice key words once per chunk, so its
per-step traffic is ~0 — the software edition of the paper's
never-move-the-randomness argument.  The fused rows' timing rides the
same scan substrate as the rest of the table (the scan executor draws
the identical stream through the shared counter cipher), where fused
also out-runs the cim pipeline on steps/s: one Threefry block per draw
vs pseudo-read planes + MSXOR folds.

``run(smoke=True)`` uses tiny presets for the CI bench-smoke job
(benchmarks/check_regression.py gates these rows).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.bench_workloads import machine_calibration
from repro import samplers
from repro.kernels import rng
from repro.workloads.ising import IsingModel

COLLECTS = ("all", "thin:16", "last")


def _mh_setup(seed, batch, chains, vocab):
    key = jax.random.PRNGKey(seed)
    table = jax.random.normal(key, (batch, vocab), jnp.float32)
    target = samplers.TableTarget(table)
    init = jnp.broadcast_to(
        jnp.argmax(table, -1).astype(jnp.uint32)[:, None], (batch, chains)
    )
    return target, init


def _gibbs_setup(seed, batch, side):
    model = IsingModel(height=side, width=side, beta=0.35)
    init = model.random_init(jax.random.PRNGKey(seed), batch)
    return model, init


def _footprint_mb(update, collect, n_steps, n_sites, chunk, nbits) -> dict:
    """Analytic peak engine traffic (beyond the O(state) carry), in MB:
    the streamed per-chunk operands — u always, flip words only for mh
    (gibbs runs the u-only ``need_flips=False`` path) — plus the kept
    sample buffer the collection mode retains."""
    mode, k = samplers.parse_collect(collect)
    chunk = max(1, min(chunk, n_steps))
    if mode == "all":
        kept = n_steps
    elif mode == "thin":
        kept = samplers.kept_count(n_steps, k)
    else:
        kept = 0
    u_mb = chunk * n_sites * 4 / 1e6
    flips_mb = chunk * n_sites * 4 / 1e6 if update == "mh" else 0.0
    return {
        "kept_steps": kept,
        "chunk_operand_mb": round(u_mb + flips_mb, 3),
        "kept_sample_mb": round(kept * n_sites * 4 / 1e6, 3),
        "peak_operand_mb": round(
            u_mb + flips_mb + kept * n_sites * 4 / 1e6, 3
        ),
    }


def _operand_traffic(update, randomness, init, chunk, n_steps, nbits) -> dict:
    """Randomness bytes crossing the sampling-kernel boundary per step
    under the pallas executors: host/cim ship per-step operand planes
    (u always, flip words for mh); fused ships only the per-column/
    per-lattice chain-key words, once per chunk.  The measured column
    materialises exactly what the executor would ship for one chunk and
    divides by its steps."""
    chunk = max(1, min(chunk, n_steps))
    n_slots = init.shape[1] if update == "mh" else init.shape[0]
    if randomness == "fused":
        k0, k1 = rng.key_words(jax.random.PRNGKey(0))
        shipped = (
            jnp.broadcast_to(k0, (n_slots,)),
            jnp.broadcast_to(k1, (n_slots,)),
        )
        analytic = 8.0 * n_slots / chunk
    else:
        backend = samplers.make_randomness_backend(randomness, p_bfr=0.45)
        flips, u = backend.chunk(
            jax.random.PRNGKey(0), 0, chunk, init.shape, nbits,
            need_flips=(update == "mh"),
        )
        shipped = (u,) if flips is None else (flips, u)
        analytic = (8.0 if update == "mh" else 4.0) * init.size
    measured = sum(x.nbytes for x in shipped) / chunk
    return {
        "operand_bytes_per_step": round(analytic, 1),
        "measured_operand_bytes_per_step": round(measured, 1),
    }


def bench_case(
    update: str, randomness: str, collect: str, n_steps: int,
    chunk_steps: int, target, init, repeats: int = 2,
) -> dict:
    """One timed eager ``engine.submit`` (the CLI/workload call path), best
    of ``repeats`` with a warm-up compile pass, all outputs blocked on."""
    engine = samplers.MHEngine(
        samplers.EngineConfig(
            update=update,
            randomness=randomness,
            execution="scan",
            chunk_steps=chunk_steps,
            collect=collect,
        )
    )
    key = jax.random.PRNGKey(0)

    plan = samplers.RunPlan(
        target=target, n_steps=n_steps, init_words=init, key=key
    )

    def once():
        result = engine.submit(plan).result
        jax.block_until_ready((result.samples, result.final_words))
        return result

    once()  # warm-up compile
    wall_s = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.time()
        once()
        wall_s = min(wall_s, time.time() - t0)

    n_sites = int(init.size)
    nbits = getattr(target, "nbits", 1)
    row = {
        "bench": "collection",
        "update": update,
        "randomness": randomness,
        "collect": collect,
        "n_steps": n_steps,
        "chunk_steps": chunk_steps,
        "n_sites": n_sites,
        "wall_s": round(wall_s, 3),
        "steps_per_s": round(n_steps / max(wall_s, 1e-9), 1),
        "site_steps_per_s": round(
            n_steps * n_sites / max(wall_s, 1e-9), 1
        ),
        "calib_steps_per_s": round(machine_calibration(), 1),
    }
    row.update(
        _footprint_mb(update, collect, n_steps, n_sites, chunk_steps, nbits)
    )
    row.update(
        _operand_traffic(update, randomness, init, chunk_steps, n_steps, nbits)
    )
    return row


def _assembly_case(
    n_chunks: int, chunk: int, n_sites: int, assembly: str,
    repeats: int = 3,
) -> dict:
    """The pallas chunk-buffer assembly micro-bench: the before/after
    pair for ``_drive_pallas_chunks``'s eager collect="all" path.

    ``pieces_concat`` is the historical strategy (append every chunk's
    rows to a python list, one full-stream ``concatenate`` copy at the
    end — O(kept) extra traffic); ``jit_donated`` is the current one
    (preallocate the kept buffer once, write each chunk through the
    donating jitted ``_chunk_writer`` so XLA reuses the buffer in
    place).  Same chunk outputs, same result, only the assembly differs.
    """
    from repro.samplers.engine import _chunk_writer

    chunks = [
        jax.block_until_ready(
            jnp.full((chunk, n_sites), i, jnp.uint32)
        )
        for i in range(n_chunks)
    ]

    if assembly == "pieces_concat":
        def assemble():
            pieces = []
            for rows in chunks:
                pieces.append(rows)
            return jnp.concatenate(pieces, axis=0)
    else:
        write = _chunk_writer(1)

        def assemble():
            out = jnp.zeros((n_chunks * chunk, n_sites), jnp.uint32)
            pos = 0
            for rows in chunks:
                out = write(out, rows, pos)
                pos += chunk
            return out

    jax.block_until_ready(assemble())  # warm-up (compiles the writer)
    wall_s = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.time()
        jax.block_until_ready(assemble())
        wall_s = min(wall_s, time.time() - t0)

    n_steps = n_chunks * chunk
    return {
        "bench": "collection_assembly",
        "assembly": assembly,
        "collect": "all",
        "n_steps": n_steps,
        "chunk_steps": chunk,
        "n_sites": n_sites,
        "wall_s": round(wall_s, 4),
        "steps_per_s": round(n_steps / max(wall_s, 1e-9), 1),
        "site_steps_per_s": round(n_steps * n_sites / max(wall_s, 1e-9), 1),
        "calib_steps_per_s": round(machine_calibration(), 1),
    }


def presets(smoke: bool = False):
    """(update, randomness, n_steps, chunk, setup) cases.

    Full-size host rows are the long-chain regime where the sample
    buffer reaches GB scale (the headline collect="last" win); cim rows
    are shorter — the MSXOR u pipeline costs ~50x host randomness per
    step, and the collection axis is orthogonal to that cost.
    """
    if smoke:
        return (
            ("mh", "host", 768, 64, _mh_setup(0, 2, 128, 64)),
            ("mh", "cim", 768, 64, _mh_setup(0, 2, 128, 64)),
            ("mh", "fused", 768, 64, _mh_setup(0, 2, 128, 64)),
            ("gibbs", "host", 768, 64, _gibbs_setup(1, 2, 8)),
            ("gibbs", "cim", 768, 64, _gibbs_setup(1, 2, 8)),
            ("gibbs", "fused", 768, 64, _gibbs_setup(1, 2, 8)),
        )
    return (
        ("mh", "host", 50000, 128, _mh_setup(0, 2, 512, 256)),
        ("mh", "cim", 2048, 64, _mh_setup(0, 2, 128, 256)),
        ("mh", "fused", 2048, 64, _mh_setup(0, 2, 128, 256)),
        ("gibbs", "host", 50000, 128, _gibbs_setup(1, 8, 32)),
        ("gibbs", "cim", 2048, 64, _gibbs_setup(1, 2, 16)),
        ("gibbs", "fused", 2048, 64, _gibbs_setup(1, 2, 16)),
    )


def run(smoke: bool = False) -> list[dict]:
    rows = []
    for update, randomness, n_steps, chunk, (target, init) in presets(smoke):
        for collect in COLLECTS:
            rows.append(
                bench_case(
                    update, randomness, collect, n_steps, chunk,
                    target, init, repeats=5 if smoke else 2,
                )
            )
    n_chunks, chunk, n_sites = (12, 64, 256) if smoke else (64, 128, 4096)
    for assembly in ("pieces_concat", "jit_donated"):
        rows.append(
            _assembly_case(
                n_chunks, chunk, n_sites, assembly,
                repeats=5 if smoke else 3,
            )
        )
    return rows
