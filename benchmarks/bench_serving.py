"""Serving-tier throughput/latency: requests/s and p50/p99 vs slots x backend.

The serving question is orthogonal to raw chain throughput: how many
*requests* per second does the packed executor deliver, and what latency
does a request see, as the slot pool widens and the randomness backend
changes (host jax.random vs the CIM pipeline vs fused in-kernel
counters)?  Each cell serves a closed burst of ``2 x slots`` identical
requests (so the FIFO overflow path and slot reuse are exercised) on the
GMM posterior workload under scan execution, after a warm-up burst that
pays the compile.

Row semantics: ``site_steps_per_s`` is total chain work / wall (the
regression gate's normalised throughput field, comparable with the
workloads table); ``requests_per_s`` and the latency percentiles come
from ``repro.serving.latency_summary`` over the measured burst only.
"""

from __future__ import annotations

import time

from benchmarks.bench_workloads import machine_calibration
from repro.serving import Scheduler, ServeRequest, latency_summary

WORKLOAD = "gmm"  # MH + table target: every randomness backend applies


def _serve_cell(
    slots: int, randomness: str, n_steps: int, smoke: bool
) -> dict:
    n_requests = 2 * slots
    sched = Scheduler(
        n_slots=slots, randomness=randomness, execution="scan", smoke=smoke
    )
    # warm-up burst: compiles the packed advance traces for this slot
    # count (the measured burst replays the same (seg, collect) set)
    warm = [
        ServeRequest(
            rid=-1 - i, workload=WORKLOAD, n_steps=n_steps, seed=1000 + i
        )
        for i in range(n_requests)
    ]
    sched.serve(warm)

    now = sched.clock()
    reqs = [
        ServeRequest(
            rid=i, workload=WORKLOAD, n_steps=n_steps, seed=i, t_arrive=now
        )
        for i in range(n_requests)
    ]
    t0 = time.perf_counter()
    sched.serve(reqs)
    wall_s = time.perf_counter() - t0

    ex = sched.executors[WORKLOAD]
    n_sites = 1
    for d in ex.state_shape:
        n_sites *= d
    site_steps = n_requests * n_steps * n_sites
    return {
        "workload": WORKLOAD,
        "update": ex.engine.config.update,
        "slots": slots,
        "randomness": randomness,
        "backend": "scan",
        "n_requests": n_requests,
        "n_steps": n_steps,
        "collect": "last",
        "wall_s": round(wall_s, 3),
        "site_steps_per_s": round(site_steps / max(wall_s, 1e-9), 1),
        "calib_steps_per_s": round(machine_calibration(), 1),
        **{
            k: v
            for k, v in latency_summary(reqs).items()
            if k != "n_requests"  # already a config key
        },
    }


def presets(smoke: bool = False):
    """(slots, randomness) grid; smoke trims the pool sizes for CI."""
    slot_sizes = (1, 4) if smoke else (1, 4, 16)
    backends = ("host", "cim", "fused")
    return [(s, r) for s in slot_sizes for r in backends]


def run(smoke: bool = False) -> list[dict]:
    n_steps = 64 if smoke else 512
    return [
        _serve_cell(slots, randomness, n_steps, smoke)
        for slots, randomness in presets(smoke)
    ]


if __name__ == "__main__":
    for row in run(smoke=True):
        print("  ".join(f"{k}={v}" for k, v in row.items()))
