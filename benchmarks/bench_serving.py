"""Serving-tier throughput/latency: requests/s and p50/p99 vs slots x backend.

The serving question is orthogonal to raw chain throughput: how many
*requests* per second does the packed executor deliver, and what latency
does a request see, as the slot pool widens and the randomness backend
changes (host jax.random vs the CIM pipeline vs fused in-kernel
counters)?  Each cell serves a closed burst of ``2 x slots`` identical
requests (so the FIFO overflow path and slot reuse are exercised) on the
GMM posterior workload under scan execution, after a warm-up burst that
pays the compile.

``_mixed_cell`` is the shape-class packing benchmark: a mixed ising+gmm
burst (round-robin) through one scheduler, under scan (ONE class
program with per-slot ``lax.switch`` dispatch) and pallas (one batched
fused-kernel grid per workload geometry — the per-slot solo-submit
fallback this replaced compiled and ran one program per slot per
segment).  The row reports ``shape_classes`` and ``compiled_programs``
alongside throughput, the compiled-programs-per-burst number the
regression gate tracks.

Row semantics: ``site_steps_per_s`` is total chain work / wall (the
regression gate's normalised throughput field, comparable with the
workloads table); ``requests_per_s`` and the latency percentiles come
from ``repro.serving.latency_summary`` over the measured burst only.
"""

from __future__ import annotations

import math
import time

from benchmarks.bench_workloads import machine_calibration
from repro.serving import Scheduler, ServeRequest, latency_summary

WORKLOAD = "gmm"  # MH + table target: every randomness backend applies
MIXED = ("gmm", "ising")  # round-robin mixed burst (even rid=gmm, odd=ising)


def _serve_cell(
    slots: int, randomness: str, n_steps: int, smoke: bool
) -> dict:
    n_requests = 2 * slots
    sched = Scheduler(
        n_slots=slots, randomness=randomness, execution="scan", smoke=smoke
    )
    # warm-up burst: compiles the packed advance traces for this slot
    # count (the measured burst replays the same (seg, collect) set)
    warm = [
        ServeRequest(
            rid=-1 - i, workload=WORKLOAD, n_steps=n_steps, seed=1000 + i
        )
        for i in range(n_requests)
    ]
    sched.serve(warm)

    now = sched.clock()
    reqs = [
        ServeRequest(
            rid=i, workload=WORKLOAD, n_steps=n_steps, seed=i, t_arrive=now
        )
        for i in range(n_requests)
    ]
    t0 = time.perf_counter()
    sched.serve(reqs)
    wall_s = time.perf_counter() - t0

    ex = sched.executor_for(WORKLOAD)
    n_sites = math.prod(ex.state_shape)
    site_steps = n_requests * n_steps * n_sites
    return {
        "workload": WORKLOAD,
        "update": ex.engine.config.update,
        "slots": slots,
        "randomness": randomness,
        "backend": "scan",
        "n_requests": n_requests,
        "n_steps": n_steps,
        "collect": "last",
        "wall_s": round(wall_s, 3),
        "site_steps_per_s": round(site_steps / max(wall_s, 1e-9), 1),
        "calib_steps_per_s": round(machine_calibration(), 1),
        **{
            k: v
            for k, v in latency_summary(reqs).items()
            if k != "n_requests"  # already a config key
        },
    }


def _mixed_cell(
    slots: int, randomness: str, execution: str, n_steps: int, smoke: bool
) -> dict:
    """A mixed ising+gmm burst through one scheduler: the shape-class
    packing cell.  ``compiled_programs`` counts compiled packed advance
    programs over warm-up + measurement (jit-cache growth) — one per
    shape class is the packing claim.

    The cell always runs the smoke workload shapes: it measures the
    *packing* cost (programs compiled, per-segment dispatch) at a given
    slot count, which the chain size only dilutes — the full-size chain
    throughput story lives in the homogeneous cells above.
    """
    del smoke  # the packing cell is shape-pinned (see docstring)
    smoke = True
    n_requests = 2 * slots

    def burst(rid0, seed0, t_arrive=0.0):
        return [
            ServeRequest(
                rid=rid0 + i, workload=MIXED[i % len(MIXED)],
                n_steps=n_steps, seed=seed0 + i, t_arrive=t_arrive,
            )
            for i in range(n_requests)
        ]

    sched = Scheduler(
        n_slots=slots, randomness=randomness, execution=execution,
        smoke=smoke, chunk_steps=16,
    )
    sched.serve(burst(-n_requests, 1000))  # warm-up pays the compiles

    now = sched.clock()
    reqs = burst(0, 0, t_arrive=now)
    t0 = time.perf_counter()
    sched.serve(reqs)
    wall_s = time.perf_counter() - t0

    site_steps = sum(
        n_steps * math.prod(sched.executor_for(r.workload).member_for(
            r.workload).state_shape)
        for r in reqs
    )
    return {
        "workload": "+".join(MIXED),
        "update": "mixed",
        "slots": slots,
        "randomness": randomness,
        "backend": execution,
        "n_requests": n_requests,
        "n_steps": n_steps,
        "collect": "last",
        "workload_groups": len(MIXED),
        "shape_classes": sched.shape_classes,
        "compiled_programs": sched.compiled_programs,
        "wall_s": round(wall_s, 3),
        "site_steps_per_s": round(site_steps / max(wall_s, 1e-9), 1),
        "calib_steps_per_s": round(machine_calibration(), 1),
        **{
            k: v
            for k, v in latency_summary(reqs).items()
            if k != "n_requests"
        },
    }


def presets(smoke: bool = False):
    """(slots, randomness) grid; smoke trims the pool sizes for CI."""
    slot_sizes = (1, 4) if smoke else (1, 4, 16)
    backends = ("host", "cim", "fused")
    return [(s, r) for s in slot_sizes for r in backends]


def mixed_presets(smoke: bool = False):
    """(slots, randomness, execution) for the mixed-burst packing cells:
    scan (one class program) vs pallas (one kernel grid per geometry)."""
    slots = 4 if smoke else 16
    return [(slots, "fused", "scan"), (slots, "fused", "pallas")]


def run(smoke: bool = False) -> list[dict]:
    n_steps = 64 if smoke else 512
    rows = [
        _serve_cell(slots, randomness, n_steps, smoke)
        for slots, randomness in presets(smoke)
    ]
    rows += [
        _mixed_cell(slots, randomness, execution, 64, smoke)
        for slots, randomness, execution in mixed_presets(smoke)
    ]
    return rows


if __name__ == "__main__":
    for row in run(smoke=True):
        print("  ".join(f"{k}={v}" for k, v in row.items()))
