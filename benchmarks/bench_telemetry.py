"""Telemetry overhead + trace-quality benchmark (DESIGN.md §Telemetry).

Two row families:

  * ``disabled_overhead`` — the overhead contract, measured: the same
    engine workload timed through the raw ``engine.run`` call (no
    instrumentation in the path) and through the instrumented
    ``engine.submit`` surface with telemetry OFF, best-of-N each.
    ``disabled_overhead_pct`` is the relative cost of the disabled
    instrumentation sites; ``check_regression`` fails any row whose
    overhead exceeds its ``overhead_budget_pct`` (2%).
  * ``enabled_trace`` — the same workload with tracing ON: records the
    trace volume (``trace_events``/``submit_calls``) and splits the
    submit wall time into ``compile_s`` (spans whose jit-cache verdict
    was "miss" — first trace of a signature) vs ``steady_s`` (cache
    hits), the compile-vs-execute decomposition the trace view shows.

``run(smoke=True)`` uses tiny presets for the CI bench-smoke job.
"""

from __future__ import annotations

import time

import jax

from benchmarks.bench_workloads import machine_calibration
from repro import telemetry, workloads
from repro.samplers.plan import RunPlan

OVERHEAD_BUDGET_PCT = 2.0


def _interleaved_overhead(fn_a, fn_b, repeats: int):
    """(best_a, best_b, overhead_ratio) with alternating runs.

    The overhead estimate is the MINIMUM over per-pair ratios
    ``t_b_i / t_a_i`` — each adjacent pair shares the machine's load
    conditions, so a single clean pair suffices to show the true
    (near-zero) overhead, where a min-over-separate-minima estimate
    needs *both* series to catch a clean window at once.  The gate
    budget is 2%; host-loop workloads jitter by more than that
    run-to-run, pairwise-min does not."""
    best_a = best_b = float("inf")
    ratio = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        fn_a()
        t_a = time.perf_counter() - t0
        t0 = time.perf_counter()
        fn_b()
        t_b = time.perf_counter() - t0
        best_a = min(best_a, t_a)
        best_b = min(best_b, t_b)
        ratio = min(ratio, t_b / max(t_a, 1e-9))
    return best_a, best_b, max(0.0, ratio - 1.0)


def bench_disabled_overhead(
    name: str = "ising", *, smoke: bool = True, n_steps: int | None = None,
    repeats: int = 7,
) -> dict:
    """Raw ``engine.run`` vs instrumented ``engine.submit`` with
    telemetry off — the <2% disabled-mode contract, measured."""
    telemetry.disable()
    wl = workloads.build(
        name, jax.random.PRNGKey(0), smoke=smoke, n_steps=n_steps
    )
    engine, target, init = wl.engine, wl.target, wl.init_words
    key = jax.random.PRNGKey(1)
    plan = RunPlan(
        target=target, n_steps=wl.n_steps, init_words=init, key=key
    )

    def base():
        r = engine.run(key, target, wl.n_steps, init)
        jax.block_until_ready(r.final_words)

    def instrumented():
        r = engine.submit(plan).result
        jax.block_until_ready(r.final_words)

    base()          # warm-up pays the compile for both paths (same trace)
    instrumented()
    t_base, t_inst, overhead = _interleaved_overhead(
        base, instrumented, repeats
    )
    overhead_pct = overhead * 100.0
    site_steps = wl.n_steps * int(init.size)
    return {
        "bench": "disabled_overhead",
        "workload": name,
        "n_steps": wl.n_steps,
        "overhead_budget_pct": OVERHEAD_BUDGET_PCT,
        "calib_steps_per_s": round(machine_calibration(), 1),
        "wall_s": round(t_inst, 4),
        "site_steps_per_s": round(site_steps / max(t_inst, 1e-9), 1),
        "base_site_steps_per_s": round(site_steps / max(t_base, 1e-9), 1),
        "disabled_overhead_pct": round(overhead_pct, 2),
    }


def bench_enabled_trace(
    name: str = "ising", *, smoke: bool = True, n_steps: int | None = None,
    calls: int = 4,
) -> dict:
    """Tracing ON: trace volume + the compile/steady split — ``calls``
    dispatches of one signature through the compiled submit surface, so
    call 1 compiles (span meta ``jit_cache="miss"``) and 2..N reuse the
    trace (``"hit"``); the span durations aggregate into ``compile_s``
    vs ``steady_s``."""
    wl = workloads.build(
        name, jax.random.PRNGKey(0), smoke=smoke, n_steps=n_steps
    )
    engine, target, init = wl.engine, wl.target, wl.init_words
    # a fresh engine instance isolates this row's jit cache so the
    # "miss" verdict lands on call 1 regardless of run order
    engine = type(engine)(engine.config)
    plan = RunPlan(
        target=target, n_steps=wl.n_steps, init_words=init,
        key=jax.random.PRNGKey(1), collect="last",
    )
    tracer = telemetry.enable()
    t0 = time.perf_counter()
    for _ in range(max(2, calls)):
        r = engine.submit(plan, compiled=True).result
        jax.block_until_ready(r.final_words)
    wall_s = time.perf_counter() - t0
    events = tracer.events()
    telemetry.disable()
    submit = [
        e for e in events if e.kind == "span" and e.name == "engine.submit"
    ]
    compile_s = sum(
        e.dur_us for e in submit if e.meta.get("jit_cache") == "miss"
    ) / 1e6
    steady_s = sum(
        e.dur_us for e in submit if e.meta.get("jit_cache") != "miss"
    ) / 1e6
    site_steps = wl.n_steps * max(2, calls) * int(init.size)
    return {
        "bench": "enabled_trace",
        "workload": name,
        "n_steps": wl.n_steps,
        "calls": max(2, calls),
        "calib_steps_per_s": round(machine_calibration(), 1),
        "wall_s": round(wall_s, 4),
        "site_steps_per_s": round(site_steps / max(wall_s, 1e-9), 1),
        "trace_events": len(events),
        "submit_calls": len(submit),
        "compile_s": round(compile_s, 4),
        "steady_s": round(steady_s, 4),
    }


def run(smoke: bool = False) -> list[dict]:
    n_steps = None if smoke else 2048
    return [
        bench_disabled_overhead("ising", smoke=smoke, n_steps=n_steps),
        bench_disabled_overhead("gmm", smoke=smoke, n_steps=n_steps),
        bench_enabled_trace("ising", smoke=smoke, n_steps=n_steps),
    ]


if __name__ == "__main__":
    for row in run(smoke=True):
        print("  ".join(f"{k}={v}" for k, v in row.items()))
