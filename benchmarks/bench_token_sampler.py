"""Token-sampler fidelity/latency trade-off (the paper's technique in LLM
decode position): TV distance to the exact softmax distribution vs MH
steps, with and without the beyond-paper top-k restriction."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import token_sampler


def _tv_for(cfg, logits, ref, n_runs=300, seed=0):
    sample = jax.jit(
        lambda k: token_sampler.sample_tokens(k, logits, cfg).tokens
    )
    counts = np.zeros(logits.shape[1])
    for k in jax.random.split(jax.random.PRNGKey(seed), n_runs):
        counts[int(sample(k)[0])] += 1
    emp = counts / counts.sum()
    return float(0.5 * np.abs(emp - ref).sum())


def run() -> list[dict]:
    rows = []
    vocab = 128
    n_runs = 300
    logits = jnp.asarray(
        np.random.default_rng(0).normal(size=(1, vocab)) * 2.0, jnp.float32
    )
    ref_full = np.asarray(jax.nn.softmax(logits[0]))

    # finite-sample floor: n_runs draws from the exact softmax
    exact = np.asarray(
        jax.random.categorical(
            jax.random.PRNGKey(9), jnp.repeat(logits, n_runs, 0), axis=-1
        )
    )
    emp = np.bincount(exact, minlength=vocab) / n_runs
    rows.append(
        {
            "bench": "token_sampler_fidelity",
            "variant": "exact_categorical (finite-sample floor)",
            "mh_steps": "-",
            "tv_vs_reference": round(float(0.5 * np.abs(emp - ref_full).sum()), 4),
        }
    )

    for n_steps in (8, 32, 128, 512):
        cfg = token_sampler.TokenSamplerConfig(vocab_size=vocab, n_steps=n_steps)
        rows.append(
            {
                "bench": "token_sampler_fidelity",
                "variant": "full_vocab",
                "mh_steps": n_steps,
                "tv_vs_reference": round(_tv_for(cfg, logits, ref_full, n_runs), 4),
            }
        )
    for top_k in (8, 32):
        cfg = token_sampler.TokenSamplerConfig(
            vocab_size=vocab, n_steps=32, top_k=top_k
        )
        # compare against the *restricted* renormalised softmax the top-k
        # sampler actually targets
        top_vals, top_idx = jax.lax.top_k(logits[0], top_k)
        ref_k = np.zeros(vocab)
        ref_k[np.asarray(top_idx)] = np.asarray(jax.nn.softmax(top_vals))
        rows.append(
            {
                "bench": "token_sampler_fidelity",
                "variant": f"top_{top_k} (beyond-paper)",
                "mh_steps": 32,
                "tv_vs_reference": round(_tv_for(cfg, logits, ref_k, n_runs), 4),
            }
        )
    return rows
