"""Token-sampler fidelity/latency trade-off (the paper's technique in LLM
decode position): TV distance to the exact softmax distribution vs MH
steps, with and without the beyond-paper top-k restriction — plus the
engine's two new axes: scan vs fused-pallas execution (measured latency)
and host vs cim randomness (measured fidelity/acceptance delta)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import token_sampler


def _tv_for(cfg, logits, ref, n_runs=300, seed=0):
    sample = jax.jit(
        lambda k: token_sampler.sample_tokens(k, logits, cfg).tokens
    )
    counts = np.zeros(logits.shape[1])
    for k in jax.random.split(jax.random.PRNGKey(seed), n_runs):
        counts[int(sample(k)[0])] += 1
    emp = counts / counts.sum()
    return float(0.5 * np.abs(emp - ref).sum())


def _latency_us(cfg, logits, reps=20, seed=0):
    sample = jax.jit(
        lambda k: token_sampler.sample_tokens(k, logits, cfg).tokens
    )
    keys = jax.random.split(jax.random.PRNGKey(seed), reps + 1)
    jax.block_until_ready(sample(keys[0]))  # compile
    t0 = time.perf_counter()
    for k in keys[1:]:
        out = sample(k)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list[dict]:
    rows = []
    vocab = 128
    n_runs = 300
    logits = jnp.asarray(
        np.random.default_rng(0).normal(size=(1, vocab)) * 2.0, jnp.float32
    )
    ref_full = np.asarray(jax.nn.softmax(logits[0]))

    # finite-sample floor: n_runs draws from the exact softmax
    exact = np.asarray(
        jax.random.categorical(
            jax.random.PRNGKey(9), jnp.repeat(logits, n_runs, 0), axis=-1
        )
    )
    emp = np.bincount(exact, minlength=vocab) / n_runs
    rows.append(
        {
            "bench": "token_sampler_fidelity",
            "variant": "exact_categorical (finite-sample floor)",
            "mh_steps": "-",
            "tv_vs_reference": round(float(0.5 * np.abs(emp - ref_full).sum()), 4),
        }
    )

    for n_steps in (8, 32, 128, 512):
        cfg = token_sampler.TokenSamplerConfig(vocab_size=vocab, n_steps=n_steps)
        rows.append(
            {
                "bench": "token_sampler_fidelity",
                "variant": "full_vocab",
                "mh_steps": n_steps,
                "tv_vs_reference": round(_tv_for(cfg, logits, ref_full, n_runs), 4),
            }
        )
    for top_k in (8, 32):
        cfg = token_sampler.TokenSamplerConfig(
            vocab_size=vocab, n_steps=32, top_k=top_k
        )
        # compare against the *restricted* renormalised softmax the top-k
        # sampler actually targets
        top_vals, top_idx = jax.lax.top_k(logits[0], top_k)
        ref_k = np.zeros(vocab)
        ref_k[np.asarray(top_idx)] = np.asarray(jax.nn.softmax(top_vals))
        rows.append(
            {
                "bench": "token_sampler_fidelity",
                "variant": f"top_{top_k} (beyond-paper)",
                "mh_steps": 32,
                "tv_vs_reference": round(_tv_for(cfg, logits, ref_k, n_runs), 4),
            }
        )

    # --- randomness axis: host jax.random vs cim pseudo-read + MSXOR -----
    for randomness in ("host", "cim"):
        cfg = token_sampler.TokenSamplerConfig(
            vocab_size=vocab, n_steps=64, randomness=randomness
        )
        tv = _tv_for(cfg, logits, ref_full, n_runs)
        sample = jax.jit(
            lambda k: token_sampler.sample_tokens(k, logits, cfg).acceptance_rate
        )
        acc = float(
            np.mean([sample(k) for k in jax.random.split(jax.random.PRNGKey(1), 32)])
        )
        rows.append(
            {
                "bench": "token_sampler_randomness",
                "randomness": randomness,
                "mh_steps": 64,
                "tv_vs_reference": round(tv, 4),
                # canonical label + pre-rename alias (DESIGN.md §Run-API)
                "acceptance_rate": round(acc, 3),
                "acceptance": round(acc, 3),
            }
        )

    # --- execution axis: lax.scan vs fused pallas (interpret off-TPU) ----
    batch_logits = jnp.asarray(
        np.random.default_rng(1).normal(size=(8, vocab)) * 2.0, jnp.float32
    )
    on_tpu = jax.default_backend() == "tpu"
    for execution in ("scan", "pallas"):
        cfg = token_sampler.TokenSamplerConfig(
            vocab_size=vocab, n_steps=64, execution=execution
        )
        rows.append(
            {
                "bench": "token_sampler_backend",
                "execution": execution
                + ("" if on_tpu or execution == "scan" else " (interpret)"),
                "batch": batch_logits.shape[0],
                "mh_steps": 64,
                "us_per_batch": round(_latency_us(cfg, batch_logits), 1),
            }
        )
    return rows
