"""Paper Fig. 17(c)/(d): GMM & MGD sampling — macro vs software baselines.

Exactly the paper's experiment, scaled to this container:
  * NumPy MH-MCMC (the paper's "NumPy on CPU" baseline) — measured live,
  * JAX jit MH-MCMC (the paper's "JAX CPU" baseline) — measured live,
  * the CIM macro — sample quality simulated through the behavioural model,
    wall time derived from the calibrated 28 nm timing model (we cannot
    tape out; the derivation is validated against §6.4/§6.5 in
    table_fig16*), at the paper's fixed 32-bit sample width.

Sample quality (TV distance on the discretisation grid) is reported for
all three so the speedup is apples-to-apples at matched fidelity.
"""

import time

import jax
import numpy as np

from repro.core import energy, metropolis, targets
from repro.core.macro import CIMMacro, MacroConfig

N_SAMPLES = 100_000  # paper sweeps to 1e6; scaled for the CPU container


def _numpy_mh(log_prob, nbits, n_samples, p_bfr=0.45, burn_in=500, seed=0):
    rng = np.random.default_rng(seed)
    state = int(rng.integers(0, 1 << nbits))
    logp = log_prob(state)
    out = np.empty(n_samples, dtype=np.uint32)
    for i in range(n_samples + burn_in):
        flips = rng.random(nbits) < p_bfr
        mask = int(np.sum((1 << np.arange(nbits))[flips]))
        cand = state ^ mask
        logp_c = log_prob(cand)
        if rng.random() < np.exp(min(0.0, logp_c - logp)):
            state, logp = cand, logp_c
        if i >= burn_in:
            out[i - burn_in] = state
    return out


def _tv(samples, ref_probs, nbits):
    counts = np.bincount(np.asarray(samples).reshape(-1), minlength=1 << nbits)
    emp = counts / counts.sum()
    return float(0.5 * np.abs(emp - ref_probs).sum())


def _case(name, density, codec):
    log_prob_jax = targets.discretized_target(density, codec)
    ref = targets.reference_grid_probs(density, codec)
    logp_table = np.log(np.maximum(ref, 1e-300))
    rows = []

    # --- NumPy baseline (scalar chain, the paper's slowest software case)
    np_samples = min(N_SAMPLES, 20_000)  # NumPy is slow; scale + extrapolate
    t0 = time.perf_counter()
    s_np = _numpy_mh(lambda w: logp_table[w], codec.nbits, np_samples)
    t_np = (time.perf_counter() - t0) * (N_SAMPLES / np_samples)
    rows.append(
        {
            "bench": f"fig17_{name}",
            "impl": "numpy_cpu",
            "n_samples": N_SAMPLES,
            "time_s": round(t_np, 4),
            "samples_per_s": f"{N_SAMPLES / t_np:.3g}",
            "tv_distance": round(_tv(s_np, ref, codec.nbits), 4),
        }
    )

    # --- JAX jit baseline (vectorised chains, fair modern-software case)
    cfg = metropolis.MHConfig(nbits=codec.nbits, burn_in=500)
    n_chains = 64
    per_chain = N_SAMPLES // n_chains
    run = jax.jit(
        lambda k: metropolis.run_chain(
            k, log_prob_jax, cfg, n_samples=per_chain, chain_shape=(n_chains,)
        ).samples
    )
    s_jax = run(jax.random.PRNGKey(0))
    s_jax.block_until_ready()  # exclude compile
    t0 = time.perf_counter()
    s_jax = run(jax.random.PRNGKey(1))
    s_jax.block_until_ready()
    t_jax = time.perf_counter() - t0
    rows.append(
        {
            "bench": f"fig17_{name}",
            "impl": "jax_jit_cpu",
            "n_samples": N_SAMPLES,
            "time_s": round(t_jax, 4),
            "samples_per_s": f"{N_SAMPLES / t_jax:.3g}",
            "tv_distance": round(_tv(s_jax, ref, codec.nbits), 4),
        }
    )

    # --- CIM macro: quality from the behavioural twin, time from the
    # calibrated 28 nm model at 32-bit samples (paper's configuration)
    macro = CIMMacro(MacroConfig(nbits=codec.nbits, burn_in=500))
    words, stats = macro.sample(
        jax.random.PRNGKey(2), log_prob_jax, n_samples=N_SAMPLES
    )
    t_macro = energy.time_for_samples_s(N_SAMPLES, nbits=32)
    rows.append(
        {
            "bench": f"fig17_{name}",
            "impl": "cim_macro_28nm",
            "n_samples": N_SAMPLES,
            "time_s": f"{t_macro:.3g}",
            "samples_per_s": f"{N_SAMPLES / t_macro:.3g}",
            "tv_distance": round(_tv(words, ref, codec.nbits), 4),
            # canonical label + pre-rename alias
            "acceptance_rate": round(stats.acceptance_rate, 3),
            "acceptance": round(stats.acceptance_rate, 3),
            "energy_pj_per_sample": round(stats.energy_per_sample_pj, 4),
            "speedup_vs_numpy": f"{t_np / t_macro:.3g}",
            "speedup_vs_jax": f"{t_jax / t_macro:.3g}",
        }
    )
    return rows


def run() -> list[dict]:
    gmm = targets.GaussianMixture.paper_gmm()
    gmm_codec = targets.GridCodec(nbits=8, dim=1, lo=(-10.0,), hi=(10.0,))
    mgd = targets.MultivariateGaussian.paper_mgd()
    mgd_codec = targets.GridCodec(
        nbits=12, dim=2, lo=(-4.0, -4.0), hi=(4.0, 4.0)
    )
    return _case("gmm", gmm, gmm_codec) + _case("mgd", mgd, mgd_codec)
