"""Sampler statistical quality: TV distance + per-bit uniformity as a
function of burn-in (the paper's §2.1 burn-in discussion, quantified)."""

import jax
import numpy as np

from repro.core import metropolis, targets, uniform_rng


def run() -> list[dict]:
    rows = []
    gmm = targets.GaussianMixture.paper_gmm()
    codec = targets.GridCodec(nbits=8, dim=1, lo=(-10.0,), hi=(10.0,))
    log_prob = targets.discretized_target(gmm, codec)
    ref = targets.reference_grid_probs(gmm, codec)
    for burn_in in (0, 100, 500, 1000):
        cfg = metropolis.MHConfig(nbits=8, burn_in=burn_in)
        res = metropolis.run_chain(
            jax.random.PRNGKey(0), log_prob, cfg, n_samples=1000, chain_shape=(64,)
        )
        counts = np.bincount(
            np.asarray(res.samples).reshape(-1), minlength=256
        )
        emp = counts / counts.sum()
        rows.append(
            {
                "bench": "sampler_quality_burnin",
                "burn_in": burn_in,
                "tv_distance": round(float(0.5 * np.abs(emp - ref).sum()), 4),
                # canonical label + pre-rename alias
                "acceptance_rate": round(float(res.acceptance_rate), 3),
                "acceptance": round(float(res.acceptance_rate), 3),
            }
        )
    # uniform RNG quality (chi-square-ish per-bit stats)
    u = np.asarray(
        uniform_rng.uniform(jax.random.PRNGKey(1), (400_000,), 0.45, 16)
    )
    hist, _ = np.histogram(u, bins=64, range=(0, 1))
    expected = u.size / 64
    chi2 = float(((hist - expected) ** 2 / expected).sum())
    rows.append(
        {
            "bench": "uniform_rng_quality",
            "n": u.size,
            "mean": round(float(u.mean()), 5),
            "chi2_64bins": round(chi2, 1),
            "chi2_expected_df63": "~63 +- 11",
        }
    )
    return rows
