"""Workload-zoo benchmark: throughput + sample quality per workload/executor.

For each zoo workload (ising Gibbs, gmm MH) x execution backend, run the
engine, time it, and fold in the chain diagnostics and the macro energy
model: ESS per joule is the figure of merit that ties sample *quality*
to the hardware's energy story (MC²RAM / Bashizade-style accounting —
a sampler that mixes twice as fast is worth twice the joules).

``run(smoke=True)`` uses tiny presets sized for the CI bench-smoke job
(benchmarks/check_regression.py gates PRs on these rows).
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro import workloads
from repro.core import energy


@functools.lru_cache(maxsize=1)
def machine_calibration() -> float:
    """Reference FLOP-loop throughput (element-steps/s) of this machine.

    A fixed, engine-independent jax scan measured best-of-3.  Every bench
    row carries it so ``check_regression`` can compare *normalised*
    throughput across machines — the committed baseline and the CI runner
    are different hardware, and a raw wall-clock gate would just measure
    that difference.
    """
    steps, side = 2000, 64
    x = jnp.zeros((side, side), jnp.float32)

    def body(c, _):
        c = jnp.tanh(c * 1.000001 + 0.5)
        return c, c.sum()

    f = jax.jit(lambda v: jax.lax.scan(body, v, None, length=steps))
    jax.block_until_ready(f(x))
    best = float("inf")
    for _ in range(3):
        t0 = time.time()
        jax.block_until_ready(f(x))
        best = min(best, time.time() - t0)
    return steps * side * side / max(best, 1e-9)


def bench_workload(
    name: str, execution: str, num_chains: int = 1, repeats: int = 1, **kwargs
) -> dict:
    """One timed workload run folded with diagnostics + the energy model.

    ``repeats`` re-times the run and keeps the fastest wall-clock —
    best-of-N is what makes the tiny smoke presets stable enough for the
    CI regression gate (a loaded runner inflates individual timings by
    2x; the minimum tracks the actual compute).
    """
    key = jax.random.PRNGKey(0)
    k_init, k_run = jax.random.split(key)
    wl = workloads.build(
        name, k_init, randomness="cim", backend=execution,
        num_chains=num_chains, **kwargs,
    )
    # warm-up compile, then timed runs (keep the fastest + its result)
    jax.block_until_ready(wl.run(k_run).samples)
    wall_s = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.time()
        result = wl.run(k_run)
        jax.block_until_ready(result.samples)
        wall_s = min(wall_s, time.time() - t0)

    diag = wl.diagnostics(result)
    n_sites = int(wl.init_words.size)  # includes the chains axis
    site_steps = wl.n_steps * n_sites
    nbits = int(wl.meta.get("nbits", 4))
    macro_j = (
        energy.energy_per_sample_fj(float(result.acceptance_rate), nbits)
        * site_steps
        * 1e-15
    )
    return {
        "bench": "workloads",
        "workload": name,
        "execution": execution,
        "num_chains": num_chains,
        "n_steps": wl.n_steps,
        "n_sites": n_sites,
        "wall_s": round(wall_s, 3),
        "site_steps_per_s": round(site_steps / max(wall_s, 1e-9), 1),
        "calib_steps_per_s": round(machine_calibration(), 1),
        # canonical rate label (workloads.WorkloadRun.rate_key):
        # acceptance_rate for mh, flip_rate for gibbs; "acceptance" is
        # the pre-rename alias column kept for old table readers
        wl.rate_key: diag.get(wl.rate_key),
        "acceptance": diag.get(wl.rate_key),
        "tau": diag["tau"],
        "ess": diag["ess"],
        "split_rhat": diag["split_rhat"],
        "macro_energy_uj": round(macro_j * 1e6, 4),
        "ess_per_joule": round(diag["ess"] / macro_j, 1),
    }


def presets(smoke: bool = False):
    # smoke sizes are chosen so even the fastest (pallas) rows spend
    # ~0.1 s+ in the chain proper — dispatch overhead must not dominate
    # a timing that the CI regression gate compares across machines
    if smoke:
        return (
            ("ising", dict(height=8, width=8, batch=2, n_steps=384)),
            ("gmm", dict(chains=32, n_steps=384)),
        )
    return (
        ("ising", dict(height=8, width=8, batch=4, n_steps=256)),
        ("gmm", dict(chains=32, n_steps=512)),
    )


def run(smoke: bool = False) -> list[dict]:
    rows = []
    for name, kwargs in presets(smoke):
        for execution in ("scan", "pallas"):
            rows.append(
                bench_workload(
                    name, execution, repeats=5 if smoke else 1, **kwargs
                )
            )
    return rows
