"""Workload-zoo benchmark: throughput + sample quality per workload/executor.

For each zoo workload (ising Gibbs, gmm MH) x execution backend, run the
engine, time it, and fold in the chain diagnostics and the macro energy
model: ESS per joule is the figure of merit that ties sample *quality*
to the hardware's energy story (MC²RAM / Bashizade-style accounting —
a sampler that mixes twice as fast is worth twice the joules).
"""

from __future__ import annotations

import time

import jax

from repro import workloads
from repro.core import energy


def _bench_one(name: str, execution: str, **kwargs) -> dict:
    key = jax.random.PRNGKey(0)
    k_init, k_run = jax.random.split(key)
    wl = workloads.build(
        name, k_init, randomness="cim", backend=execution, **kwargs
    )
    # warm-up compile, then timed run
    jax.block_until_ready(wl.run(k_run).samples)
    t0 = time.time()
    result = wl.run(k_run)
    jax.block_until_ready(result.samples)
    wall_s = time.time() - t0

    diag = wl.diagnostics(result)
    n_sites = int(wl.init_words.size)
    site_steps = wl.n_steps * n_sites
    nbits = int(wl.meta.get("nbits", 4))
    macro_j = (
        energy.energy_per_sample_fj(float(result.acceptance_rate), nbits)
        * site_steps
        * 1e-15
    )
    return {
        "bench": "workloads",
        "workload": name,
        "execution": execution,
        "n_steps": wl.n_steps,
        "n_sites": n_sites,
        "wall_s": round(wall_s, 3),
        "site_steps_per_s": round(site_steps / max(wall_s, 1e-9), 1),
        "acceptance": diag["acceptance_rate"],
        "tau": diag["tau"],
        "ess": diag["ess"],
        "split_rhat": diag["split_rhat"],
        "macro_energy_uj": round(macro_j * 1e6, 4),
        "ess_per_joule": round(diag["ess"] / macro_j, 1),
    }


def run() -> list[dict]:
    rows = []
    for name, kwargs in (
        ("ising", dict(height=8, width=8, batch=4, n_steps=256)),
        ("gmm", dict(chains=32, n_steps=512)),
    ):
        for execution in ("scan", "pallas"):
            rows.append(_bench_one(name, execution, **kwargs))
    return rows
