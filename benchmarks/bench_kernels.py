"""Kernel micro-benchmarks (interpret-mode CPU wall times + work rates).

Interpret-mode timings validate plumbing, not TPU perf — the TPU-side
story lives in the dry-run/roofline artifacts.  Reported here: us/call and
debiased-bits/s (MSXOR) / chain-steps/s (fused MH) for three sizes each,
plus the engine-level scan-vs-pallas delta at matched shapes (same
randomness backend, same chunking — so the delta is pure executor cost).
"""

import time

import jax
import jax.numpy as jnp

from repro import samplers
from repro.kernels.mh import ops as mh_ops
from repro.kernels.msxor import ops as msxor_ops


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run() -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(0)
    for m in (4096, 65536, 262144):
        raw = jax.random.bits(key, (8, m), dtype=jnp.uint32)
        dt = _time(msxor_ops.msxor_fold, raw)
        rows.append(
            {
                "bench": "kernel_msxor",
                "raw_words": f"8x{m}",
                "us_per_call": round(dt * 1e6, 1),
                "debiased_bits_per_s": f"{32 * m / dt:.3g}",
            }
        )
    for b, c, k in ((1, 64, 64), (8, 256, 64), (16, 1024, 32)):
        table = jax.random.normal(key, (b, 256), jnp.float32)
        init = jnp.zeros((b, c), jnp.uint32)
        rnd = mh_ops.generate_randomness(key, k, b, c, 0.45)

        def call(t, i, f, u):
            return mh_ops.mh_sample(t, i, f, u, nbits=8)

        dt = _time(call, table, init, rnd.flips, rnd.u)
        rows.append(
            {
                "bench": "kernel_mh_fused",
                "shape": f"B{b}xC{c}xK{k}",
                "us_per_call": round(dt * 1e6, 1),
                "chain_steps_per_s": f"{b * c * k / dt:.3g}",
            }
        )

    # --- engine execution axis: scan vs pallas, randomness included ------
    on_tpu = jax.default_backend() == "tpu"
    for b, c, k in ((1, 64, 64), (8, 256, 64)):
        table = jax.random.normal(key, (b, 256), jnp.float32)
        target = samplers.TableTarget(table)
        init = jnp.zeros((b, c), jnp.uint32)
        for execution in ("scan", "pallas"):
            engine = samplers.MHEngine(
                samplers.EngineConfig(execution=execution, chunk_steps=32)
            )
            run_fn = jax.jit(
                lambda kk, ii, e=engine, t=target, n=k: e.submit(
                    samplers.RunPlan(
                        target=t, n_steps=n, init_words=ii, key=kk
                    )
                ).accept_count
            )
            dt = _time(run_fn, key, init)
            rows.append(
                {
                    "bench": "engine_backend",
                    "execution": execution
                    + ("" if on_tpu or execution == "scan" else " (interpret)"),
                    "shape": f"B{b}xC{c}xK{k}",
                    "us_per_call": round(dt * 1e6, 1),
                    "chain_steps_per_s": f"{b * c * k / dt:.3g}",
                }
            )
    return rows
