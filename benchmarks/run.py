"""Benchmark harness: one module per paper table/figure.

Each module exposes ``run() -> list[dict]``; this driver executes them all
and prints per-table key=value lines (machine-greppable, human-readable).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig17      # name filter
"""

from __future__ import annotations

import sys
import time

MODULES = [
    ("fig4_bfr", "benchmarks.table_fig4_bfr"),
    ("fig9_msxor", "benchmarks.table_fig9_msxor"),
    ("fig15_thermal", "benchmarks.table_fig15_thermal"),
    ("fig16a_energy", "benchmarks.table_fig16_energy"),
    ("fig16b_throughput", "benchmarks.table_fig16b_throughput"),
    ("fig17_sampling", "benchmarks.table_fig17_sampling"),
    ("kernels", "benchmarks.bench_kernels"),
    ("sampler_quality", "benchmarks.bench_sampler_quality"),
    ("token_sampler", "benchmarks.bench_token_sampler"),
    ("gray_ablation", "benchmarks.bench_gray_ablation"),
]


def main() -> None:
    flt = sys.argv[1] if len(sys.argv) > 1 else ""
    failures = []
    for name, modpath in MODULES:
        if flt and flt not in name:
            continue
        print(f"\n=== {name} ({modpath}) ===")
        t0 = time.time()
        try:
            mod = __import__(modpath, fromlist=["run"])
            rows = mod.run()
            for row in rows:
                print("  " + "  ".join(f"{k}={v}" for k, v in row.items()))
            print(f"  [{len(rows)} rows, {time.time() - t0:.1f}s]")
        except Exception as e:  # keep the harness going; report at the end
            import traceback

            traceback.print_exc()
            failures.append((name, repr(e)))
    if failures:
        print("\nFAILED:", failures)
        raise SystemExit(1)
    print("\nall benchmarks completed")


if __name__ == "__main__":
    main()
