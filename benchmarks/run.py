"""Benchmark harness: one module per paper table/figure or subsystem.

Each module exposes ``run() -> list[dict]``; this driver executes them
all, prints per-table key=value lines (machine-greppable,
human-readable), and aggregates every table into ``BENCH_workloads.json``
at the repo root so the perf trajectory stays machine-readable across
PRs (rows are merged table-by-table, so a filtered run refreshes only
the tables it executed).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig17      # name filter
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI bench-smoke job

``--smoke`` runs only the modules that expose tiny presets
(``run(smoke=True)``), writes their tables under a ``_smoke`` suffix —
so a smoke run never clobbers the full-size rows — and is what the CI
bench job regenerates and gates via ``benchmarks.check_regression``.
``--out`` redirects the aggregate (CI writes a fresh file and compares
it against the committed baseline).
"""

from __future__ import annotations

import argparse
import inspect
import json
import os
import time

MODULES = [
    ("fig4_bfr", "benchmarks.table_fig4_bfr"),
    ("fig9_msxor", "benchmarks.table_fig9_msxor"),
    ("fig15_thermal", "benchmarks.table_fig15_thermal"),
    ("fig16a_energy", "benchmarks.table_fig16_energy"),
    ("fig16b_throughput", "benchmarks.table_fig16b_throughput"),
    ("fig17_sampling", "benchmarks.table_fig17_sampling"),
    ("kernels", "benchmarks.bench_kernels"),
    ("sampler_quality", "benchmarks.bench_sampler_quality"),
    ("token_sampler", "benchmarks.bench_token_sampler"),
    ("gray_ablation", "benchmarks.bench_gray_ablation"),
    ("workloads", "benchmarks.bench_workloads"),
    ("autotune", "benchmarks.bench_autotune"),
    ("chain_scaling", "benchmarks.bench_chain_scaling"),
    ("tempering", "benchmarks.bench_tempering"),
    ("collection", "benchmarks.bench_collection"),
    ("serving", "benchmarks.bench_serving"),
    ("telemetry", "benchmarks.bench_telemetry"),
]

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
AGGREGATE_PATH = os.path.join(_REPO_ROOT, "BENCH_workloads.json")


def _supports_smoke(run_fn) -> bool:
    return "smoke" in inspect.signature(run_fn).parameters


def write_aggregate(tables: dict, path: str = AGGREGATE_PATH) -> None:
    """Merge the tables that ran into the cross-PR aggregate file."""
    merged = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f).get("tables", {})
        except (json.JSONDecodeError, OSError):
            merged = {}  # corrupt/legacy file: rebuild from this run
    merged.update(tables)
    with open(path, "w") as f:
        json.dump({"format": 1, "tables": merged}, f, indent=2, sort_keys=True)
        f.write("\n")


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="benchmarks.run", description="Run the benchmark tables."
    )
    p.add_argument("filter", nargs="?", default="", help="table-name filter")
    p.add_argument(
        "--smoke", action="store_true",
        help="tiny presets; only smoke-capable modules; *_smoke table names",
    )
    p.add_argument(
        "--out", default=AGGREGATE_PATH,
        help=f"aggregate JSON path (default {AGGREGATE_PATH})",
    )
    p.add_argument(
        "--trace-dir", default=None, metavar="DIR",
        help="record a telemetry trace per bench module and export "
        "DIR/<table>.trace.jsonl artifacts (summarize/validate with "
        "python -m repro.launch.monitor)",
    )
    return p


def _export_module_trace(trace_dir: str, name: str) -> None:
    """One trace artifact per bench module, plus the compile/steady
    split its engine.submit spans carry (printed, not tabled — the
    gated compile_s/steady_s fields live in the telemetry table)."""
    from repro import telemetry

    path = os.path.join(trace_dir, f"{name}.trace.jsonl")
    events = telemetry.TRACER.events()
    n = telemetry.TRACER.export_jsonl(path)
    submit = [
        e for e in events if e.kind == "span" and e.name == "engine.submit"
    ]
    compile_s = sum(
        e.dur_us for e in submit if e.meta.get("jit_cache") == "miss"
    ) / 1e6
    steady_s = sum(
        e.dur_us for e in submit if e.meta.get("jit_cache") != "miss"
    ) / 1e6
    print(
        f"  [trace] {n} events -> {path} (submit compile_s="
        f"{compile_s:.3f} steady_s={steady_s:.3f})"
    )


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    if args.trace_dir:
        os.makedirs(args.trace_dir, exist_ok=True)
    failures = []
    tables = {}
    for name, modpath in MODULES:
        if args.filter and args.filter not in name:
            continue
        print(f"\n=== {name} ({modpath}) ===")
        t0 = time.time()
        try:
            mod = __import__(modpath, fromlist=["run"])
            if args.smoke:
                if not _supports_smoke(mod.run):
                    print("  [skipped: no smoke presets]")
                    continue
                name = f"{name}_smoke"
            if args.trace_dir:
                from repro import telemetry

                telemetry.enable()  # reset: one trace per module
            rows = mod.run(smoke=True) if args.smoke else mod.run()
            for row in rows:
                print("  " + "  ".join(f"{k}={v}" for k, v in row.items()))
            print(f"  [{len(rows)} rows, {time.time() - t0:.1f}s]")
            if args.trace_dir:
                _export_module_trace(args.trace_dir, name)
            tables[name] = rows
        except Exception as e:  # keep the harness going; report at the end
            import traceback

            traceback.print_exc()
            failures.append((name, repr(e)))
    if tables:
        write_aggregate(tables, path=args.out)
        print(f"\naggregated {len(tables)} tables -> {args.out}")
    if failures:
        print("\nFAILED:", failures)
        raise SystemExit(1)
    print("\nall benchmarks completed")


if __name__ == "__main__":
    main()
