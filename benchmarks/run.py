"""Benchmark harness: one module per paper table/figure or subsystem.

Each module exposes ``run() -> list[dict]``; this driver executes them
all, prints per-table key=value lines (machine-greppable,
human-readable), and aggregates every table into ``BENCH_workloads.json``
at the repo root so the perf trajectory stays machine-readable across
PRs (rows are merged table-by-table, so a filtered run refreshes only
the tables it executed).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig17      # name filter
"""

from __future__ import annotations

import json
import os
import sys
import time

MODULES = [
    ("fig4_bfr", "benchmarks.table_fig4_bfr"),
    ("fig9_msxor", "benchmarks.table_fig9_msxor"),
    ("fig15_thermal", "benchmarks.table_fig15_thermal"),
    ("fig16a_energy", "benchmarks.table_fig16_energy"),
    ("fig16b_throughput", "benchmarks.table_fig16b_throughput"),
    ("fig17_sampling", "benchmarks.table_fig17_sampling"),
    ("kernels", "benchmarks.bench_kernels"),
    ("sampler_quality", "benchmarks.bench_sampler_quality"),
    ("token_sampler", "benchmarks.bench_token_sampler"),
    ("gray_ablation", "benchmarks.bench_gray_ablation"),
    ("workloads", "benchmarks.bench_workloads"),
]

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
AGGREGATE_PATH = os.path.join(_REPO_ROOT, "BENCH_workloads.json")


def write_aggregate(tables: dict, path: str = AGGREGATE_PATH) -> None:
    """Merge the tables that ran into the cross-PR aggregate file."""
    merged = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f).get("tables", {})
        except (json.JSONDecodeError, OSError):
            merged = {}  # corrupt/legacy file: rebuild from this run
    merged.update(tables)
    with open(path, "w") as f:
        json.dump({"format": 1, "tables": merged}, f, indent=2, sort_keys=True)
        f.write("\n")


def main() -> None:
    flt = sys.argv[1] if len(sys.argv) > 1 else ""
    failures = []
    tables = {}
    for name, modpath in MODULES:
        if flt and flt not in name:
            continue
        print(f"\n=== {name} ({modpath}) ===")
        t0 = time.time()
        try:
            mod = __import__(modpath, fromlist=["run"])
            rows = mod.run()
            for row in rows:
                print("  " + "  ".join(f"{k}={v}" for k, v in row.items()))
            print(f"  [{len(rows)} rows, {time.time() - t0:.1f}s]")
            tables[name] = rows
        except Exception as e:  # keep the harness going; report at the end
            import traceback

            traceback.print_exc()
            failures.append((name, repr(e)))
    if tables:
        write_aggregate(tables)
        print(f"\naggregated {len(tables)} tables -> {AGGREGATE_PATH}")
    if failures:
        print("\nFAILED:", failures)
        raise SystemExit(1)
    print("\nall benchmarks completed")


if __name__ == "__main__":
    main()
