"""Paper Fig. 16(b): throughput vs sample precision (4..64 bits).

Checks the headline 166.7 M samples/s at 4-bit and the sub-2x slowdown per
bit doubling (§6.5), plus the aggregate macro rate with 64 compartments.
"""

from repro.core import energy


def run() -> list[dict]:
    rows = []
    prev = None
    for nbits in (4, 8, 16, 32, 64):
        per_chain = energy.throughput_per_chain(nbits)
        rows.append(
            {
                "bench": "fig16b_throughput",
                "nbits": nbits,
                "iteration_ns": energy.iteration_time_ns(nbits),
                "per_chain_samples_per_s": f"{per_chain:.4g}",
                "macro_aggregate_per_s": f"{energy.throughput_aggregate(nbits):.4g}",
                "slowdown_vs_half_bits": (
                    round(prev / per_chain, 3) if prev else ""
                ),
                "paper_anchor": "166.7e6" if nbits == 4 else "",
            }
        )
        prev = per_chain
    return rows
