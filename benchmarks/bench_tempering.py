"""Tempering benchmark: time-to-target-energy and swap health, host vs cim.

The figure of merit for the tempering subsystem (DESIGN.md §Tempering)
is *optimisation* throughput, not raw step rate: on an exhaustively
solvable ±J spin-glass instance, how many engine steps (and how much
wall-clock) until the cold replica has visited the true ground state,
and do the replica-exchange diagnostics (per-pair swap acceptance,
walker round trips) show a ladder that actually transports
configurations?  Rows sweep the replica count R ∈ {2, 8, 16} for both
randomness backends — the host-vs-cim comparison carries to swap
decisions too, since swap uniforms come from the same backend stream.

``run(smoke=True)`` uses tiny presets for the CI bench-smoke job; the
regression gate compares calibration-normalised ``site_steps_per_s``
only (benchmarks/check_regression.py) — steps-to-ground is seeded and
deterministic but listed as a measured field.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_workloads import machine_calibration
from repro import tempering, workloads
from repro.workloads.spin_glass import exhaustive_ground_state

REPLICA_COUNTS = (2, 8, 16)


def bench_ladder(
    num_replicas: int,
    randomness: str,
    execution: str,
    height: int,
    width: int,
    batch: int,
    n_steps: int,
    swap_every: int,
    repeats: int = 1,
) -> dict:
    key = jax.random.PRNGKey(0)
    k_init, k_run = jax.random.split(key)
    wl = workloads.build(
        "spin_glass", k_init, randomness=randomness, backend=execution,
        height=height, width=width, batch=batch, n_steps=n_steps,
    )
    ladder = tempering.Ladder.geometric(num_replicas, beta_min=0.3)
    rex = tempering.ReplicaExchange(
        ladder=ladder, engine=wl.engine, swap_every=swap_every
    )
    init = jnp.broadcast_to(wl.init_words, (num_replicas, *wl.init_words.shape))
    ground_e, _ = exhaustive_ground_state(wl.target)

    # warm-up compile, then timed runs: best-of-N wall-clock keeps smoke
    # rows stable on a loaded CI runner; the kept result is the last
    # run's, which equals every run's (tempered streams are
    # key-deterministic)
    jax.block_until_ready(rex.run(k_run, wl.target, wl.n_steps, init).samples)
    wall_s = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.time()
        result = rex.run(k_run, wl.target, wl.n_steps, init)
        jax.block_until_ready(result.samples)
        wall_s = min(wall_s, time.time() - t0)

    # time-to-target: first cold-replica step whose energy hits the
    # exhaustive ground energy (deterministic for a fixed key)
    cold_e = np.asarray(wl.target.energy(result.cold_samples))  # (T, B)
    hits = np.nonzero(np.isclose(cold_e.min(axis=1), ground_e))[0]
    steps_to_ground = int(hits[0]) + 1 if hits.size else -1
    swap = result.swap.summary()
    rates = [r for r in swap["pair_accept_rate"] if r == r]

    n_sites = int(init.size)
    site_steps = wl.n_steps * n_sites
    return {
        "bench": "tempering",
        "workload": "spin_glass",
        "randomness": randomness,
        "execution": execution,
        "lattice": f"{height}x{width}",
        "batch": batch,
        "num_replicas": num_replicas,
        "swap_every": swap_every,
        "n_steps": n_steps,
        "n_sites": n_sites,
        "wall_s": round(wall_s, 3),
        "site_steps_per_s": round(site_steps / max(wall_s, 1e-9), 1),
        "calib_steps_per_s": round(machine_calibration(), 1),
        "swap_accept_rate": swap["swap_accept_rate"],
        "swap_rate_min": round(min(rates), 4) if rates else float("nan"),
        "swap_rate_max": round(max(rates), 4) if rates else float("nan"),
        "round_trips": swap["round_trips"],
        "ground_energy": round(ground_e, 4),
        "best_energy": round(float(cold_e.min()), 4),
        "steps_to_ground": steps_to_ground,
        "time_to_ground_s": round(
            wall_s * steps_to_ground / n_steps, 4
        ) if steps_to_ground > 0 else -1.0,
    }


def presets(smoke: bool = False):
    # 4x4 keeps the exhaustive ground-truth solve trivial; step counts
    # give every ladder a fair shot at touching the ground state
    if smoke:
        return dict(
            height=4, width=4, batch=1, n_steps=96, swap_every=8,
            executions=("scan",), replica_counts=(2, 8), repeats=3,
        )
    return dict(
        height=4, width=4, batch=2, n_steps=256, swap_every=16,
        executions=("scan", "pallas"), replica_counts=REPLICA_COUNTS,
        repeats=1,
    )


def run(smoke: bool = False) -> list[dict]:
    cfg = presets(smoke)
    rows = []
    for execution in cfg["executions"]:
        for randomness in ("host", "cim"):
            for num_replicas in cfg["replica_counts"]:
                rows.append(
                    bench_ladder(
                        num_replicas, randomness, execution,
                        height=cfg["height"], width=cfg["width"],
                        batch=cfg["batch"], n_steps=cfg["n_steps"],
                        swap_every=cfg["swap_every"],
                        repeats=cfg["repeats"],
                    )
                )
    return rows
