"""Autotuner evidence table: measured constants vs the hand-chosen ones.

One row per workload: the incumbent (committed ``EngineConfig``
constants) and the autotuned winner, both measured under the tuner's own
protocol (warm-up compile, best-of-N — ``samplers.autotune``).  Because
the incumbent is always the first candidate in the tuner's grid and the
winner is the measured argmax, ``speedup >= 1.0`` holds by construction
— the bench gate (``check_regression.py``) then guards the *tuned*
throughput across PRs via the shared ``site_steps_per_s`` column.

Rows force a fresh measurement (``refresh=True``), so the table reports
this machine/commit, not a stale cache; the measurement still lands in
the autotune cache for subsequent runs to hit.
"""

from __future__ import annotations

import jax

from benchmarks.bench_workloads import machine_calibration
from repro import samplers, workloads


def _row(name: str, smoke: bool, n_steps: int, repeats: int) -> dict:
    key = jax.random.PRNGKey(0)
    k_init, k_run = jax.random.split(key)
    wl = workloads.build(name, k_init, randomness="cim", smoke=smoke)
    cfg = wl.engine.config
    tuned_cfg, tuned = samplers.autotune_config(
        cfg, wl.target, wl.init_words, key=k_run,
        n_steps=n_steps, repeats=repeats, refresh=True,
    )
    return {
        "bench": "autotune",
        "workload": name,
        "chunk_default": cfg.chunk_steps,
        # measured outputs (machine-dependent — excluded from row
        # identity in check_regression.MEASURED_FIELDS)
        "chunk_tuned": tuned.chunk_steps,
        "block_c_tuned": tuned.block_c,
        "execution_tuned": tuned.execution,
        "default_steps_per_s": round(tuned.baseline_steps_per_s, 1),
        "site_steps_per_s": round(tuned.steps_per_s, 1),
        "calib_steps_per_s": round(machine_calibration(), 1),
        "speedup": round(
            tuned.steps_per_s / max(tuned.baseline_steps_per_s, 1e-9), 3
        ),
        "candidates": len(tuned.candidates),
    }


def run(smoke: bool = False) -> list[dict]:
    if smoke:
        spec = dict(smoke=True, n_steps=128, repeats=2)
    else:
        spec = dict(smoke=False, n_steps=512, repeats=3)
    return [_row(name, **spec) for name in ("ising", "gmm")]


if __name__ == "__main__":
    for r in run(smoke=True):
        print("  ".join(f"{k}={v}" for k, v in r.items()))
