"""Paper Fig. 4(c): bit flip rate vs CVDD under pseudo-read.

Reports the behavioural BFR model at the paper's anchor supplies and a
Monte-Carlo check that simulated pseudo-reads reproduce the curve.
"""

import jax

from repro.core import bitcell


def run() -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(0)
    for cvdd in (0.3, 0.4, 0.5, 0.55, 0.6, 0.7, 0.8):
        p_model = float(bitcell.bit_flip_rate(cvdd))
        bits = bitcell.pseudo_read_fresh(
            jax.random.fold_in(key, int(cvdd * 100)),
            p_model,
            shape=(500_000,),
        )
        p_mc = float(bits.mean())
        rows.append(
            {
                "bench": "fig4c_bfr",
                "cvdd_v": cvdd,
                "bfr_model": round(p_model, 4),
                "bfr_montecarlo": round(p_mc, 4),
                "paper_anchor": {0.5: 0.45, 0.6: 0.40}.get(cvdd, ""),
            }
        )
    return rows
