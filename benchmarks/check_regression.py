"""Bench-regression gate: fail CI when throughput drops vs the baseline.

Compares a freshly generated benchmark aggregate (``benchmarks.run
--smoke --out fresh.json``) against the committed baseline
(``BENCH_workloads.json``).  For every table present in both files, rows
are matched on their *configuration* keys (everything that is not a
measured quantity); a matched row regresses when its throughput falls
more than ``--threshold`` (default 30%) below the baseline.  A baseline
row with no fresh counterpart also fails — a vanished row is how a
regression hides.

  PYTHONPATH=src python -m benchmarks.check_regression \
      --fresh bench_smoke.json --baseline BENCH_workloads.json

Exit code 0 = within budget, 1 = regression (or malformed inputs).
"""

from __future__ import annotations

import argparse
import json
import sys

# measured outputs; everything else in a row is configuration identity
MEASURED_FIELDS = frozenset({
    "wall_s",
    "site_steps_per_s",
    "steps_per_s",
    "calib_steps_per_s",
    # the canonical rate labels (workloads.WorkloadRun.rate_key) plus the
    # pre-rename "acceptance" alias column old tables still carry
    "acceptance",
    "acceptance_rate",
    "flip_rate",
    "tau",
    "ess",
    "split_rhat",
    "macro_energy_uj",
    "ess_per_joule",
    "window_capped",
    # autotune table (benchmarks/bench_autotune.py): the tuned choice is
    # a machine-dependent *output*, never row identity
    "chunk_tuned",
    "block_c_tuned",
    "execution_tuned",
    "default_steps_per_s",
    "speedup",
    "candidates",
    # collection table (benchmarks/bench_collection.py) — analytic
    # footprints ride along as measured so formula tweaks never orphan
    # a baseline row
    "kept_steps",
    "chunk_operand_mb",
    "kept_sample_mb",
    "peak_operand_mb",
    "operand_bytes_per_step",
    "measured_operand_bytes_per_step",
    # tempering table (benchmarks/bench_tempering.py)
    "swap_accept_rate",
    "swap_rate_min",
    "swap_rate_max",
    "round_trips",
    "ground_energy",
    "best_energy",
    "steps_to_ground",
    "time_to_ground_s",
    # serving table (benchmarks/bench_serving.py): request-level
    # throughput/latency ride along; the gate still normalises on
    # site_steps_per_s like every other row
    "requests_per_s",
    "p50_latency_s",
    "p99_latency_s",
    "mean_wait_s",
    # shape-class packing (mixed-burst cells): compiled packed advance
    # programs per burst and the class count are measured outputs — the
    # packing claim is one program per class, not per slot or workload
    "compiled_programs",
    "shape_classes",
    "workload_groups",
    # wait-vs-service decomposition (serving/scheduler.latency_summary)
    "p99_wait_s",
    "mean_service_s",
    "p50_service_s",
    "p99_service_s",
    # telemetry table (benchmarks/bench_telemetry.py): the disabled-mode
    # overhead contract plus trace volume and the compile/steady split
    "base_site_steps_per_s",
    "disabled_overhead_pct",
    "trace_events",
    "submit_calls",
    "compile_s",
    "steady_s",
})

# a fresh row reporting disabled-mode telemetry overhead above its
# budget fails the gate outright — the overhead contract is absolute,
# not relative to the baseline row
OVERHEAD_FIELD = "disabled_overhead_pct"
OVERHEAD_BUDGET_FIELD = "overhead_budget_pct"
DEFAULT_OVERHEAD_BUDGET_PCT = 2.0

THROUGHPUT_FIELD = "site_steps_per_s"
CALIBRATION_FIELD = "calib_steps_per_s"


def normalized_throughput(row: dict) -> float:
    """Throughput divided by the row's machine-calibration factor (when
    present on both sides of a comparison) — baseline and CI run on
    different hardware, and the gate must measure the *code*, not the
    runner."""
    thpt = float(row[THROUGHPUT_FIELD])
    calib = row.get(CALIBRATION_FIELD)
    return thpt / float(calib) if calib else thpt


def row_identity(row: dict) -> tuple:
    return tuple(
        sorted((k, str(v)) for k, v in row.items() if k not in MEASURED_FIELDS)
    )


def load_tables(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    tables = data.get("tables")
    if not isinstance(tables, dict):
        raise ValueError(f"{path}: no 'tables' mapping (format 1 expected)")
    return tables


MIN_WALL_S = 0.05  # baseline rows faster than this are dispatch noise


def compare(
    fresh: dict, baseline: dict, threshold: float
) -> tuple[list[str], int]:
    """(failure messages, number of rows compared).

    Rows whose *baseline* wall-clock is under ``MIN_WALL_S`` are skipped:
    a timing that small measures dispatch overhead, not the chain, and
    the calibration factor only models compute throughput."""
    failures = []
    compared = 0
    # absolute gates on fresh rows (no baseline counterpart needed)
    for table in sorted(fresh):
        for row in fresh[table]:
            if OVERHEAD_FIELD not in row:
                continue
            compared += 1
            budget = float(
                row.get(OVERHEAD_BUDGET_FIELD, DEFAULT_OVERHEAD_BUDGET_PCT)
            )
            got = float(row[OVERHEAD_FIELD])
            if got > budget:
                failures.append(
                    f"OVERHEAD  {table}: "
                    + " ".join(f"{k}={v}" for k, v in row_identity(row))
                    + f": {OVERHEAD_FIELD} {got:.2f}% > budget {budget:g}%"
                )
    for table in sorted(set(fresh) & set(baseline)):
        base_rows = {
            row_identity(r): r
            for r in baseline[table]
            if THROUGHPUT_FIELD in r
        }
        fresh_rows = {
            row_identity(r): r
            for r in fresh[table]
            if THROUGHPUT_FIELD in r
        }
        for ident, base in sorted(base_rows.items()):
            label = f"{table}: " + " ".join(f"{k}={v}" for k, v in ident)
            got = fresh_rows.get(ident)
            if got is None:
                failures.append(f"MISSING  {label}")
                continue
            if float(base.get("wall_s", MIN_WALL_S)) < MIN_WALL_S:
                print(f"  skipped (wall_s < {MIN_WALL_S}s): {label}")
                continue
            compared += 1
            if CALIBRATION_FIELD in base and CALIBRATION_FIELD in got:
                b, f = normalized_throughput(base), normalized_throughput(got)
                unit = f"{THROUGHPUT_FIELD}/calib"
            else:  # legacy rows without calibration: raw wall-clock gate
                b = float(base[THROUGHPUT_FIELD])
                f = float(got[THROUGHPUT_FIELD])
                unit = THROUGHPUT_FIELD
            floor = (1.0 - threshold) * b
            if f < floor:
                failures.append(
                    f"REGRESSED  {label}: {unit} "
                    f"{f:.3g} < {floor:.3g} (baseline {b:.3g}, "
                    f"-{(1 - f / b) * 100:.0f}%)"
                )
    return failures, compared


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="benchmarks.check_regression",
        description="Gate throughput against the committed bench baseline.",
    )
    p.add_argument("--fresh", required=True, help="freshly generated aggregate")
    p.add_argument(
        "--baseline", default="BENCH_workloads.json", help="committed baseline"
    )
    p.add_argument(
        "--threshold", type=float, default=0.30,
        help="max allowed fractional throughput drop (default 0.30)",
    )
    args = p.parse_args(argv)
    fresh = load_tables(args.fresh)
    baseline = load_tables(args.baseline)
    shared = sorted(set(fresh) & set(baseline))
    if not shared:
        print(
            f"no shared tables between {args.fresh} ({sorted(fresh)}) and "
            f"{args.baseline} ({sorted(baseline)})"
        )
        return 1
    failures, compared = compare(fresh, baseline, args.threshold)
    if failures:
        print(f"bench regression check FAILED ({len(failures)} problems):")
        for msg in failures:
            print(f"  {msg}")
        return 1
    print(
        f"bench regression check passed: {compared} rows across "
        f"{len(shared)} tables within {args.threshold:.0%} of baseline"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
