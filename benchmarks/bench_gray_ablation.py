"""Beyond-paper ablation: Gray-coded grid encoding for the bit-flip proposal.

The paper raster-encodes sample values as plain binary, so a single-bit
flip in a high bit jumps 2^k grid cells — long-range proposals that are
mostly rejected on smooth targets.  Gray-coding the per-dimension fields
(`GridCodec(gray=True)`) makes *every* single-bit flip move to an adjacent
or power-of-two-near cell with a smoother distance profile, at zero
hardware cost (the decode LUT changes, not the macro).

Reported: acceptance rate and TV-vs-exact for binary vs Gray at matched
chain budgets, on both paper workloads.  (Multi-bit pseudo-read flips at
p_BFR=0.45 temper the effect — the chain is near-independence — so we
also report a low-flip-rate variant (p=0.1) where proposal locality
dominates; that regime is exactly the macro's CVDD≈0.65 V operating
point.)
"""

import jax
import numpy as np

from repro.core import metropolis, targets


def _run(density, codec, p_bfr: float, seed=0):
    log_prob = targets.discretized_target(density, codec)
    cfg = metropolis.MHConfig(nbits=codec.nbits, p_bfr=p_bfr, burn_in=300)
    res = metropolis.run_chain(
        jax.random.PRNGKey(seed), log_prob, cfg, n_samples=1500,
        chain_shape=(64,),
    )
    counts = np.bincount(
        np.asarray(res.samples).reshape(-1), minlength=1 << codec.nbits
    )
    emp = counts / counts.sum()
    ref = targets.reference_grid_probs(density, codec)
    tv = float(0.5 * np.abs(emp - ref).sum())
    return tv, float(res.acceptance_rate)


def run() -> list[dict]:
    rows = []
    gmm = targets.GaussianMixture.paper_gmm()
    mgd = targets.MultivariateGaussian.paper_mgd()
    cases = [
        ("gmm_8bit", gmm, dict(nbits=8, dim=1, lo=(-10.0,), hi=(10.0,))),
        ("mgd_12bit", mgd, dict(nbits=12, dim=2, lo=(-4.0, -4.0), hi=(4.0, 4.0))),
    ]
    for name, density, kw in cases:
        for p_bfr in (0.45, 0.10):
            for gray in (False, True):
                codec = targets.GridCodec(gray=gray, **kw)
                tv, acc = _run(density, codec, p_bfr)
                rows.append(
                    {
                        "bench": "gray_code_ablation",
                        "target": name,
                        "p_bfr": p_bfr,
                        "encoding": "gray" if gray else "binary (paper)",
                        "tv_distance": round(tv, 4),
                        # canonical label + pre-rename alias
                        "acceptance_rate": round(acc, 3),
                        "acceptance": round(acc, 3),
                    }
                )
    return rows
