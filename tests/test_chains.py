"""Chains axis (DESIGN.md §Chains-axis): chains==solo bit-parity, chunk
invariance with C>1, workload wiring, and sharded==unsharded equality.

The contract under test: per-chain randomness (and per-chain workload
inits) are counter-derived from ``(chain_id, absolute_step)``, so chain c
of a C-chain run is bit-identical to a solo run with ``chain_id=c`` —
for both randomness backends, both update rules, and both executors —
and sharding the chain axis over a device mesh changes nothing.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import samplers, workloads
from repro.launch import sample as sample_cli
from repro.workloads.ising import IsingModel

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _mh_target(b=2, v=64, chains=8, seed=0):
    table = jax.random.normal(jax.random.PRNGKey(seed), (b, v), jnp.float32)
    init = jnp.broadcast_to(
        jnp.argmax(table, -1).astype(jnp.uint32)[:, None], (b, chains)
    )
    return samplers.TableTarget(table), init


def _gibbs_target(b=2, h=6, w=6, seed=0):
    model = IsingModel(height=h, width=w, beta=0.35)
    return model, model.random_init(jax.random.PRNGKey(seed), b)


def _engine(**kw):
    return samplers.MHEngine(samplers.EngineConfig(**kw))


def _bcast(init, num_chains):
    """Explicit chain broadcast — the engine requires the leading axis."""
    return jnp.broadcast_to(init, (num_chains, *init.shape))


class TestChainsSoloParity:
    @pytest.mark.parametrize("randomness", ["host", "cim"])
    @pytest.mark.parametrize("execution", ["scan", "pallas"])
    @pytest.mark.parametrize("update", ["mh", "gibbs"])
    def test_chain_of_multi_run_equals_solo(
        self, randomness, execution, update
    ):
        """The ISSUE-3 acceptance matrix: every {randomness} x {executor}
        x {update rule} cell satisfies chains==solo bit-parity."""
        if update == "mh":
            target, init = _mh_target()
        else:
            target, init = _gibbs_target()
        key = jax.random.PRNGKey(7)
        n_steps, num_chains = 22, 3
        multi = _engine(
            update=update, randomness=randomness, execution=execution,
            num_chains=num_chains, chunk_steps=8,
        ).run(key, target, n_steps, _bcast(init, num_chains))
        solo_engine = _engine(
            update=update, randomness=randomness, execution=execution,
            chunk_steps=8,
        )
        for c in range(num_chains):
            solo = solo_engine.run(key, target, n_steps, init, chain_id=c)
            np.testing.assert_array_equal(
                np.asarray(multi.samples[c]), np.asarray(solo.samples)
            )
            np.testing.assert_array_equal(
                np.asarray(multi.accept_count[c]),
                np.asarray(solo.accept_count),
            )
            np.testing.assert_array_equal(
                np.asarray(multi.final_logp[c]), np.asarray(solo.final_logp)
            )

    @pytest.mark.parametrize("update", ["mh", "gibbs"])
    def test_scan_and_pallas_multi_chain_bit_identical(self, update):
        """Executor parity survives the chains axis (the pallas side runs
        a genuinely batched grid, not a python loop over chains)."""
        target, init = _mh_target() if update == "mh" else _gibbs_target()
        key = jax.random.PRNGKey(3)
        runs = {}
        for execution in ("scan", "pallas"):
            runs[execution] = _engine(
                update=update, execution=execution, num_chains=4,
                chunk_steps=8,
            ).run(key, target, 20, _bcast(init, 4))
        np.testing.assert_array_equal(
            np.asarray(runs["scan"].samples), np.asarray(runs["pallas"].samples)
        )
        np.testing.assert_array_equal(
            np.asarray(runs["scan"].accept_count),
            np.asarray(runs["pallas"].accept_count),
        )

    @pytest.mark.parametrize("update", ["mh", "gibbs"])
    def test_chunked_vs_monolithic_with_chains(self, update):
        """Chunk invariance must hold per chain: randomness for
        (chain, step) depends only on (key, chain_id, t)."""
        target, init = _mh_target() if update == "mh" else _gibbs_target()
        key = jax.random.PRNGKey(11)
        r_chunked = _engine(update=update, num_chains=4, chunk_steps=7).run(
            key, target, 30, _bcast(init, 4)
        )
        r_mono = _engine(update=update, num_chains=4, chunk_steps=1000).run(
            key, target, 30, _bcast(init, 4)
        )
        np.testing.assert_array_equal(
            np.asarray(r_chunked.samples), np.asarray(r_mono.samples)
        )
        np.testing.assert_array_equal(
            np.asarray(r_chunked.accept_count),
            np.asarray(r_mono.accept_count),
        )

    def test_per_chain_init_respected(self):
        """A (num_chains, ...) init seeds each chain separately; an
        init without the leading chain axis is rejected, never guessed
        (a solo init whose first dim equals num_chains would otherwise
        be silently misread as per-chain)."""
        target, init = _mh_target(chains=4)
        per_chain = jnp.stack([init, init + 1, init + 2])
        key = jax.random.PRNGKey(0)
        multi = _engine(num_chains=3).run(key, target, 8, per_chain)
        for c in range(3):
            solo = _engine().run(key, target, 8, per_chain[c], chain_id=c)
            np.testing.assert_array_equal(
                np.asarray(multi.samples[c]), np.asarray(solo.samples)
            )
        with pytest.raises(ValueError, match="leading"):
            _engine(num_chains=3).run(key, target, 8, init)
        # pallas executors additionally pin the per-chain rank, so a
        # solo-shaped init whose first dim collides with num_chains is
        # caught rather than silently folded
        with pytest.raises(ValueError, match="num_chains, B, C"):
            _engine(num_chains=2, execution="pallas").run(
                key, target, 8, init
            )

    def test_chain_id_base_composes_multi_runs(self):
        """chain_id offsets a multi-chain run: two 4-chain runs with
        bases 0 and 4 are exactly the 8-chain run, stream for stream."""
        target, init = _mh_target()
        key = jax.random.PRNGKey(5)
        full = _engine(num_chains=8).run(key, target, 10, _bcast(init, 8))
        eng4 = _engine(num_chains=4)
        lo = eng4.run(key, target, 10, _bcast(init, 4), chain_id=0)
        hi = eng4.run(key, target, 10, _bcast(init, 4), chain_id=4)
        np.testing.assert_array_equal(
            np.asarray(full.samples),
            np.concatenate([np.asarray(lo.samples), np.asarray(hi.samples)]),
        )

    def test_num_chains_validation(self):
        with pytest.raises(ValueError):
            samplers.EngineConfig(num_chains=0)


class TestWorkloadChains:
    @pytest.mark.parametrize("name", ["ising", "gmm"])
    def test_workload_chain0_equals_solo_build(self, name):
        """The CLI acceptance criterion: --num-chains C vs --num-chains 1
        agree on chain 0 bit-for-bit, inits included."""
        k_init, k_run = jax.random.split(jax.random.PRNGKey(0))
        multi = workloads.build(
            name, k_init, smoke=True, n_steps=16, backend="pallas",
            num_chains=4,
        )
        solo = workloads.build(
            name, k_init, smoke=True, n_steps=16, backend="pallas",
            num_chains=1,
        )
        np.testing.assert_array_equal(
            np.asarray(multi.init_words[0]), np.asarray(solo.init_words)
        )
        np.testing.assert_array_equal(
            np.asarray(multi.run(k_run).samples[0]),
            np.asarray(solo.run(k_run).samples),
        )

    def test_cli_num_chains_smoke(self, capsys):
        row = sample_cli.main(
            ["--workload", "ising", "--smoke", "--steps", "12",
             "--num-chains", "4", "--backend", "pallas"]
        )
        assert row["num_chains"] == 4
        assert "ess" in row and "split_rhat" in row
        # 4 chains x 2 smoke lattices contribute 8 diagnostic columns
        assert row["n_chains"] == 8
        assert "num_chains=4" in capsys.readouterr().out

    def test_multi_chain_diagnostics_stream_matches_batch(self):
        """WorkloadRun.diagnostics streams the (T, C·m) block in chunks;
        the result must equal the batch estimator over the same block."""
        from repro import diagnostics

        k_init, k_run = jax.random.split(jax.random.PRNGKey(1))
        wl = workloads.build(
            "gmm", k_init, smoke=True, n_steps=40, num_chains=3,
            backend="scan",
        )
        result = wl.run(k_run)
        streamed = wl.diagnostics(result)
        series = wl.series(result)[wl.burn_in:]
        batch = diagnostics.summarize(
            series, acceptance_rate=float(result.acceptance_rate)
        )
        assert streamed == batch


class TestShardedChains:
    def test_sharded_equals_unsharded_two_device_mesh(self):
        """shard_map over a mocked 2-device mesh: the chain axis shards,
        the sample streams do not change (subprocess — the main pytest
        process keeps 1 CPU device)."""
        code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro import samplers
        from repro.workloads.ising import IsingModel

        assert jax.device_count() == 2, jax.devices()
        # jax.sharding.Mesh directly: jax.make_mesh needs >= 0.4.35 and
        # this must pass on the pinned-min (0.4.30) CI cell
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()), ("data",))
        key = jax.random.PRNGKey(7)

        table = jax.random.normal(jax.random.PRNGKey(0), (2, 64), jnp.float32)
        target = samplers.TableTarget(table)
        init = jnp.broadcast_to(
            jnp.argmax(table, -1).astype(jnp.uint32)[:, None], (2, 8)
        )
        cinit = jnp.broadcast_to(init, (4, *init.shape))
        eng = samplers.MHEngine(samplers.EngineConfig(
            num_chains=4, execution="scan", chunk_steps=8))
        a = eng.run(key, target, 16, cinit, mesh=mesh)
        b = eng.run(key, target, 16, cinit)
        np.testing.assert_array_equal(
            np.asarray(a.samples), np.asarray(b.samples))

        model = IsingModel(height=6, width=6)
        ginit = model.random_init(jax.random.PRNGKey(1), 2)
        gcinit = jnp.broadcast_to(ginit, (4, *ginit.shape))
        geng = samplers.MHEngine(samplers.EngineConfig(
            update="gibbs", num_chains=4, chunk_steps=8))
        a = geng.run(key, model, 12, gcinit, mesh=mesh)
        b = geng.run(key, model, 12, gcinit)
        np.testing.assert_array_equal(
            np.asarray(a.samples), np.asarray(b.samples))

        # a chain count the mesh doesn't divide replicates (still correct)
        odd = samplers.MHEngine(samplers.EngineConfig(num_chains=3)).run(
            key, target, 8, jnp.broadcast_to(init, (3, *init.shape)),
            mesh=mesh)
        assert odd.samples.shape[0] == 3
        print("SHARDED-OK")
        """
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        env["PYTHONPATH"] = SRC
        # keep the child on the CPU platform explicitly: popping
        # JAX_PLATFORMS makes jax probe for accelerator plugins, which
        # stalls for minutes on CI-like containers
        env["JAX_PLATFORMS"] = "cpu"
        out = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(code)],
            capture_output=True, text=True, env=env, timeout=900,
        )
        assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
        assert "SHARDED-OK" in out.stdout
