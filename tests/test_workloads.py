"""Workload zoo: Gibbs engine parity, Ising statistics, GMM posterior, CLI.

The PR-1 parity guarantee (same key => bit-identical streams across
executors and chunkings) must extend to the ``gibbs`` update rule, and
the workloads must sample their nominal distributions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import samplers, workloads
from repro.kernels.gibbs import ops as gibbs_ops
from repro.kernels.gibbs.ref import gibbs_chain_ref
from repro.launch import sample as sample_cli
from repro.workloads import gmm as gmm_wl
from repro.workloads.ising import IsingModel


def _gibbs_engine(**kw):
    kw.setdefault("update", "gibbs")
    return samplers.MHEngine(samplers.EngineConfig(**kw))


def _lattice(b=2, h=8, w=8, seed=0):
    model = IsingModel(height=h, width=w, beta=0.35)
    init = model.random_init(jax.random.PRNGKey(seed), b)
    return model, init


class TestGibbsExecutionParity:
    @pytest.mark.parametrize("randomness", ["host", "cim"])
    def test_scan_and_pallas_bit_identical(self, randomness):
        """The Gibbs half-sweep has one scan body and one kernel body that
        mirror each other op-for-op => exact array equality."""
        model, init = _lattice()
        key = jax.random.PRNGKey(7)
        r_scan = _gibbs_engine(
            execution="scan", randomness=randomness, chunk_steps=16
        ).run(key, model, 40, init)
        r_pal = _gibbs_engine(
            execution="pallas", randomness=randomness, chunk_steps=16
        ).run(key, model, 40, init)
        np.testing.assert_array_equal(
            np.asarray(r_scan.samples), np.asarray(r_pal.samples)
        )
        np.testing.assert_array_equal(
            np.asarray(r_scan.accept_count), np.asarray(r_pal.accept_count)
        )
        np.testing.assert_array_equal(
            np.asarray(r_scan.final_logp), np.asarray(r_pal.final_logp)
        )

    @pytest.mark.parametrize("execution", ["scan", "pallas"])
    def test_chunked_vs_monolithic_bit_identical(self, execution):
        """Checkerboard parity rides the absolute step index, so chunking
        cannot change the sweep schedule."""
        model, init = _lattice(b=1, h=6, w=6, seed=1)
        key = jax.random.PRNGKey(11)
        r_chunked = _gibbs_engine(execution=execution, chunk_steps=7).run(
            key, model, 30, init
        )
        r_mono = _gibbs_engine(execution=execution, chunk_steps=1000).run(
            key, model, 30, init
        )
        np.testing.assert_array_equal(
            np.asarray(r_chunked.samples), np.asarray(r_mono.samples)
        )
        np.testing.assert_array_equal(
            np.asarray(r_chunked.accept_count),
            np.asarray(r_mono.accept_count),
        )

    def test_kernel_matches_ref_oracle(self):
        """Same logit_fn on both sides: a mismatch isolates pallas_call
        plumbing, not conditional math."""
        model = IsingModel(height=8, width=8, beta=0.4, field=0.1)
        key = jax.random.PRNGKey(3)
        init = jax.random.bernoulli(key, 0.5, (2, 8, 8)).astype(jnp.uint32)
        u = jax.random.uniform(jax.random.fold_in(key, 1), (20, 2, 8, 8))
        s_k, f_k = gibbs_ops.gibbs_sweep(init, u, model.conditional_logit)
        s_r, f_r = gibbs_chain_ref(init, u, model.conditional_logit)
        np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
        np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_r))


class TestGibbsSemantics:
    def test_only_active_colour_updates(self):
        """A half-sweep may only touch sites of its checkerboard parity."""
        model, init = _lattice(b=1, h=8, w=8, seed=2)
        res = _gibbs_engine(execution="scan", randomness="host").run(
            jax.random.PRNGKey(0), model, 2, init
        )
        first = np.asarray(res.samples[0])
        changed = first != np.asarray(init)
        row, col = np.indices((8, 8))
        inactive = ((row + col) % 2) != 0  # step 0 has parity 0
        assert not changed[0][inactive].any()

    def test_flip_rate_at_most_half(self):
        model, init = _lattice()
        res = _gibbs_engine(execution="scan").run(
            jax.random.PRNGKey(5), model, 60, init
        )
        assert 0.0 < float(res.acceptance_rate) <= 0.5

    @pytest.mark.slow
    def test_beta_zero_matches_independent_spins(self):
        """At beta=0 every active site resamples i.i.d. with
        p(+1) = sigmoid(2h), so <s> -> tanh(h)."""
        h_field = 0.3
        model = IsingModel(height=16, width=16, beta=0.0, field=h_field)
        init = model.random_init(jax.random.PRNGKey(0), 2)
        res = _gibbs_engine(execution="scan", randomness="host").run(
            jax.random.PRNGKey(9), model, 160, init
        )
        mags = np.asarray(model.magnetization(res.samples[40:]))
        assert mags.mean() == pytest.approx(np.tanh(h_field), abs=0.03)

    @pytest.mark.slow
    def test_cold_lattice_orders(self):
        """Deep below the critical point (beta >> 0.44) the lattice
        magnetises: |<s>| climbs towards 1."""
        model = IsingModel(height=12, width=12, beta=1.0)
        init = model.random_init(jax.random.PRNGKey(1), 2)
        res = _gibbs_engine(execution="scan", randomness="cim").run(
            jax.random.PRNGKey(2), model, 400, init
        )
        mags = np.asarray(model.magnetization(res.samples[300:]))
        assert np.abs(mags).mean() > 0.8


class TestGibbsDispatch:
    def test_update_rule_validation(self):
        with pytest.raises(ValueError):
            samplers.EngineConfig(update="metropolis-within-gibbs")

    def test_gibbs_needs_conditional_target(self):
        table = samplers.TableTarget(jnp.zeros((1, 16), jnp.float32))
        with pytest.raises(ValueError, match="conditional"):
            _gibbs_engine(execution="scan").run(
                jax.random.PRNGKey(0), table, 4, jnp.zeros((1, 4), jnp.uint32)
            )

    def test_pallas_gibbs_needs_fused_lattice_model(self):
        table = samplers.TableTarget(jnp.zeros((1, 16), jnp.float32))
        with pytest.raises(ValueError, match="checkerboard"):
            samplers.resolve_execution("pallas", table, "gibbs")

    def test_auto_gibbs_is_always_scan(self):
        """auto cannot see whether the lattice is lane-aligned, so it
        never fuses Gibbs — explicit pallas opts in."""
        model = IsingModel(height=4, width=4)
        assert samplers.resolve_execution("auto", model, "gibbs") == "scan"

    def test_pallas_gibbs_rejects_flat_state(self):
        model, _ = _lattice()
        with pytest.raises(ValueError, match="lattice state"):
            _gibbs_engine(execution="pallas").run(
                jax.random.PRNGKey(0), model, 4, jnp.zeros((16,), jnp.uint32)
            )


class TestGMMWorkload:
    def test_scan_and_pallas_bit_identical(self):
        key = jax.random.PRNGKey(0)
        runs = {}
        for backend in ("scan", "pallas"):
            wl = workloads.build("gmm", key, smoke=True, backend=backend)
            runs[backend] = wl.run(jax.random.PRNGKey(4))
        np.testing.assert_array_equal(
            np.asarray(runs["scan"].samples), np.asarray(runs["pallas"].samples)
        )

    def test_table_materialises_callable_exactly(self):
        """The TableTarget rows are by construction the CallableTarget's
        values at every word — same distribution, fused-kernel-eligible."""
        mix, codec = gmm_wl.default_model()
        callable_t = gmm_wl.make_callable_target(mix, codec)
        table_t = gmm_wl.make_table_target(mix, codec)
        words = jnp.arange(1 << codec.nbits, dtype=jnp.uint32)[None, :]
        np.testing.assert_allclose(
            np.asarray(callable_t.log_prob(words)),
            np.asarray(table_t.log_prob(words)),
            rtol=1e-6,
        )

    @pytest.mark.slow
    def test_posterior_matches_reference_grid(self):
        """Post burn-in histogram converges to the exact cell probabilities
        (TV distance) — the MC²RAM benchmark's correctness claim."""
        wl = workloads.build(
            "gmm",
            jax.random.PRNGKey(1),
            randomness="host",
            backend="scan",
            chains=64,
            n_steps=1500,
        )
        res = wl.run(jax.random.PRNGKey(2))
        kept = np.asarray(res.samples[wl.burn_in:]).reshape(-1)
        emp = np.bincount(kept, minlength=256) / kept.size
        ref = gmm_wl.reference_probs(8)
        tv = 0.5 * np.abs(emp - ref).sum()
        assert tv < 0.08, f"TV {tv}"


class TestRegistryAndCLI:
    def test_unknown_workload_rejected(self):
        with pytest.raises(ValueError, match="unknown workload"):
            workloads.build("spin-glass", jax.random.PRNGKey(0))

    @pytest.mark.parametrize("workload", ["ising", "gmm"])
    @pytest.mark.parametrize("randomness", ["host", "cim"])
    @pytest.mark.parametrize("backend", ["scan", "pallas"])
    def test_cli_smoke_matrix(self, workload, randomness, backend, capsys):
        """The PR's acceptance matrix: every workload completes under
        every --randomness x --backend combination on CPU."""
        row = sample_cli.main(
            ["--workload", workload, "--smoke", "--steps", "12",
             "--randomness", randomness, "--backend", backend]
        )
        assert row["workload"] == workload
        assert row["update"] == ("gibbs" if workload == "ising" else "mh")
        assert "ess" in row and "split_rhat" in row
        assert f"workload={workload}" in capsys.readouterr().out

    def test_cli_burn_in_slicing(self):
        row = sample_cli.main(
            ["--workload", "gmm", "--smoke", "--steps", "24",
             "--randomness", "host"]
        )
        assert row["kept_steps"] == 24 - 24 // 4
