"""Multi-device distributed tests: run in subprocesses with fake devices
(the main pytest process keeps 1 CPU device)."""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

if not hasattr(jax.sharding, "AxisType"):
    pytest.skip(
        "jax.sharding.AxisType unavailable on this jax version "
        "(every case here builds an AxisType mesh in a subprocess)",
        allow_module_level=True,
    )

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n_devices: int = 8, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


class TestCompressedPodPsum:
    def test_int8_error_feedback_reduction(self):
        """Compressed pod-psum matches the exact mean within int8 rounding,
        and the error feedback makes the *accumulated* series exact."""
        out = run_with_devices(
            """
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P, AxisType
            from repro.distributed.compression import compressed_pmean

            mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'),
                                 axis_types=(AxisType.Auto,)*3)
            rng = np.random.default_rng(0)
            g_pods = rng.normal(size=(2, 64)).astype(np.float32)

            def body(err_w):
                g_true = jnp.asarray(g_pods)  # (2, 64)
                def inner(e):
                    idx = jax.lax.axis_index('pod')
                    g = g_true[idx]  # pod-varying gradient
                    red, new_e = compressed_pmean({'w': g}, {'w': e}, 'pod')
                    return red['w'], new_e['w']
                return jax.shard_map(inner, mesh=mesh, in_specs=P(),
                                     out_specs=(P(), P()), axis_names={'pod'},
                                     check_vma=False)(err_w)

            err = jnp.zeros(64, jnp.float32)
            true_mean = g_pods.mean(axis=0)
            acc_red = np.zeros(64)
            scale = np.abs(g_pods).max() / 127.0
            for it in range(4):
                red, err = jax.jit(body)(err)
                red = np.asarray(red)
                acc_red += red
                # single-step error bounded by int8 quantisation
                assert np.abs(red - true_mean).max() <= scale * 1.01, it
            # error feedback: accumulated mean converges tighter than 1 step
            drift = np.abs(acc_red / 4 - true_mean).max()
            assert drift <= scale * 0.6, drift
            print('COMPRESSION OK', drift)
            """
        )
        assert "COMPRESSION OK" in out

    def test_compressed_train_step_lowers(self):
        """make_train_step(compress_pods=True) lowers+compiles on a pod mesh
        and the HLO pod-axis payload is int8 (the compression is real)."""
        out = run_with_devices(
            """
            import jax, jax.numpy as jnp
            from jax.sharding import AxisType
            from repro import configs
            from repro.models import lm
            from repro.optim import AdamWConfig, adamw_init
            from repro.training.step import TrainStepConfig, make_train_step

            mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'),
                                 axis_types=(AxisType.Auto,)*3)
            cfg = configs.get_smoke_config('granite3_8b')
            with jax.set_mesh(mesh):
                vals, axes = lm.init_lm_values(jax.random.PRNGKey(0), cfg)
                opt = adamw_init(vals)
                err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), vals)
                step = make_train_step(cfg, axes, AdamWConfig(),
                                       step_cfg=TrainStepConfig(n_micro=2, compress_pods=True),
                                       mesh=mesh)
                toks = jnp.zeros((8, 16), jnp.int32)
                batch = {'tokens': toks, 'labels': toks}
                lowered = jax.jit(step).lower(vals, opt, batch, err)
                compiled = lowered.compile()
                hlo = compiled.as_text()
                assert 'all-reduce' in hlo
                assert 's8[' in hlo or 's32[' in hlo  # quantised payload present
                # run it for real: loss finite
                v2, o2, m, e2 = jax.jit(step)(vals, opt, batch, err)
                assert bool(jnp.isfinite(m['loss']))
                print('COMPRESSED STEP OK', float(m['loss']))
            """
        )
        assert "COMPRESSED STEP OK" in out


class TestShardedTrainingParity:
    def test_mesh_vs_single_device_loss(self):
        """The same train step on a (2,2) mesh and on 1 device gives the
        same loss (distribution must not change numerics materially)."""
        out = run_with_devices(
            """
            import jax, jax.numpy as jnp
            from jax.sharding import AxisType
            from repro import configs
            from repro.models import lm

            cfg = configs.get_smoke_config('phi35_moe_42b')
            vals, axes = lm.init_lm_values(jax.random.PRNGKey(0), cfg)
            toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
            batch = {'tokens': toks, 'labels': toks}
            l_single, _ = jax.jit(lambda v, b: lm.train_loss(v, cfg, b))(vals, batch)

            mesh = jax.make_mesh((2, 4), ('data', 'model'), axis_types=(AxisType.Auto,)*2)
            with jax.set_mesh(mesh):
                l_mesh, _ = jax.jit(lambda v, b: lm.train_loss(v, cfg, b))(vals, batch)
            import numpy as np
            np.testing.assert_allclose(float(l_single), float(l_mesh), rtol=2e-5)
            print('PARITY OK', float(l_single), float(l_mesh))
            """
        )
        assert "PARITY OK" in out

    def test_decode_parity_seq_sharded_cache(self):
        """Decode with a seq-sharded KV cache matches single-device decode."""
        out = run_with_devices(
            """
            import dataclasses
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import AxisType
            from repro import configs
            from repro.models import lm
            from repro.distributed.sharding import rules_for_config, use_rules

            cfg = configs.get_smoke_config('granite_34b')
            cfg = dataclasses.replace(
                cfg, sharding_overrides=(('cache_seq', ('data', 'model')),))
            vals, _ = lm.init_lm_values(jax.random.PRNGKey(0), cfg)
            toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)

            def roll(vals, toks):
                cache = lm.init_cache(cfg, 2, 16)
                logits, cache = lm.prefill(vals, cfg, {'tokens': toks}, cache)
                nxt = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
                logits2, cache = lm.decode_step(vals, cfg, nxt, cache)
                return logits, logits2

            l1, l2 = jax.jit(roll)(vals, toks)
            mesh = jax.make_mesh((2, 4), ('data', 'model'), axis_types=(AxisType.Auto,)*2)
            with jax.set_mesh(mesh), use_rules(rules_for_config(cfg)):
                m1, m2 = jax.jit(roll)(vals, toks)
            np.testing.assert_allclose(np.asarray(l1), np.asarray(m1), atol=3e-4)
            np.testing.assert_allclose(np.asarray(l2), np.asarray(m2), atol=3e-4)
            print('DECODE PARITY OK')
            """
        )
        assert "DECODE PARITY OK" in out


class TestHLOParser:
    def test_collective_bytes_detects_psum(self):
        out = run_with_devices(
            """
            import jax, jax.numpy as jnp, json
            from jax.sharding import PartitionSpec as P, NamedSharding, AxisType
            from repro.distributed.hlo_analysis import collective_bytes
            mesh = jax.make_mesh((8,), ('x',), axis_types=(AxisType.Auto,))
            def f(a, b):
                return jnp.einsum('ij,jk->ik', a, b)
            with jax.set_mesh(mesh):
                sa = NamedSharding(mesh, P(None, 'x'))
                sb = NamedSharding(mesh, P('x', None))
                low = jax.jit(f, in_shardings=(sa, sb),
                              out_shardings=NamedSharding(mesh, P())).lower(
                    jax.ShapeDtypeStruct((64, 64), jnp.float32),
                    jax.ShapeDtypeStruct((64, 64), jnp.float32))
                hlo = low.compile().as_text()
            stats = collective_bytes(hlo)
            # contracting a sharded axis with replicated output => all-reduce
            # of the (64,64) f32 partials = 16384 bytes
            assert stats.get('all-reduce', 0) >= 16384, stats
            print('PARSER OK', json.dumps(stats))
            """
        )
        assert "PARSER OK" in out
