"""Real N-device meshes (launch/mesh.py): 4-device sharded ==
unsharded bit-parity, sharded resume, and the streaming-diagnostic
shard merge.

Device-count tests run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the CI
multidevice job sets the same env process-wide); the main pytest
process keeps 1 CPU device.  The child env must SET
``JAX_PLATFORMS=cpu`` explicitly — unsetting it makes jax probe for
accelerator plugins, which stalls for minutes on CI containers.
"""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.diagnostics import StreamingChainStats
from repro.launch.mesh import make_chains_mesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_forced(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


class TestMeshBuilder:
    def test_single_device_returns_none(self):
        # the main pytest process has 1 CPU device: no mesh to build
        if jax.device_count() == 1:
            assert make_chains_mesh(4) is None
        assert make_chains_mesh(1) is None

    def test_four_device_mesh_spans_devices(self):
        out = _run_forced("""
        import jax
        from repro.launch.mesh import make_chains_mesh

        assert jax.device_count() == 4, jax.devices()
        mesh = make_chains_mesh(4)
        assert mesh is not None
        assert mesh.axis_names == ("data",)
        assert mesh.devices.size == 4
        print("MESH-OK")
        """)
        assert "MESH-OK" in out


class TestShardedParity:
    def test_sharded_equals_unsharded_four_devices(self):
        """RunPlan(mesh=...) on 4 forced host devices reproduces the
        unsharded stream bit-for-bit, mh and gibbs."""
        out = _run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import samplers
        from repro.launch.mesh import make_chains_mesh
        from repro.workloads.ising import IsingModel

        assert jax.device_count() == 4, jax.devices()
        mesh = make_chains_mesh(4)
        key = jax.random.PRNGKey(7)

        table = jax.random.normal(jax.random.PRNGKey(0), (2, 64), jnp.float32)
        target = samplers.TableTarget(table)
        init = jnp.broadcast_to(
            jnp.argmax(table, -1).astype(jnp.uint32)[:, None], (2, 8)
        )
        cinit = jnp.broadcast_to(init, (4, *init.shape))
        eng = samplers.MHEngine(samplers.EngineConfig(
            num_chains=4, execution="scan", chunk_steps=8))
        plan = samplers.RunPlan(
            target=target, n_steps=16, init_words=cinit, key=key)
        a = eng.submit(plan.replace(mesh=mesh)).result
        b = eng.submit(plan).result
        np.testing.assert_array_equal(
            np.asarray(a.samples), np.asarray(b.samples))
        np.testing.assert_array_equal(
            np.asarray(a.accept_count), np.asarray(b.accept_count))

        model = IsingModel(height=6, width=6)
        ginit = model.random_init(jax.random.PRNGKey(1), 2)
        gcinit = jnp.broadcast_to(ginit, (4, *ginit.shape))
        geng = samplers.MHEngine(samplers.EngineConfig(
            update="gibbs", num_chains=4, chunk_steps=8))
        gplan = samplers.RunPlan(
            target=model, n_steps=12, init_words=gcinit, key=key)
        a = geng.submit(gplan.replace(mesh=mesh)).result
        b = geng.submit(gplan).result
        np.testing.assert_array_equal(
            np.asarray(a.samples), np.asarray(b.samples))
        print("SHARDED-4-OK")
        """)
        assert "SHARDED-4-OK" in out

    def test_sharded_resume_bit_exact(self):
        """A checkpointed run killed mid-flight resumes bit-exactly on a
        4-device mesh (and matches the unsharded unsegmented run)."""
        out = _run_forced("""
        import tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro import samplers
        from repro.checkpoint import run_resumable
        from repro.launch.mesh import make_chains_mesh

        assert jax.device_count() == 4, jax.devices()
        mesh = make_chains_mesh(4)
        key = jax.random.PRNGKey(3)
        table = jax.random.normal(jax.random.PRNGKey(0), (2, 64), jnp.float32)
        target = samplers.TableTarget(table)
        init = jnp.broadcast_to(
            jnp.argmax(table, -1).astype(jnp.uint32)[:, None], (2, 8)
        )
        cinit = jnp.broadcast_to(init, (4, *init.shape))
        eng = samplers.MHEngine(samplers.EngineConfig(
            num_chains=4, execution="scan", chunk_steps=8))
        plan = samplers.RunPlan(
            target=target, n_steps=24, init_words=cinit, key=key, mesh=mesh)
        ref = eng.submit(plan.replace(mesh=None)).result

        with tempfile.TemporaryDirectory() as d:
            class Die(RuntimeError):
                pass

            def die(done, total, handle):
                if done >= 8:
                    raise Die

            try:
                run_resumable(eng, plan, directory=d, every=8, on_segment=die)
                raise AssertionError("expected the preemption")
            except Die:
                pass
            handle = run_resumable(eng, plan, directory=d, every=8)
        np.testing.assert_array_equal(
            np.asarray(handle.samples), np.asarray(ref.samples))
        np.testing.assert_array_equal(
            np.asarray(handle.final_words), np.asarray(ref.final_words))
        np.testing.assert_array_equal(
            np.asarray(handle.acceptance_rate),
            np.asarray(ref.acceptance_rate))
        print("RESUME-4-OK")
        """)
        assert "RESUME-4-OK" in out


class TestShardedServing:
    def test_slot_sharded_serving_equals_unsharded(self):
        """Scheduler(mesh=...) on 4 forced host devices: a mixed
        ising+gmm burst with slot-sharded class programs reproduces the
        unsharded burst bit-for-bit (slots never communicate, so the
        shard_map wrap is collective-free)."""
        out = _run_forced("""
        import jax, numpy as np
        from repro.launch.mesh import make_chains_mesh
        from repro.serving import Scheduler, ServeRequest

        assert jax.device_count() == 4, jax.devices()
        mesh = make_chains_mesh(4)
        assert mesh is not None

        def reqs():
            return [
                ServeRequest(rid=0, workload="gmm", n_steps=16, seed=1,
                             collect="all"),
                ServeRequest(rid=1, workload="ising", n_steps=12, seed=2,
                             collect="all"),
                ServeRequest(rid=2, workload="gmm", n_steps=24, seed=3,
                             collect="last"),
                ServeRequest(rid=3, workload="ising", n_steps=8, seed=4,
                             collect="last"),
            ]

        done_m = Scheduler(
            n_slots=4, smoke=True, chunk_steps=8, mesh=mesh
        ).serve(reqs())
        done_u = Scheduler(
            n_slots=4, smoke=True, chunk_steps=8
        ).serve(reqs())
        bm = {r.rid: r for r in done_m}
        bu = {r.rid: r for r in done_u}
        for rid in range(4):
            np.testing.assert_array_equal(
                bm[rid].samples, bu[rid].samples)
            np.testing.assert_array_equal(
                bm[rid].final_words, bu[rid].final_words)
            np.testing.assert_array_equal(
                bm[rid].accept_count, bu[rid].accept_count)
        print("SERVE-SHARD-OK")
        """)
        assert "SERVE-SHARD-OK" in out


class TestStreamingMerge:
    def _feed(self, stats, block, chunk=16):
        for s in range(0, block.shape[0], chunk):
            stats.update(block[s : s + chunk])

    def test_merge_equals_joint_accumulator(self):
        """Per-shard accumulators merged across the chain axis must equal
        one accumulator fed the full (T, C) block — exact, because chains
        never communicate."""
        rng = np.random.default_rng(0)
        block = rng.normal(size=(96, 6)).astype(np.float64)
        joint = StreamingChainStats(num_chains=6, total_steps=96)
        self._feed(joint, block)
        shards = []
        for lo, hi in ((0, 2), (2, 4), (4, 6)):
            s = StreamingChainStats(num_chains=hi - lo, total_steps=96)
            self._feed(s, block[:, lo:hi])
            shards.append(s)
        merged = StreamingChainStats.merge_shards(shards)
        a, b = merged.summarize(), joint.summarize()
        assert a.keys() == b.keys()
        for k in a:
            np.testing.assert_allclose(a[k], b[k], rtol=0, atol=0)

    def test_merge_refuses_mismatched_shapes(self):
        a = StreamingChainStats(num_chains=2, total_steps=64)
        b = StreamingChainStats(num_chains=2, total_steps=32)
        with pytest.raises(ValueError):
            a.merge(b)
