"""Serving-tier correctness (DESIGN.md §Serving).

The load-bearing claim: packing requests into the executor's slot axis
never changes any request's numbers.  A request admitted mid-flight,
sharing the batch with strangers, retiring early, or reusing a slot must
reproduce its solo ``engine.run`` stream bit-for-bit — the ``step0``
resume axis plus per-request keys make the slot pool invisible.  Also
covers per-request collect inheritance, the FIFO overflow queue, and the
first smoke coverage of the legacy ``launch.serve.BatchedServer``
(heterogeneous prompt lengths over the per-row decode index).
"""

import jax
import numpy as np
import pytest

from repro import configs, workloads
from repro.launch import serve as serve_mod
from repro.serving import (
    FIFOQueue,
    PackedExecutor,
    Scheduler,
    ServeRequest,
    latency_summary,
)


def solo_run(workload, seed, n_steps, collect, *, randomness="cim",
             execution="scan"):
    """The solo reference a packed request must reproduce bit-for-bit:
    exactly the launch.sample derivation (PRNGKey(seed) -> split ->
    builder init from k_init, chain stream from k_run)."""
    key = jax.random.PRNGKey(seed)
    k_init, k_run = jax.random.split(key)
    wl = workloads.build(
        workload, k_init, randomness=randomness, backend=execution, smoke=True
    )
    return wl.engine.run(k_run, wl.target, n_steps, wl.init_words,
                         collect=collect)


def make_executor(workload="gmm", n_slots=2, chunk_steps=8, *,
                  randomness="cim", execution="scan"):
    return PackedExecutor.for_workload(
        workload, n_slots=n_slots, randomness=randomness,
        execution=execution, smoke=True, chunk_steps=chunk_steps,
    )


def run_to_completion(ex):
    done = []
    while ex.active_count:
        done.extend(ex.advance_chunk())
    ex.drain()
    return done


def assert_matches_solo(req, ref):
    np.testing.assert_array_equal(req.samples, np.asarray(ref.samples))
    np.testing.assert_array_equal(
        req.final_words, np.asarray(ref.final_words)
    )
    np.testing.assert_array_equal(
        req.accept_count, np.asarray(ref.accept_count)
    )
    assert req.acceptance_rate == pytest.approx(
        float(ref.acceptance_rate), abs=1e-6
    )


class TestMidFlightJoinLeave:
    def test_join_mid_flight_is_bit_exact(self):
        """A request admitted while another is 16 steps in must stream
        exactly as if it ran alone (the step0 packing invariant)."""
        ex = make_executor(n_slots=2, chunk_steps=8)
        a = ServeRequest(rid=0, workload="gmm", n_steps=40, seed=1,
                         collect="all")
        ex.admit(a)
        for _ in range(2):
            ex.advance_chunk()
        b = ServeRequest(rid=1, workload="gmm", n_steps=16, seed=2,
                         collect="all")
        ex.admit(b)
        done = run_to_completion(ex)
        assert {r.rid for r in done} == {0, 1}
        assert_matches_solo(a, solo_run("gmm", 1, 40, "all"))
        assert_matches_solo(b, solo_run("gmm", 2, 16, "all"))

    def test_leave_does_not_perturb_survivor(self):
        """An early retirement (and the freed slot running dead work)
        must not touch the surviving request's stream."""
        ex = make_executor(n_slots=2, chunk_steps=8)
        a = ServeRequest(rid=0, workload="gmm", n_steps=48, seed=3,
                         collect="all")
        b = ServeRequest(rid=1, workload="gmm", n_steps=8, seed=4,
                         collect="last")
        ex.admit(a)
        ex.admit(b)
        run_to_completion(ex)
        assert_matches_solo(a, solo_run("gmm", 3, 48, "all"))
        assert_matches_solo(b, solo_run("gmm", 4, 8, "last"))

    def test_gibbs_mid_flight_join(self):
        """Same invariant under the gibbs update (checkerboard parity
        rides the absolute step, so a mid-flight join must resume the
        right colour)."""
        ex = make_executor("ising", n_slots=2, chunk_steps=4)
        a = ServeRequest(rid=0, workload="ising", n_steps=20, seed=5,
                         collect="all")
        ex.admit(a)
        ex.advance_chunk()  # A at step 4 (odd parity next) when B joins
        b = ServeRequest(rid=1, workload="ising", n_steps=12, seed=6,
                         collect="all")
        ex.admit(b)
        done = run_to_completion(ex)
        assert {r.rid for r in done} == {0, 1}
        assert_matches_solo(a, solo_run("ising", 5, 20, "all"))
        assert_matches_solo(b, solo_run("ising", 6, 12, "all"))
        assert a.rate_label == "flip_rate"


class TestSlotReuse:
    def test_retire_and_replace_is_bit_exact(self):
        """Three requests through one slot: the slot's history must be
        invisible (streams are keyed by request, not slot)."""
        ex = make_executor(n_slots=1, chunk_steps=8)
        reqs = []
        for seed in (1, 2, 3):
            r = ServeRequest(rid=seed, workload="gmm", n_steps=24,
                             seed=seed, collect="all")
            assert ex.admit(r) == 0
            run_to_completion(ex)
            reqs.append(r)
        for r in reqs:
            assert_matches_solo(r, solo_run("gmm", r.seed, 24, "all"))


class TestCollectInheritance:
    def test_per_request_collect_modes(self):
        """all / thin:k / last coexist in one packed batch, each bit-
        identical to its solo run; thin is the strided slice of the
        request's own "all" stream."""
        ex = make_executor(n_slots=3, chunk_steps=8)
        ra = ServeRequest(rid=0, workload="gmm", n_steps=32, seed=7,
                          collect="all")
        rt = ServeRequest(rid=1, workload="gmm", n_steps=32, seed=7,
                          collect="thin:8")
        rl = ServeRequest(rid=2, workload="gmm", n_steps=32, seed=7,
                          collect="last")
        for r in (ra, rt, rl):
            ex.admit(r)
        run_to_completion(ex)
        assert_matches_solo(ra, solo_run("gmm", 7, 32, "all"))
        assert_matches_solo(rt, solo_run("gmm", 7, 32, "thin:8"))
        assert_matches_solo(rl, solo_run("gmm", 7, 32, "last"))
        # thin == strided slice of the all stream (same seed)
        np.testing.assert_array_equal(rt.samples, ra.samples[::8])
        assert rl.samples.shape[0] == 0
        np.testing.assert_array_equal(rl.final_words, ra.final_words)


class TestPallasServing:
    def test_pallas_slots_match_solo(self):
        """The pallas path (one batched kernel grid over all slots,
        per-slot operand step0) honours the same packing invariant
        (interpret mode on CPU)."""
        ex = make_executor("gmm", n_slots=2, chunk_steps=8,
                           execution="pallas")
        a = ServeRequest(rid=0, workload="gmm", n_steps=16, seed=1,
                         collect="all")
        b = ServeRequest(rid=1, workload="gmm", n_steps=8, seed=2,
                         collect="last")
        ex.admit(a)
        ex.admit(b)
        run_to_completion(ex)
        assert_matches_solo(
            a, solo_run("gmm", 1, 16, "all", execution="pallas")
        )
        assert_matches_solo(
            b, solo_run("gmm", 2, 8, "last", execution="pallas")
        )


class TestShapeClassPacking:
    """Scan execution packs heterogeneous workloads into ONE executor
    (one compiled class program with a per-slot ``lax.switch``)."""

    def test_mixed_burst_shares_one_class_program(self):
        sched = Scheduler(n_slots=4, smoke=True, chunk_steps=8)
        reqs = [
            ServeRequest(rid=0, workload="gmm", n_steps=16, seed=1,
                         collect="all"),
            ServeRequest(rid=1, workload="ising", n_steps=12, seed=2,
                         collect="all"),
            ServeRequest(rid=2, workload="gmm", n_steps=24, seed=3,
                         collect="last"),
            ServeRequest(rid=3, workload="ising", n_steps=8, seed=4,
                         collect="last"),
        ]
        done = sched.serve(reqs)
        assert len(done) == 4
        # one shape class: gmm and ising share one packed program
        assert sched.shape_classes == 1
        assert len(sched.executors) == 1
        by_rid = {r.rid: r for r in done}
        assert_matches_solo(by_rid[0], solo_run("gmm", 1, 16, "all"))
        assert_matches_solo(by_rid[1], solo_run("ising", 2, 12, "all"))
        assert_matches_solo(by_rid[2], solo_run("gmm", 3, 24, "last"))
        assert_matches_solo(by_rid[3], solo_run("ising", 4, 8, "last"))
        assert by_rid[1].rate_label == "flip_rate"
        assert by_rid[0].rate_label == "acceptance_rate"

    def test_mixed_mid_flight_join_is_bit_exact(self):
        """An ising request joining a class program mid-flight (while a
        gmm request is 16 steps in) must stream exactly its solo run —
        the switch member table extends without touching live slots."""
        ex = make_executor("gmm", n_slots=2, chunk_steps=8)
        ex.add_workload("ising", randomness="cim", execution="scan",
                        smoke=True)
        a = ServeRequest(rid=0, workload="gmm", n_steps=40, seed=1,
                         collect="all")
        ex.admit(a)
        for _ in range(2):
            ex.advance_chunk()
        b = ServeRequest(rid=1, workload="ising", n_steps=16, seed=2,
                         collect="all")
        ex.admit(b)
        done = run_to_completion(ex)
        assert {r.rid for r in done} == {0, 1}
        assert_matches_solo(a, solo_run("gmm", 1, 40, "all"))
        assert_matches_solo(b, solo_run("ising", 2, 16, "all"))

    def test_add_member_while_live_grows_pad(self):
        """Registering a wider member mid-run re-pads the flat slot pool
        in place without perturbing the narrower live request."""
        ex = make_executor("gmm", n_slots=2, chunk_steps=8)
        a = ServeRequest(rid=0, workload="gmm", n_steps=24, seed=5,
                         collect="all")
        ex.admit(a)
        ex.advance_chunk()
        pad_before = ex.n_pad
        ex.add_workload("ising", randomness="cim", execution="scan",
                        smoke=True)
        assert ex.n_pad >= pad_before
        run_to_completion(ex)
        assert_matches_solo(a, solo_run("gmm", 5, 24, "all"))


class TestPackedPallas:
    """Pallas execution: every slot folds into ONE batched fused-kernel
    grid (no per-slot fallback) with per-slot operand step0."""

    @pytest.mark.parametrize("randomness", ["host", "fused"])
    def test_gmm_mid_flight_join_matches_solo(self, randomness):
        ex = make_executor("gmm", n_slots=2, chunk_steps=8,
                           randomness=randomness, execution="pallas")
        a = ServeRequest(rid=0, workload="gmm", n_steps=32, seed=1,
                         collect="all")
        ex.admit(a)
        ex.advance_chunk()
        b = ServeRequest(rid=1, workload="gmm", n_steps=16, seed=2,
                         collect="all")
        ex.admit(b)
        done = run_to_completion(ex)
        assert {r.rid for r in done} == {0, 1}
        assert_matches_solo(a, solo_run(
            "gmm", 1, 32, "all", randomness=randomness, execution="pallas"
        ))
        assert_matches_solo(b, solo_run(
            "gmm", 2, 16, "all", randomness=randomness, execution="pallas"
        ))

    @pytest.mark.parametrize("randomness", ["cim", "fused"])
    def test_ising_mid_flight_join_matches_solo(self, randomness):
        """Gibbs slots fold into the lattice-batch axis; a mid-flight
        join must resume on the right checkerboard colour (the operand
        step0 carries parity into the packed kernel)."""
        ex = make_executor("ising", n_slots=2, chunk_steps=4,
                           randomness=randomness, execution="pallas")
        a = ServeRequest(rid=0, workload="ising", n_steps=20, seed=3,
                         collect="all")
        ex.admit(a)
        ex.advance_chunk()
        b = ServeRequest(rid=1, workload="ising", n_steps=12, seed=4,
                         collect="all")
        ex.admit(b)
        done = run_to_completion(ex)
        assert {r.rid for r in done} == {0, 1}
        assert_matches_solo(a, solo_run(
            "ising", 3, 20, "all", randomness=randomness,
            execution="pallas",
        ))
        assert_matches_solo(b, solo_run(
            "ising", 4, 12, "all", randomness=randomness,
            execution="pallas",
        ))

    def test_mixed_pallas_burst_one_program_per_workload(self):
        """A mixed ising+gmm pallas burst runs one packed program per
        workload geometry (two shape classes — never one per slot)."""
        sched = Scheduler(n_slots=2, randomness="fused",
                          execution="pallas", smoke=True, chunk_steps=8)
        reqs = [
            ServeRequest(rid=0, workload="gmm", n_steps=16, seed=1,
                         collect="all"),
            ServeRequest(rid=1, workload="ising", n_steps=12, seed=2,
                         collect="all"),
            ServeRequest(rid=2, workload="gmm", n_steps=8, seed=3,
                         collect="last"),
        ]
        done = sched.serve(reqs)
        assert len(done) == 3
        assert sched.shape_classes == 2   # one per kernel geometry
        by_rid = {r.rid: r for r in done}
        assert_matches_solo(by_rid[0], solo_run(
            "gmm", 1, 16, "all", randomness="fused", execution="pallas"
        ))
        assert_matches_solo(by_rid[1], solo_run(
            "ising", 2, 12, "all", randomness="fused", execution="pallas"
        ))
        assert_matches_solo(by_rid[2], solo_run(
            "gmm", 3, 8, "last", randomness="fused", execution="pallas"
        ))

    def test_packed_pallas_matches_packed_scan(self):
        """The same burst through packed pallas and packed scan yields
        identical streams (the engine's cross-execution bit-parity
        survives packing)."""
        reqs = lambda: [
            ServeRequest(rid=0, workload="gmm", n_steps=16, seed=7,
                         collect="all"),
            ServeRequest(rid=1, workload="ising", n_steps=12, seed=8,
                         collect="all"),
        ]
        done_p = Scheduler(
            n_slots=2, randomness="fused", execution="pallas",
            smoke=True, chunk_steps=8,
        ).serve(reqs())
        done_s = Scheduler(
            n_slots=2, randomness="fused", execution="scan",
            smoke=True, chunk_steps=8,
        ).serve(reqs())
        bp = {r.rid: r for r in done_p}
        bs = {r.rid: r for r in done_s}
        for rid in (0, 1):
            np.testing.assert_array_equal(bp[rid].samples, bs[rid].samples)
            np.testing.assert_array_equal(
                bp[rid].final_words, bs[rid].final_words
            )


class TestDonationGuard:
    def test_stale_carry_read_raises(self):
        """The donation contract is enforced: a reference to the slot
        carry taken before an advance is poisoned by the dispatch and
        raises on read instead of silently showing donated memory."""
        ex = make_executor("gmm", n_slots=2, chunk_steps=8)
        r = ServeRequest(rid=0, workload="gmm", n_steps=16, seed=1,
                         collect="last")
        ex.admit(r)
        stale = ex.words
        ex.advance_chunk()
        assert stale.is_deleted()
        with pytest.raises(RuntimeError):
            np.asarray(stale)
        run_to_completion(ex)
        # the request itself is untouched by the poisoning
        ref = solo_run("gmm", 1, 16, "last")
        np.testing.assert_array_equal(
            r.final_words, np.asarray(ref.final_words)
        )

    def test_pallas_carry_poisoned_too(self):
        ex = make_executor("gmm", n_slots=1, chunk_steps=8,
                           execution="pallas")
        r = ServeRequest(rid=0, workload="gmm", n_steps=16, seed=2,
                         collect="last")
        ex.admit(r)
        stale = ex.words
        ex.advance_chunk()
        assert stale.is_deleted()
        run_to_completion(ex)


class TestMeshServingSmoke:
    def test_one_device_mesh_matches_unsharded(self):
        """Scheduler(mesh=...) routes the class program through
        shard_map over the slot axis; on a 1-device mesh the wrapped
        program must be bit-identical (the 4-device case lives in
        test_multidevice.py)."""
        mesh = jax.sharding.Mesh(
            np.asarray(jax.devices()[:1]), ("data",)
        )
        reqs = lambda: [
            ServeRequest(rid=0, workload="gmm", n_steps=16, seed=1,
                         collect="all"),
            ServeRequest(rid=1, workload="ising", n_steps=12, seed=2,
                         collect="all"),
        ]
        done_m = Scheduler(
            n_slots=2, smoke=True, chunk_steps=8, mesh=mesh
        ).serve(reqs())
        done_u = Scheduler(
            n_slots=2, smoke=True, chunk_steps=8
        ).serve(reqs())
        bm = {r.rid: r for r in done_m}
        bu = {r.rid: r for r in done_u}
        for rid in (0, 1):
            np.testing.assert_array_equal(bm[rid].samples, bu[rid].samples)
            np.testing.assert_array_equal(
                bm[rid].final_words, bu[rid].final_words
            )

    def test_mesh_rejects_pallas(self):
        mesh = jax.sharding.Mesh(
            np.asarray(jax.devices()[:1]), ("data",)
        )
        with pytest.raises(ValueError, match="mesh"):
            PackedExecutor.for_workload(
                "gmm", n_slots=2, execution="pallas", smoke=True,
                mesh=mesh,
            )


class TestFIFOQueue:
    def test_order_and_arrival_gating(self):
        q = FIFOQueue()
        q.push("a", 0.0)
        q.push("b", 1.0)
        assert q.pop_ready(0.5) == "a"
        assert q.pop_ready(0.5) is None  # b hasn't arrived yet
        assert q.next_arrival() == 1.0
        assert q.pop_ready(2.0) == "b"
        assert not q and q.next_arrival() is None

    def test_push_front_keeps_turn(self):
        q = FIFOQueue()
        q.push("a")
        q.push("b")
        head = q.pop_ready()
        q.push_front(head)  # could not be placed: keeps its turn
        assert q.pop_ready() == "a"
        assert q.pop_ready() == "b"


class TestScheduler:
    def test_overflow_queue_is_fifo_and_bit_exact(self):
        sched = Scheduler(n_slots=1, smoke=True, chunk_steps=8)
        reqs = [
            ServeRequest(rid=i, workload="gmm", n_steps=16, seed=i,
                         collect="last")
            for i in range(3)
        ]
        done = sched.serve(reqs)
        assert len(done) == 3
        by_admit = sorted(done, key=lambda r: r.t_admit)
        assert [r.rid for r in by_admit] == [0, 1, 2]
        for r in done:
            ref = solo_run("gmm", r.seed, 16, "last")
            np.testing.assert_array_equal(
                r.final_words, np.asarray(ref.final_words)
            )
        summary = latency_summary(done)
        assert summary["n_requests"] == 3
        assert summary["requests_per_s"] > 0
        assert summary["p99_latency_s"] >= summary["p50_latency_s"]

    def test_default_steps_and_validation(self):
        with pytest.raises(ValueError):
            ServeRequest(rid=0, collect="bogus")
        with pytest.raises(ValueError):
            ServeRequest(rid=0, n_steps=0)
        sched = Scheduler(n_slots=2, smoke=True, chunk_steps=8)
        r = ServeRequest(rid=0, workload="gmm", seed=1, collect="last")
        done = sched.serve([r])
        # n_steps=None -> the workload group's default budget
        default = workloads.build(
            "gmm", jax.random.PRNGKey(0), smoke=True
        ).n_steps
        ref = solo_run("gmm", 1, default, "last")
        np.testing.assert_array_equal(
            done[0].final_words, np.asarray(ref.final_words)
        )


class TestBatchedServerSmoke:
    """First coverage of the legacy KV-cache server — heterogeneous
    prompt lengths must decode exactly like solo runs (the per-row
    decode index satellite)."""

    GEN = 3

    def _server(self, n_slots):
        cfg = configs.get_smoke_config("granite3_8b")
        scfg = serve_mod.ServeConfig(
            n_slots=n_slots, max_len=24, gen_tokens=self.GEN,
            sampler="greedy", seed=0,
        )
        return cfg, serve_mod.BatchedServer(cfg, scfg)

    def _drive(self, server, submissions):
        for slot, req in submissions:
            server.submit(slot, req)
        finished = []
        while server.active():
            finished.extend(server.step())
        return {r.rid: r.out_tokens for r in finished}

    def test_heterogeneous_prompts_decode_like_solo(self):
        cfg, packed = self._server(2)
        rng = np.random.default_rng(0)
        p0 = rng.integers(0, cfg.vocab_size, size=5)
        p1 = rng.integers(0, cfg.vocab_size, size=9)
        out = self._drive(packed, [
            (0, serve_mod.Request(rid=0, prompt=p0)),
            (1, serve_mod.Request(rid=1, prompt=p1)),
        ])
        assert all(len(t) == 1 + self.GEN for t in out.values())
        for rid, prompt in ((0, p0), (1, p1)):
            _, solo = self._server(1)
            ref = self._drive(
                solo, [(0, serve_mod.Request(rid=rid, prompt=prompt))]
            )
            assert out[rid] == ref[rid], f"packed decode diverged rid={rid}"

    def test_retired_slot_is_refilled(self):
        cfg, server = self._server(1)
        rng = np.random.default_rng(1)
        first = serve_mod.Request(
            rid=0, prompt=rng.integers(0, cfg.vocab_size, size=4)
        )
        out = self._drive(server, [(0, first)])
        assert server.free_slot() == 0  # retirement freed the slot
        second = serve_mod.Request(
            rid=1, prompt=rng.integers(0, cfg.vocab_size, size=6)
        )
        out2 = self._drive(server, [(0, second)])
        assert len(out2[1]) == 1 + self.GEN
        assert out[0] is not out2[1]
