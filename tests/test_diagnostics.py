"""Chain diagnostics: the estimators must rank chains correctly.

Calibration contract (ISSUE 2): ESS ~ N on i.i.d. chains, ESS << N on a
near-constant chain, split-R-hat ~ 1 on stationary chains and > 1.1 on
divergent ones.  Everything here is numpy-only — no JAX.
"""

import numpy as np
import pytest

from repro import diagnostics


class TestAutocorrTime:
    def test_iid_tau_near_one(self):
        x = np.random.default_rng(0).normal(size=(4000, 4))
        tau = diagnostics.integrated_autocorr_time(x)
        assert 0.7 < tau < 1.6, tau

    def test_correlated_tau_grows(self):
        """AR(1) with rho=0.9 has tau = (1+rho)/(1-rho) = 19."""
        rng = np.random.default_rng(1)
        n, rho = 20000, 0.9
        x = np.zeros(n)
        eps = rng.normal(size=n)
        for t in range(1, n):
            x[t] = rho * x[t - 1] + eps[t]
        tau = diagnostics.integrated_autocorr_time(x)
        assert 10 < tau < 30, tau

    def test_clipped_to_chain_length(self):
        x = np.repeat([0.0, 1.0], 50)  # one slow switch
        tau = diagnostics.integrated_autocorr_time(x)
        assert 1.0 <= tau <= x.size


class TestESS:
    def test_iid_ess_near_n(self):
        x = np.random.default_rng(2).normal(size=(4000, 4))
        ess = diagnostics.effective_sample_size(x)
        n = x.size
        assert 0.6 * n < ess < 1.5 * n, ess

    def test_near_constant_ess_much_less_than_n(self):
        """A chain that moves every 200 steps has ~n/200-ish independent
        values; ESS must collapse far below N."""
        rng = np.random.default_rng(3)
        x = np.repeat(rng.normal(size=20), 200)  # 4000 steps, 20 moves
        ess = diagnostics.effective_sample_size(x)
        assert ess < 0.05 * x.size, ess

    def test_constant_chain_degenerate_but_finite(self):
        x = np.ones((100, 2))
        ess = diagnostics.effective_sample_size(x)
        assert np.isfinite(ess)
        assert ess <= x.shape[1]  # tau = n_steps => ESS = n_chains


class TestSplitRhat:
    def test_stationary_near_one(self):
        x = np.random.default_rng(4).normal(size=(2000, 4))
        r = diagnostics.split_rhat(x)
        assert 0.98 < r < 1.05, r

    def test_divergent_chains_flagged(self):
        rng = np.random.default_rng(5)
        a = rng.normal(size=(1000, 2))
        b = rng.normal(loc=3.0, size=(1000, 2))
        r = diagnostics.split_rhat(np.concatenate([a, b], axis=1))
        assert r > 1.1, r

    def test_within_chain_drift_flagged(self):
        """Splitting catches a trend a whole-chain R-hat would miss."""
        drift = np.linspace(0.0, 5.0, 2000)[:, None]
        x = np.random.default_rng(6).normal(size=(2000, 2)) * 0.1 + drift
        assert diagnostics.split_rhat(x) > 1.1

    def test_constant_chains_converged_by_convention(self):
        assert diagnostics.split_rhat(np.zeros((100, 3))) == 1.0


class TestStreaming:
    """StreamingChainStats must reproduce the batch estimators from
    chunked consumption (the §Chains-axis O(chunk)-memory contract)."""

    @staticmethod
    def _ar1(n=3000, chains=4, rho=0.8, seed=0):
        rng = np.random.default_rng(seed)
        x = np.zeros((n, chains))
        eps = rng.normal(size=(n, chains))
        for t in range(1, n):
            x[t] = rho * x[t - 1] + eps[t]
        return x

    def test_stream_equals_batch_summarize(self):
        """Ragged chunk boundaries, same rounded bundle as the batch
        path — tau, ESS, split-R-hat, mean, std, everything."""
        x = self._ar1()
        batch = diagnostics.summarize(x, acceptance_rate=0.4)
        acc = diagnostics.StreamingChainStats(4, x.shape[0], max_lag=400)
        for s in range(0, x.shape[0], 37):
            acc.update(x[s : s + 37])
        assert acc.summarize(acceptance_rate=0.4) == batch

    def test_chunk_size_invariance(self):
        x = self._ar1(n=500, chains=2, seed=1)
        outs = []
        for chunk in (1, 7, 100, 500):
            outs.append(
                diagnostics.summarize_stream(
                    (x[s : s + chunk] for s in range(0, 500, chunk)),
                    num_chains=2, total_steps=500, max_lag=200,
                )
            )
        assert all(o == outs[0] for o in outs)

    def test_memory_is_bounded_by_max_lag(self):
        """The accumulator's buffers never exceed O(chains * max_lag)
        regardless of stream length — the whole point of streaming."""
        acc = diagnostics.StreamingChainStats(2, 10_000, max_lag=32)
        x = self._ar1(n=10_000, chains=2, seed=2)
        for s in range(0, 10_000, 256):
            acc.update(x[s : s + 256])
        assert acc._tail.shape[0] <= 32
        assert acc._head.shape[0] <= 32
        assert acc._cross.shape == (33, 2)
        assert np.isfinite(acc.summarize()["tau"])

    def test_constant_chains_degenerate_but_defined(self):
        z = np.ones((100, 3))
        out = diagnostics.summarize_stream([z[:60], z[60:]], 3, 100)
        assert out["split_rhat"] == 1.0
        assert np.isfinite(out["tau"])

    def test_window_capped_flag(self):
        """A mixing time beyond max_lag is reported, not silently wrong."""
        x = np.repeat(np.random.default_rng(3).normal(size=50), 40)[:, None]
        out = diagnostics.summarize_stream([x], 1, x.shape[0], max_lag=8)
        assert out.get("window_capped") is True

    def test_stream_overflow_and_incomplete_rejected(self):
        acc = diagnostics.StreamingChainStats(1, 10)
        acc.update(np.zeros((6, 1)))
        with pytest.raises(ValueError, match="overflow"):
            acc.update(np.zeros((5, 1)))
        with pytest.raises(ValueError, match="incomplete"):
            acc.summarize()
        with pytest.raises(ValueError, match="chunk must be"):
            acc.update(np.zeros((2, 3)))


class TestSummarize:
    def test_bundle_keys_and_acceptance(self):
        x = np.random.default_rng(7).normal(size=(500, 3))
        d = diagnostics.summarize(x, acceptance_rate=0.37)
        for k in ("n_steps", "n_chains", "tau", "ess", "split_rhat"):
            assert k in d
        assert d["n_steps"] == 500 and d["n_chains"] == 3
        assert d["acceptance_rate"] == pytest.approx(0.37)

    def test_shape_validation(self):
        with pytest.raises(ValueError):
            diagnostics.summarize(np.zeros((2, 2, 2)))
        with pytest.raises(ValueError):
            diagnostics.integrated_autocorr_time(np.zeros((1,)))
