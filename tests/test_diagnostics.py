"""Chain diagnostics: the estimators must rank chains correctly.

Calibration contract (ISSUE 2): ESS ~ N on i.i.d. chains, ESS << N on a
near-constant chain, split-R-hat ~ 1 on stationary chains and > 1.1 on
divergent ones.  Everything here is numpy-only — no JAX.
"""

import numpy as np
import pytest

from repro import diagnostics


class TestAutocorrTime:
    def test_iid_tau_near_one(self):
        x = np.random.default_rng(0).normal(size=(4000, 4))
        tau = diagnostics.integrated_autocorr_time(x)
        assert 0.7 < tau < 1.6, tau

    def test_correlated_tau_grows(self):
        """AR(1) with rho=0.9 has tau = (1+rho)/(1-rho) = 19."""
        rng = np.random.default_rng(1)
        n, rho = 20000, 0.9
        x = np.zeros(n)
        eps = rng.normal(size=n)
        for t in range(1, n):
            x[t] = rho * x[t - 1] + eps[t]
        tau = diagnostics.integrated_autocorr_time(x)
        assert 10 < tau < 30, tau

    def test_clipped_to_chain_length(self):
        x = np.repeat([0.0, 1.0], 50)  # one slow switch
        tau = diagnostics.integrated_autocorr_time(x)
        assert 1.0 <= tau <= x.size


class TestESS:
    def test_iid_ess_near_n(self):
        x = np.random.default_rng(2).normal(size=(4000, 4))
        ess = diagnostics.effective_sample_size(x)
        n = x.size
        assert 0.6 * n < ess < 1.5 * n, ess

    def test_near_constant_ess_much_less_than_n(self):
        """A chain that moves every 200 steps has ~n/200-ish independent
        values; ESS must collapse far below N."""
        rng = np.random.default_rng(3)
        x = np.repeat(rng.normal(size=20), 200)  # 4000 steps, 20 moves
        ess = diagnostics.effective_sample_size(x)
        assert ess < 0.05 * x.size, ess

    def test_constant_chain_degenerate_but_finite(self):
        x = np.ones((100, 2))
        ess = diagnostics.effective_sample_size(x)
        assert np.isfinite(ess)
        assert ess <= x.shape[1]  # tau = n_steps => ESS = n_chains


class TestSplitRhat:
    def test_stationary_near_one(self):
        x = np.random.default_rng(4).normal(size=(2000, 4))
        r = diagnostics.split_rhat(x)
        assert 0.98 < r < 1.05, r

    def test_divergent_chains_flagged(self):
        rng = np.random.default_rng(5)
        a = rng.normal(size=(1000, 2))
        b = rng.normal(loc=3.0, size=(1000, 2))
        r = diagnostics.split_rhat(np.concatenate([a, b], axis=1))
        assert r > 1.1, r

    def test_within_chain_drift_flagged(self):
        """Splitting catches a trend a whole-chain R-hat would miss."""
        drift = np.linspace(0.0, 5.0, 2000)[:, None]
        x = np.random.default_rng(6).normal(size=(2000, 2)) * 0.1 + drift
        assert diagnostics.split_rhat(x) > 1.1

    def test_constant_chains_converged_by_convention(self):
        assert diagnostics.split_rhat(np.zeros((100, 3))) == 1.0


class TestSummarize:
    def test_bundle_keys_and_acceptance(self):
        x = np.random.default_rng(7).normal(size=(500, 3))
        d = diagnostics.summarize(x, acceptance_rate=0.37)
        for k in ("n_steps", "n_chains", "tau", "ess", "split_rhat"):
            assert k in d
        assert d["n_steps"] == 500 and d["n_chains"] == 3
        assert d["acceptance_rate"] == pytest.approx(0.37)

    def test_shape_validation(self):
        with pytest.raises(ValueError):
            diagnostics.summarize(np.zeros((2, 2, 2)))
        with pytest.raises(ValueError):
            diagnostics.integrated_autocorr_time(np.zeros((1,)))
