"""The run API (DESIGN.md §Run-API): RunPlan validation, submit parity,
handle resume, the deprecated shims' bit-compatibility, and the
autotuner's never-slower + cache contracts."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import samplers
from repro.workloads.ising import IsingModel

jax.config.update("jax_platform_name", "cpu")


def _mh_setup(b=2, v=64, c=8, seed=0):
    table = jax.random.normal(jax.random.PRNGKey(seed), (b, v), jnp.float32)
    target = samplers.TableTarget(table)
    init = jnp.broadcast_to(
        jnp.argmax(table, -1).astype(jnp.uint32)[:, None], (b, c)
    )
    return target, init


class TestRunPlanValidation:
    def test_key_xor_seed(self):
        target, init = _mh_setup()
        with pytest.raises(ValueError, match="exactly one of"):
            samplers.RunPlan(target=target, n_steps=4, init_words=init)
        with pytest.raises(ValueError, match="exactly one of"):
            samplers.RunPlan(
                target=target, n_steps=4, init_words=init,
                key=jax.random.PRNGKey(0), seed=1,
            )

    def test_init_words_required(self):
        target, _ = _mh_setup()
        with pytest.raises(ValueError, match="init_words is required"):
            samplers.RunPlan(
                target=target, n_steps=4, init_words=None, seed=0
            )

    def test_bad_n_steps_step0_collect(self):
        target, init = _mh_setup()
        with pytest.raises(ValueError, match="n_steps"):
            samplers.RunPlan(
                target=target, n_steps=0, init_words=init, seed=0
            )
        with pytest.raises(ValueError, match="step0"):
            samplers.RunPlan(
                target=target, n_steps=4, init_words=init, seed=0, step0=-1
            )
        with pytest.raises(ValueError):
            samplers.RunPlan(
                target=target, n_steps=4, init_words=init, seed=0,
                collect="thin:0",
            )

    def test_seed_resolves_to_prngkey(self):
        target, init = _mh_setup()
        plan = samplers.RunPlan(
            target=target, n_steps=4, init_words=init, seed=7
        )
        np.testing.assert_array_equal(
            np.asarray(plan.resolved_key()),
            np.asarray(jax.random.PRNGKey(7)),
        )

    def test_submit_rejects_non_plan(self):
        engine = samplers.MHEngine(samplers.EngineConfig())
        with pytest.raises(TypeError, match="RunPlan"):
            engine.submit({"n_steps": 4})


class TestSubmitParity:
    @pytest.mark.parametrize("compiled", [False, True])
    def test_submit_matches_engine_run(self, compiled):
        target, init = _mh_setup()
        engine = samplers.MHEngine(samplers.EngineConfig(chunk_steps=8))
        key = jax.random.PRNGKey(3)
        ref = engine.run(key, target, 24, init)
        handle = engine.submit(
            samplers.RunPlan(
                target=target, n_steps=24, init_words=init, key=key
            ),
            compiled=compiled,
        )
        np.testing.assert_array_equal(
            np.asarray(handle.samples), np.asarray(ref.samples)
        )
        np.testing.assert_array_equal(
            np.asarray(handle.accept_count), np.asarray(ref.accept_count)
        )
        np.testing.assert_array_equal(
            np.asarray(handle.final_words), np.asarray(ref.final_words)
        )

    def test_handle_resume_is_segment_invariant(self):
        target, init = _mh_setup()
        engine = samplers.MHEngine(samplers.EngineConfig(chunk_steps=8))
        key = jax.random.PRNGKey(5)
        mono = engine.run(key, target, 32, init)
        h1 = engine.submit(
            samplers.RunPlan(
                target=target, n_steps=12, init_words=init, key=key
            )
        )
        h2 = h1.resume(20)
        assert h1.progress == 12 and h2.progress == 32
        np.testing.assert_array_equal(
            np.concatenate(
                [np.asarray(h1.samples), np.asarray(h2.samples)], axis=0
            ),
            np.asarray(mono.samples),
        )
        np.testing.assert_array_equal(
            np.asarray(h1.accept_count) + np.asarray(h2.accept_count),
            np.asarray(mono.accept_count),
        )
        np.testing.assert_array_equal(
            np.asarray(h2.final_words), np.asarray(mono.final_words)
        )

    def test_gibbs_resume_segment_invariant(self):
        model = IsingModel(height=6, width=6)
        init = model.random_init(jax.random.PRNGKey(1), 2)
        engine = samplers.MHEngine(
            samplers.EngineConfig(update="gibbs", chunk_steps=8)
        )
        key = jax.random.PRNGKey(9)
        mono = engine.run(key, model, 20, init)
        h1 = engine.submit(
            samplers.RunPlan(target=model, n_steps=8, init_words=init, key=key)
        )
        h2 = h1.resume(12)
        np.testing.assert_array_equal(
            np.concatenate(
                [np.asarray(h1.samples), np.asarray(h2.samples)], axis=0
            ),
            np.asarray(mono.samples),
        )

    def test_traced_step0_goes_through_submit(self):
        """Plans with traced offsets stay traceable (the serving-tier
        pattern); compiled=True silently takes the direct path."""
        target, init = _mh_setup()
        engine = samplers.MHEngine(samplers.EngineConfig(chunk_steps=8))
        key = jax.random.PRNGKey(2)

        @jax.jit
        def seg(step0, words):
            res = engine.submit(
                samplers.RunPlan(
                    target=target, n_steps=8, init_words=words, key=key,
                    step0=step0,
                ),
                compiled=True,
            ).result
            return res.samples, res.final_words

        mono = engine.run(key, target, 16, init)
        s1, w1 = seg(jnp.int32(0), init)
        s2, _ = seg(jnp.int32(8), w1)
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(s1), np.asarray(s2)]),
            np.asarray(mono.samples),
        )

    def test_thin_traced_step0_error_names_fallback(self):
        """The thin + traced step0 error must spell out both escapes:
        concrete step0, or collect='all' + the host strided slice the
        serving tier uses."""
        target, init = _mh_setup()
        engine = samplers.MHEngine(
            samplers.EngineConfig(collect="thin:4", chunk_steps=8)
        )
        key = jax.random.PRNGKey(0)
        with pytest.raises(Exception) as e:

            @jax.jit
            def seg(step0):
                return engine.run(key, target, 8, init, step0=step0).samples

            seg(jnp.int32(8))
        msg = str(e.value)
        assert "concrete" in msg or "python int" in msg
        assert "samples[(-step0) % k :: k]" in msg
        assert "serving" in msg


class TestDeprecatedShims:
    def test_run_engine_warns_and_matches(self):
        target, init = _mh_setup()
        engine = samplers.MHEngine(samplers.EngineConfig(chunk_steps=8))
        key = jax.random.PRNGKey(4)
        ref = engine.run(key, target, 16, init)
        with pytest.warns(DeprecationWarning, match="RunPlan"):
            old = samplers.run_engine(
                key, init, engine=engine, target=target, n_steps=16
            )
        np.testing.assert_array_equal(
            np.asarray(old.samples), np.asarray(ref.samples)
        )

    def test_run_chain_warns_and_matches_impl(self):
        from repro.core import metropolis

        cfg = metropolis.MHConfig(nbits=4, burn_in=8, thin=2, chunk_steps=8)
        key = jax.random.PRNGKey(0)

        def logp(x):
            return -0.1 * (x.astype(jnp.float32) - 5.0) ** 2

        with pytest.warns(DeprecationWarning, match="RunPlan"):
            old = metropolis.run_chain(key, logp, cfg, 6, chain_shape=(4,))
        new = metropolis._run_chain_impl(key, logp, cfg, 6, chain_shape=(4,))
        np.testing.assert_array_equal(
            np.asarray(old.samples), np.asarray(new.samples)
        )

    def test_sample_tokens_warns_and_matches_impl(self):
        from repro.core import token_sampler

        cfg = token_sampler.TokenSamplerConfig(vocab_size=50, n_steps=16)
        key = jax.random.PRNGKey(0)
        logits = jax.random.normal(jax.random.PRNGKey(1), (3, 50))
        with pytest.warns(DeprecationWarning, match="sample_tokens"):
            old = token_sampler.sample_tokens(key, logits, cfg)
        new = token_sampler._sample_tokens_impl(key, logits, cfg)
        np.testing.assert_array_equal(
            np.asarray(old.tokens), np.asarray(new.tokens)
        )

    def test_documented_surface_exports(self):
        for name in (
            "RunPlan", "RunHandle", "submit", "TuneResult",
            "autotune_config", "autotune_engine", "run_engine",
        ):
            assert name in samplers.__all__, name

    def test_internal_callers_do_not_warn(self):
        """Production paths route around the shims — the warning belongs
        to external callers only."""
        from repro.core import macro

        m = macro.CIMMacro(
            macro.MacroConfig(nbits=4, n_compartments=8, burn_in=16)
        )
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            m.sample(
                jax.random.PRNGKey(0),
                lambda x: -0.05 * (x.astype(jnp.float32) - 3.0) ** 2,
                n_samples=8,
            )


class TestAutotune:
    def test_measured_then_cached_never_slower(self, tmp_path):
        target, init = _mh_setup(c=16)
        cfg = samplers.EngineConfig(chunk_steps=32, execution="scan")
        cache = str(tmp_path / "autotune.json")
        tuned_cfg, res = samplers.autotune_config(
            cfg, target, init, n_steps=32, repeats=1,
            chunk_candidates=(16, 64), cache_path=cache,
        )
        assert res.source == "measured"
        # the incumbent is candidate 0 and the winner is the argmax
        assert res.candidates[0][:3] == (32, cfg.block_c, "scan")
        assert res.steps_per_s >= res.baseline_steps_per_s
        assert tuned_cfg.chunk_steps == res.chunk_steps
        # second call hits the cache without measuring
        tuned2, res2 = samplers.autotune_config(
            cfg, target, init, n_steps=32, repeats=1,
            chunk_candidates=(16, 64), cache_path=cache,
        )
        assert res2.source == "cache"
        assert tuned2 == tuned_cfg

    def test_cache_key_separates_shapes(self, tmp_path):
        target, init = _mh_setup(c=8)
        cfg = samplers.EngineConfig()
        k1 = samplers.autotune.tune_key(cfg, target, init)
        k2 = samplers.autotune.tune_key(cfg, target, init[:, :4])
        assert k1 != k2

    def test_tuned_stream_is_unchanged(self, tmp_path):
        """chunk_steps/execution tuning must never change the sample
        stream (what makes tuning safe across resume boundaries)."""
        target, init = _mh_setup()
        key = jax.random.PRNGKey(11)
        base = samplers.MHEngine(
            samplers.EngineConfig(chunk_steps=32, execution="scan")
        )
        tuned_engine, _ = samplers.autotune_engine(
            base, target, init, n_steps=32, repeats=1,
            chunk_candidates=(8,), cache_path=str(tmp_path / "c.json"),
        )
        a = base.run(key, target, 24, init)
        b = tuned_engine.run(key, target, 24, init)
        np.testing.assert_array_equal(
            np.asarray(a.samples), np.asarray(b.samples)
        )


class TestWorkloadPlanSurface:
    def test_workload_run_goes_through_plan(self):
        from repro import workloads

        k_init, k_run = jax.random.split(jax.random.PRNGKey(0))
        wl = workloads.build("ising", k_init, smoke=True, backend="scan")
        plan = wl.plan(k_run)
        assert isinstance(plan, samplers.RunPlan)
        res = wl.run(k_run)
        ref = wl.engine.submit(plan).result
        np.testing.assert_array_equal(
            np.asarray(res.samples), np.asarray(ref.samples)
        )

    def test_rate_key_names(self):
        from repro import workloads

        k = jax.random.PRNGKey(0)
        assert (
            workloads.build("ising", k, smoke=True).rate_key == "flip_rate"
        )
        assert (
            workloads.build("gmm", k, smoke=True).rate_key
            == "acceptance_rate"
        )
