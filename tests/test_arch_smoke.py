"""Per-architecture smoke tests (assignment requirement).

Each assigned architecture instantiates a REDUCED same-family config and
runs one forward/train step + one prefill/decode step on CPU, asserting
output shapes and the absence of NaNs.  Full configs are exercised only by
the dry-run (ShapeDtypeStruct, no allocation) — also asserted here.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm

ARCHS = list(configs.ARCH_IDS)


def _batch(cfg, key, b=2, s=16):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.n_image_tokens, cfg.image_embed_dim)
        )
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(key, (b, cfg.encoder_len, cfg.frame_dim))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(hash(arch) % 2**31)
    vals, axes = lm.init_lm_values(key, cfg)
    batch = _batch(cfg, key)
    loss, metrics = lm.train_loss(vals, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    assert float(metrics["tokens"]) > 0
    # one gradient step must be finite too
    grads = jax.grad(lambda v: lm.train_loss(v, cfg, batch)[0])(vals)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf))), f"{arch} grad not finite"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(hash(arch) % 2**31 + 1)
    vals, _ = lm.init_lm_values(key, cfg)
    b, s = 2, 12
    batch = {k: v for k, v in _batch(cfg, key, b, s).items() if k != "labels"}
    cache = lm.init_cache(cfg, b, 24)
    logits, cache = lm.prefill(vals, cfg, batch, cache)
    assert logits.shape == (b, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[:, : cfg.vocab_size])))
    tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    logits2, cache = lm.decode_step(vals, cfg, tok, cache)
    assert logits2.shape == (b, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits2[:, : cfg.vocab_size])))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_abstract_params(arch):
    """Full configs must build allocation-free skeletons with sane counts."""
    cfg = configs.get_config(arch)
    shapes, axes_tree = lm.abstract_params(cfg)
    leaves = jax.tree.leaves(shapes)
    assert all(hasattr(l, "shape") for l in leaves)
    total = sum(int(np.prod(l.shape)) for l in leaves)
    # within 25% of the analytic count (analytic skips norms/bias/padding)
    assert total == pytest.approx(cfg.param_count(), rel=0.25), (
        f"{arch}: abstract {total / 1e9:.2f}B vs analytic "
        f"{cfg.param_count() / 1e9:.2f}B"
    )


def test_assigned_cells_cover_40():
    cells = configs.assigned_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    # long_500k runs only for the two sub-quadratic archs
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s, ok, _ in cells if not ok)
    assert {a for a, s, ok, _ in cells if s == "long_500k" and ok} == {
        "hymba_1p5b",
        "mamba2_1p3b",
    }


def test_param_counts_match_names():
    expect = {
        "hymba_1p5b": (1.2, 1.7),
        "phi3_vision_4p2b": (3.5, 4.3),
        "mamba2_1p3b": (1.1, 1.6),
        "phi3_medium_14b": (13.0, 15.0),
        "granite3_8b": (7.5, 9.0),
        "minitron_4b": (3.8, 5.5),
        "granite_34b": (32.0, 36.0),
        "whisper_large_v3": (1.4, 1.8),
        "phi35_moe_42b": (40.0, 44.0),
        "qwen3_moe_30b": (29.0, 32.0),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo}, {hi}]"


def test_moe_active_counts():
    assert configs.get_config("phi35_moe_42b").param_count(
        active_only=True
    ) / 1e9 == pytest.approx(6.6, abs=0.5)
    assert configs.get_config("qwen3_moe_30b").param_count(
        active_only=True
    ) / 1e9 == pytest.approx(3.3, abs=0.5)
