"""Collection axis (DESIGN.md §Collection): kept-set parity + operand-lean u.

The axis must never change the chain — only how much of it leaves the
engine:

  * ``thin:k`` == the strided slice ``all[(-step0) % k :: k]`` bit for
    bit, on every executor x update-rule x randomness combination,
  * ``last`` reproduces ``all``'s (final_words, final_logp,
    accept_count) exactly while emitting a (0, *chain) sample stream,
  * ``need_flips=False`` (the u-only operand path the Gibbs executors
    and the tempering swap test use) leaves the u stream bit-identical,
  * the kept set is defined on *absolute* steps, so thinning commutes
    with chunking and with ``step0`` segmentation (the tempering
    segment contract).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import samplers, workloads
from repro.workloads.ising import IsingModel


def _mh_case(chains=16, v=64):
    key = jax.random.PRNGKey(2)
    table = jax.random.normal(key, (2, v), jnp.float32)
    target = samplers.TableTarget(table)
    init = jnp.broadcast_to(
        jnp.argmax(table, -1).astype(jnp.uint32)[:, None], (2, chains)
    )
    return target, init


def _gibbs_case(batch=2):
    model = IsingModel(height=4, width=6)
    return model, model.random_init(jax.random.PRNGKey(3), batch)


def _engine(update, execution, randomness, **kw):
    return samplers.MHEngine(
        samplers.EngineConfig(
            update=update, execution=execution, randomness=randomness, **kw
        )
    )


def _case(update):
    return _mh_case() if update == "mh" else _gibbs_case()


class TestKeptSetParity:
    """thin == strided slice of all; last == all's final carry — across
    the full {scan, pallas} x {mh, gibbs} x {host, cim} matrix."""

    @pytest.mark.parametrize("update", ["mh", "gibbs"])
    @pytest.mark.parametrize("execution", ["scan", "pallas"])
    @pytest.mark.parametrize("randomness", ["host", "cim"])
    def test_modes_against_all(self, update, execution, randomness):
        target, init = _case(update)
        engine = _engine(update, execution, randomness, chunk_steps=7)
        key = jax.random.PRNGKey(11)
        r_all = engine.run(key, target, 40, init)
        r_thin = engine.run(key, target, 40, init, collect="thin:6")
        r_last = engine.run(key, target, 40, init, collect="last")
        np.testing.assert_array_equal(
            np.asarray(r_thin.samples), np.asarray(r_all.samples)[0::6]
        )
        assert r_last.samples.shape == (0, *init.shape)
        for field in ("final_words", "final_logp", "accept_count"):
            for r in (r_thin, r_last):
                np.testing.assert_array_equal(
                    np.asarray(getattr(r, field)),
                    np.asarray(getattr(r_all, field)),
                )

    def test_thin_one_is_all(self):
        target, init = _mh_case()
        engine = _engine("mh", "scan", "cim", chunk_steps=8)
        key = jax.random.PRNGKey(5)
        r_all = engine.run(key, target, 20, init)
        r_thin = engine.run(key, target, 20, init, collect="thin:1")
        np.testing.assert_array_equal(
            np.asarray(r_thin.samples), np.asarray(r_all.samples)
        )

    @pytest.mark.parametrize("update", ["mh", "gibbs"])
    def test_thin_respects_step0_offset(self, update):
        """The kept set is {t : (step0 + t) % k == 0}: a segment resumed
        at step0 = s keeps exactly the monolithic kept rows that fall in
        the segment, so segmented thin == thinned monolithic."""
        target, init = _case(update)
        engine = _engine(update, "scan", "host", chunk_steps=5)
        key = jax.random.PRNGKey(9)
        k = 4
        mono = engine.run(key, target, 26, init, collect=f"thin:{k}")
        head = engine.run(key, target, 11, init, collect=f"thin:{k}")
        tail = engine.run(
            key, target, 15, head.final_words, step0=11, collect=f"thin:{k}"
        )
        assert head.samples.shape[0] == samplers.kept_count(11, k, 0)
        assert tail.samples.shape[0] == samplers.kept_count(15, k, 11)
        np.testing.assert_array_equal(
            np.asarray(mono.samples),
            np.concatenate(
                [np.asarray(head.samples), np.asarray(tail.samples)]
            ),
        )


class TestCollectEdges:
    """The chunk-schedule edges the axis creates."""

    @pytest.mark.parametrize("chunk_steps", [1, 1000])
    def test_extreme_chunking_is_invariant(self, chunk_steps):
        """chunk_steps = 1 and chunk_steps > n_steps both reproduce the
        default-chunk stream for every collection mode."""
        target, init = _gibbs_case()
        key = jax.random.PRNGKey(13)
        ref = _engine("gibbs", "scan", "cim", chunk_steps=8)
        got = _engine("gibbs", "scan", "cim", chunk_steps=chunk_steps)
        for collect in ("all", "thin:6", "last"):
            r_ref = ref.run(key, target, 22, init, collect=collect)
            r_got = got.run(key, target, 22, init, collect=collect)
            np.testing.assert_array_equal(
                np.asarray(r_ref.samples), np.asarray(r_got.samples)
            )
            np.testing.assert_array_equal(
                np.asarray(r_ref.final_words), np.asarray(r_got.final_words)
            )

    @pytest.mark.parametrize("execution", ["scan", "pallas"])
    def test_thin_k_beyond_n_steps(self, execution):
        """k > n_steps keeps exactly the t = 0 row (step0 = 0)."""
        target, init = _mh_case()
        engine = _engine("mh", execution, "host", chunk_steps=4)
        key = jax.random.PRNGKey(17)
        r_all = engine.run(key, target, 10, init)
        r_thin = engine.run(key, target, 10, init, collect="thin:1000")
        assert r_thin.samples.shape[0] == 1
        np.testing.assert_array_equal(
            np.asarray(r_thin.samples), np.asarray(r_all.samples)[:1]
        )
        # ... and an offset that pushes the single kept row out of range
        r_none = engine.run(
            key, target, 10, init, step0=4, collect="thin:1000"
        )
        assert r_none.samples.shape[0] == 0

    @pytest.mark.parametrize("update,execution", [
        ("mh", "scan"), ("mh", "pallas"),
        ("gibbs", "scan"), ("gibbs", "pallas"),
    ])
    def test_last_multi_chain_segmented_resume(self, update, execution):
        """collect="last" under num_chains > 1: a step0-segmented pair of
        runs carries exactly the monolithic final state, per chain."""
        target, init = _case(update)
        num_chains = 3
        cinit = jnp.broadcast_to(init, (num_chains, *init.shape))
        engine = _engine(
            update, execution, "cim", chunk_steps=5, num_chains=num_chains
        )
        key = jax.random.PRNGKey(19)
        mono = engine.run(key, target, 24, cinit, collect="last")
        head = engine.run(key, target, 11, cinit, collect="last")
        tail = engine.run(
            key, target, 13, head.final_words, step0=11, collect="last"
        )
        assert mono.samples.shape == (num_chains, 0, *init.shape)
        np.testing.assert_array_equal(
            np.asarray(tail.final_words), np.asarray(mono.final_words)
        )
        np.testing.assert_array_equal(
            np.asarray(head.accept_count + tail.accept_count),
            np.asarray(mono.accept_count),
        )

    def test_thin_requires_concrete_step0(self):
        """The kept count is part of the output shape, so scan execution
        rejects a traced step0 under thin (all/last accept it)."""
        target, init = _mh_case()
        engine = _engine("mh", "scan", "host")
        key = jax.random.PRNGKey(23)

        def thin_run(s):
            return engine.run(
                key, target, 8, init, step0=s, collect="thin:2"
            ).final_words

        with pytest.raises(ValueError, match="concrete"):
            jax.jit(thin_run)(jnp.int32(3))
        # the "last" carry stays traceable — the tempering segment path
        last_run = jax.jit(
            lambda s: engine.run(
                key, target, 8, init, step0=s, collect="last"
            ).final_words
        )
        eager = engine.run(key, target, 8, init, step0=3, collect="last")
        np.testing.assert_array_equal(
            np.asarray(last_run(jnp.int32(3))),
            np.asarray(eager.final_words),
        )

    @pytest.mark.parametrize("update,randomness", [
        ("mh", "cim"), ("mh", "fused"),
        ("gibbs", "cim"), ("gibbs", "fused"),
    ])
    def test_pallas_accepts_traced_step0(self, update, randomness):
        """Pallas executors take step0 as a runtime value (the fused
        kernels as a per-slot operand), so a traced step0 works under
        all/last — the serving tier's packed segments jit over it."""
        target, init = _case(update)
        engine = _engine(update, "pallas", randomness)
        key = jax.random.PRNGKey(31)

        traced = jax.jit(
            lambda s: engine.run(
                key, target, 8, init, step0=s, collect="all"
            ).samples
        )
        eager = engine.run(key, target, 8, init, step0=5, collect="all")
        np.testing.assert_array_equal(
            np.asarray(traced(jnp.int32(5))), np.asarray(eager.samples)
        )


class TestOperandLeanRandomness:
    @pytest.mark.parametrize("name", ["host", "cim"])
    def test_u_stream_invariant_without_flips(self, name):
        """need_flips=False skips flip planes and leaves u bit-identical
        (the step key splits before either operand is drawn)."""
        backend = samplers.make_randomness_backend(name, p_bfr=0.45)
        key = jax.random.PRNGKey(29)
        flips, u_ref = backend.chunk(key, 3, 6, (2, 5), 4)
        none_flips, u_lean = backend.chunk(
            key, 3, 6, (2, 5), 4, need_flips=False
        )
        assert flips is not None and none_flips is None
        np.testing.assert_array_equal(np.asarray(u_ref), np.asarray(u_lean))


class TestCollectValidation:
    @pytest.mark.parametrize("bad", ["thin:0", "thin:-2", "thin:x", "median"])
    def test_bad_specs_rejected(self, bad):
        with pytest.raises(ValueError, match="collect"):
            samplers.EngineConfig(collect=bad)

    def test_kept_count(self):
        assert samplers.kept_count(10, 1) == 10
        assert samplers.kept_count(10, 3) == 4          # t = 0, 3, 6, 9
        assert samplers.kept_count(10, 3, step0=1) == 3  # t = 2, 5, 8
        assert samplers.kept_count(10, 1000) == 1
        assert samplers.kept_count(10, 1000, step0=4) == 0


class TestWorkloadAndTemperingWiring:
    def test_workload_diagnostics_under_thin_and_last(self):
        key = jax.random.PRNGKey(0)
        k_init, k_run = jax.random.split(key)
        thin = workloads.build("ising", k_init, smoke=True, collect="thin:4")
        r = thin.run(k_run)
        assert r.samples.shape[0] == samplers.kept_count(thin.n_steps, 4)
        diag = thin.diagnostics(r)
        assert diag["n_steps"] == r.samples.shape[0] - thin.kept_burn_in()
        assert "flip_rate" in diag and "tau" in diag
        last = workloads.build("ising", k_init, smoke=True, collect="last")
        r = last.run(k_run)
        assert r.samples.shape[0] == 0
        diag = last.diagnostics(r)
        assert set(diag) == {"n_steps", "flip_rate"}

    def test_tempered_streams_inherit_collection(self):
        """Replica exchange's segments resume on absolute steps, so an
        engine with collect="thin:k" yields exactly the thinned tempered
        stream, and collect="last" the same final states."""
        from repro import tempering

        model, init = _gibbs_case(batch=1)
        rinit = jnp.broadcast_to(init, (2, *init.shape))
        key = jax.random.PRNGKey(31)
        ladder = tempering.Ladder.geometric(2, beta_min=0.5)

        def run(collect):
            engine = _engine("gibbs", "scan", "cim", chunk_steps=5,
                             collect=collect)
            rex = tempering.ReplicaExchange(
                ladder=ladder, engine=engine, swap_every=8
            )
            return rex.run(key, model, 24, rinit)

        r_all, r_thin, r_last = run("all"), run("thin:4"), run("last")
        np.testing.assert_array_equal(
            np.asarray(r_thin.samples), np.asarray(r_all.samples)[:, 0::4]
        )
        assert r_last.samples.shape == (2, 0, *init.shape)
        for r in (r_thin, r_last):
            np.testing.assert_array_equal(
                np.asarray(r.final_words), np.asarray(r_all.final_words)
            )
