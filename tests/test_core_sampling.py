"""MCMC engine correctness: proposal symmetry, stationarity, convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis or skip-stubs

from repro.core import metropolis, proposal, targets, uniform_rng
from repro.core.macro import CIMMacro, MacroConfig


class TestProposal:
    @given(
        nbits=st.integers(2, 6),
        p=st.floats(0.05, 0.5, exclude_max=False),
    )
    @settings(max_examples=25, deadline=None)
    def test_transfer_matrix_symmetric_doubly_stochastic(self, nbits, p):
        q = proposal.transfer_matrix(nbits, p)
        assert np.allclose(q, q.T), "q(i,j) == q(j,i) (paper Fig. 6)"
        assert np.allclose(q.sum(axis=1), 1.0, atol=1e-9)
        assert np.allclose(q.sum(axis=0), 1.0, atol=1e-9)

    def test_bitflip_rate(self):
        key = jax.random.PRNGKey(0)
        state = jnp.zeros(50_000, jnp.uint32)
        cand = proposal.propose_bitflip(key, state, 0.45, nbits=8)
        bits = np.unpackbits(
            np.asarray(cand, dtype=np.uint32).astype(">u4").view(np.uint8)
        )
        frac = bits.mean() * 4.0  # 8 of 32 bits are live
        assert frac == pytest.approx(0.45, abs=0.01)

    def test_hamming_popcount(self):
        x = np.array([0b1010, 0b1111])
        y = np.array([0b0000, 0b1110])
        assert list(proposal.hamming_distance(x, y)) == [2, 1]


class TestStationarity:
    def test_exact_transition_kernel_preserves_target(self):
        """P built from the bit-flip proposal + MH accept has p as its
        stationary distribution — the detailed-balance core of the paper."""
        rng = np.random.default_rng(0)
        nbits = 4
        logp = rng.normal(size=1 << nbits)
        p_target = np.exp(logp - logp.max())
        p_target /= p_target.sum()
        P = proposal.mh_transition_matrix(nbits, 0.45, np.log(p_target))
        assert np.allclose(P.sum(axis=1), 1.0, atol=1e-12)
        pi_next = p_target @ P
        assert np.allclose(pi_next, p_target, atol=1e-12)

    def test_detailed_balance(self):
        rng = np.random.default_rng(1)
        nbits = 3
        logp = rng.normal(size=1 << nbits)
        p_t = np.exp(logp)
        p_t /= p_t.sum()
        P = proposal.mh_transition_matrix(nbits, 0.4, np.log(p_t))
        flux = p_t[:, None] * P
        assert np.allclose(flux, flux.T, atol=1e-12)


class TestChainConvergence:
    def test_discrete_target_tv_distance(self):
        """Long chain matches an arbitrary 5-bit target within TV < 0.02."""
        rng = np.random.default_rng(2)
        nbits = 5
        logp_table = jnp.asarray(rng.normal(size=1 << nbits), jnp.float32)
        log_prob = targets.table_target(logp_table)
        cfg = metropolis.MHConfig(nbits=nbits, burn_in=500, rng_bit_width=16)
        res = metropolis.run_chain(
            jax.random.PRNGKey(3), log_prob, cfg, n_samples=2000, chain_shape=(64,)
        )
        counts = np.bincount(
            np.asarray(res.samples).reshape(-1), minlength=1 << nbits
        )
        emp = counts / counts.sum()
        ref = np.exp(np.asarray(logp_table, dtype=np.float64))
        ref /= ref.sum()
        tv = 0.5 * np.abs(emp - ref).sum()
        assert tv < 0.02, f"TV distance {tv}"

    def test_gmm_grid_sampling(self):
        """Paper Fig. 17(a) workload at reduced scale."""
        gmm = targets.GaussianMixture.paper_gmm()
        codec = targets.GridCodec(nbits=7, dim=1, lo=(-10.0,), hi=(10.0,))
        log_prob = targets.discretized_target(gmm, codec)
        cfg = metropolis.MHConfig(nbits=7, burn_in=500, rng_bit_width=16)
        res = metropolis.run_chain(
            jax.random.PRNGKey(4), log_prob, cfg, n_samples=1500, chain_shape=(64,)
        )
        counts = np.bincount(np.asarray(res.samples).reshape(-1), minlength=128)
        emp = counts / counts.sum()
        ref = targets.reference_grid_probs(gmm, codec)
        tv = 0.5 * np.abs(emp - ref).sum()
        assert tv < 0.03, f"GMM TV distance {tv}"

    def test_acceptance_rate_plausible(self):
        """§6.4: 'sampling accept ratio typically remains between 30% and
        40%' — our near-uniform proposal on a moderately peaked target
        lands in a broad sane band."""
        gmm = targets.GaussianMixture.paper_gmm()
        codec = targets.GridCodec(nbits=8, dim=1, lo=(-10.0,), hi=(10.0,))
        cfg = metropolis.MHConfig(nbits=8, burn_in=200)
        res = metropolis.run_chain(
            jax.random.PRNGKey(5),
            targets.discretized_target(gmm, codec),
            cfg,
            n_samples=500,
            chain_shape=(32,),
        )
        assert 0.1 < float(res.acceptance_rate) < 0.9


class TestUniformRNG:
    def test_uniform_range_and_mean(self):
        u = uniform_rng.uniform(jax.random.PRNGKey(6), (100_000,), 0.45)
        u = np.asarray(u)
        assert u.min() >= 0.0 and u.max() < 1.0
        assert u.mean() == pytest.approx(0.5, abs=0.005)

    def test_bit_uniformity_after_debias(self):
        words = uniform_rng.uniform_words(
            jax.random.PRNGKey(7), (200_000,), p_bfr=0.4, bit_width=8
        )
        w = np.asarray(words)
        for b in range(8):
            frac = ((w >> b) & 1).mean()
            assert frac == pytest.approx(0.5, abs=0.006), f"bit {b}"

    def test_biased_without_debias(self):
        """Sanity: raw pseudo-read bits ARE biased (the problem MSXOR fixes)."""
        from repro.core import bitcell

        raw = bitcell.pseudo_read_fresh(
            jax.random.PRNGKey(8), 0.4, shape=(100_000,)
        )
        assert float(raw.mean()) < 0.45


class TestMacro:
    def test_macro_sampling_with_stats(self):
        macro = CIMMacro(MacroConfig(nbits=8, burn_in=200))
        gmm = targets.GaussianMixture.paper_gmm()
        codec = targets.GridCodec(nbits=8, dim=1, lo=(-10.0,), hi=(10.0,))
        pts, stats = macro.sample_points(
            jax.random.PRNGKey(9), gmm, codec, n_samples=2000
        )
        assert pts.shape == (2000, 1)
        # 8-bit samples = 2 column groups; total energy must match the §6.4
        # model evaluated at the realised acceptance rate, charged for EVERY
        # chain step (burn-in included) but normalised by KEPT samples
        from repro.core import energy

        per_step_pj = energy.energy_per_sample_fj(stats.acceptance_rate, 8) / 1e3
        assert stats.energy_pj == pytest.approx(
            per_step_pj * stats.n_steps, rel=1e-3
        )
        assert stats.energy_per_sample_pj == pytest.approx(
            stats.energy_pj / stats.n_samples, rel=1e-6
        )
        assert stats.throughput_samples_per_s == pytest.approx(
            stats.n_samples / stats.modeled_time_s, rel=1e-6
        )
        assert stats.throughput_samples_per_s > 1e8  # 64 compartments
        assert 0.05 < stats.acceptance_rate < 0.95

    def test_macro_geometry_validation(self):
        with pytest.raises(ValueError):
            MacroConfig(nbits=128)
