"""Loop-aware HLO cost model validation (the roofline's foundation)."""

import jax
import jax.numpy as jnp
import pytest

from repro.distributed.hlo_cost import analyze_hlo


def _compile(f, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(f).lower(*args).compile()


class TestLoopAwareFlops:
    def test_scan_trip_count_multiplies(self):
        def make(n):
            def f(x, w):
                def body(x, _):
                    return jnp.tanh(x @ w), None
                x, _ = jax.lax.scan(body, x, None, length=n)
                return x
            return f

        expect_per_iter = 2 * 64 ** 3
        for n in (3, 7):
            c = _compile(make(n), (64, 64), (64, 64))
            r = analyze_hlo(c.as_text())
            assert r["flops"] == pytest.approx(n * expect_per_iter, rel=1e-6)
            assert r["unknown_trip_loops"] == 0

    def test_nested_scans_compose(self):
        def f(x, w):
            def outer(x, _):
                def inner(x, _):
                    return jnp.tanh(x @ w), None
                x, _ = jax.lax.scan(inner, x, None, length=3)
                return x, None
            x, _ = jax.lax.scan(outer, x, None, length=5)
            return x

        c = _compile(f, (64, 64), (64, 64))
        r = analyze_hlo(c.as_text())
        assert r["flops"] == pytest.approx(15 * 2 * 64 ** 3, rel=1e-6)

    def test_xla_cost_analysis_is_body_once(self):
        """The reason this module exists: XLA ignores trip counts."""
        def make(n):
            def f(x, w):
                def body(x, _):
                    return jnp.tanh(x @ w), None
                x, _ = jax.lax.scan(body, x, None, length=n)
                return x
            return f

        def xla_flops(compiled):
            ca = compiled.cost_analysis()
            if isinstance(ca, list):  # older jax: one dict per device
                ca = ca[0]
            return ca["flops"]

        f5 = xla_flops(_compile(make(5), (64, 64), (64, 64)))
        f10 = xla_flops(_compile(make(10), (64, 64), (64, 64)))
        assert f5 == f10  # body-once: scan length invisible

    def test_plain_dot_flops(self):
        c = _compile(lambda a, b: a @ b, (32, 48), (48, 16))
        r = analyze_hlo(c.as_text())
        assert r["flops"] == pytest.approx(2 * 32 * 48 * 16, rel=1e-6)

    def test_grad_flops_3x_forward(self):
        """grad needs fwd recompute + two transpose matmuls = 3 dots."""
        def loss(x, w):
            return jnp.sum(jnp.tanh(x @ w))

        fwd = analyze_hlo(_compile(loss, (64, 64), (64, 64)).as_text())["flops"]
        grd = analyze_hlo(
            _compile(jax.grad(loss, argnums=(0, 1)), (64, 64), (64, 64)).as_text()
        )["flops"]
        assert grd / fwd == pytest.approx(3.0, rel=0.2)


class TestBytesModel:
    def test_dus_counts_slice_not_target(self):
        """In-place cache updates must not charge the whole cache."""
        def f(cache, upd):
            return jax.lax.dynamic_update_slice(cache, upd, (0, 0))

        args = [
            jax.ShapeDtypeStruct((4096, 4096), jnp.float32),
            jax.ShapeDtypeStruct((1, 4096), jnp.float32),
        ]
        # donate the cache so XLA aliases it (no defensive copy)
        c = jax.jit(f, donate_argnums=(0,)).lower(*args).compile()
        r = analyze_hlo(c.as_text())
        # 2 x update bytes (read + write region), << full 64 MB cache
        assert r["bytes"] <= 4 * 1 * 4096 * 4 + 1e4

    def test_upper_bound_dominates(self):
        def f(x, w):
            return jnp.tanh(x @ w) * 2.0 + 1.0

        r = analyze_hlo(_compile(f, (64, 64), (64, 64)).as_text())
        assert r["bytes_upper"] >= r["bytes"] > 0


class TestTupleTypeParsing:
    def test_big_tuple_carry_with_index_comments(self):
        """>=6-element while carries print /*index=N*/ comments containing
        '=' — the regression that once zeroed all loop costs."""
        def f(a, b, c, d, e, g, w):
            def body(carry, _):
                a, b, c, d, e, g = carry
                return (jnp.tanh(a @ w), b, c, d, e, g), None

            (a, *_), _ = jax.lax.scan(body, (a, b, c, d, e, g), None, length=6)
            return a

        shapes = [(64, 64)] * 7
        r = analyze_hlo(_compile(f, *shapes).as_text())
        assert r["flops"] == pytest.approx(6 * 2 * 64 ** 3, rel=1e-6)
