"""Tempering subsystem (DESIGN.md §Tempering): parity, degeneration,
swap correctness, and annealing optimality.

The contract under test:

  * tempered runs are **bit-identical** across {scan, pallas} x
    {chunked, monolithic} — segments resume via ``step0`` and swap
    decisions key on absolute step indices, so neither the executor nor
    the chunk size can change a stream;
  * a 1-replica ladder degenerates to a plain engine run bit-for-bit
    (swap boundaries segment the run but cannot perturb it);
  * swaps are real MH moves: equal-beta pairs always exchange, and on a
    frustrated spin glass the per-pair acceptance lands strictly inside
    (0, 1) for both randomness backends;
  * annealing finds the exhaustively verified ground state.

Sizes stay minimal — tier-1 runs everything, including slow marks.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import samplers, tempering
from repro.launch import sample as sample_cli
from repro.workloads.spin_glass import SpinGlass, exhaustive_ground_state


def _engine(**kw):
    return samplers.MHEngine(samplers.EngineConfig(**kw))


def _glass(h=4, w=4, batch=2, seed=1):
    model = SpinGlass.bimodal(jax.random.PRNGKey(seed), h, w)
    return model, model.random_init(jax.random.PRNGKey(seed + 1), batch)


def _mh_target(b=2, v=64, chains=8, seed=0):
    table = jax.random.normal(jax.random.PRNGKey(seed), (b, v), jnp.float32)
    init = jnp.broadcast_to(
        jnp.argmax(table, -1).astype(jnp.uint32)[:, None], (b, chains)
    )
    return samplers.TableTarget(table), init


def _bcast(init, n):
    return jnp.broadcast_to(init, (n, *init.shape))


class TestLadder:
    def test_geometric_shape_and_order(self):
        ladder = tempering.Ladder.geometric(4, beta_min=0.25)
        assert ladder.betas[0] == pytest.approx(1.0)
        assert ladder.betas[-1] == pytest.approx(0.25)
        assert all(a >= b for a, b in zip(ladder.betas, ladder.betas[1:]))

    def test_validation(self):
        with pytest.raises(ValueError, match="non-increasing"):
            tempering.Ladder((0.5, 1.0))
        with pytest.raises(ValueError, match="finite"):
            tempering.Ladder((1.0, 0.0))
        with pytest.raises(ValueError, match="non-decreasing"):
            tempering.Annealer((2.0, 1.0), 4)

    def test_scaled_target_beta_one_is_identity(self):
        target, _ = _mh_target()
        assert tempering.scaled_target(target, 1.0) is target

    def test_scaled_table_and_lattice(self):
        target, _ = _mh_target()
        scaled = tempering.scaled_target(target, 0.5)
        np.testing.assert_allclose(
            np.asarray(scaled.table), 0.5 * np.asarray(target.table)
        )
        model, init = _glass()
        tempered = tempering.scaled_target(model, 0.5)
        assert tempered.supports_fused_gibbs
        np.testing.assert_allclose(
            np.asarray(tempered.conditional_logit(init)),
            0.5 * np.asarray(model.conditional_logit(init)),
        )
        # observables delegate to the base model
        np.testing.assert_array_equal(
            np.asarray(tempered.energy(init)), np.asarray(model.energy(init))
        )


class TestTemperedParity:
    @pytest.mark.parametrize("update", ["mh", "gibbs"])
    def test_bit_identical_across_executors_and_chunkings(self, update):
        """The ISSUE-4 acceptance matrix: one tempered stream per key,
        whatever the executor or chunk size."""
        if update == "mh":
            target, init = _mh_target()
        else:
            target, init = _glass()
        ladder = tempering.Ladder.geometric(3, beta_min=0.3)
        key = jax.random.PRNGKey(7)
        runs = {}
        for execution in ("scan", "pallas"):
            for chunk in (5, 1000):
                engine = _engine(
                    update=update, execution=execution, chunk_steps=chunk
                )
                rex = tempering.ReplicaExchange(
                    ladder=ladder, engine=engine, swap_every=6
                )
                runs[(execution, chunk)] = rex.run(
                    key, target, 20, _bcast(init, 3)
                )
        base = runs[("scan", 5)]
        for res in runs.values():
            np.testing.assert_array_equal(
                np.asarray(base.samples), np.asarray(res.samples)
            )
            np.testing.assert_array_equal(
                np.asarray(base.accept_count), np.asarray(res.accept_count)
            )

    @pytest.mark.parametrize("update", ["mh", "gibbs"])
    def test_one_replica_ladder_is_plain_engine_run(self, update):
        """R=1 degenerates bit-for-bit: the segment boundaries (step0
        resume) leave the stream untouched and no swap ever fires."""
        if update == "mh":
            target, init = _mh_target()
        else:
            target, init = _glass()
        key = jax.random.PRNGKey(3)
        engine = _engine(update=update, chunk_steps=8)
        rex = tempering.ReplicaExchange(
            ladder=tempering.Ladder((1.0,)), engine=engine, swap_every=7
        )
        tempered = rex.run(key, target, 25, init[None])
        plain = engine.run(key, target, 25, init)
        np.testing.assert_array_equal(
            np.asarray(tempered.samples[0]), np.asarray(plain.samples)
        )
        np.testing.assert_array_equal(
            np.asarray(tempered.accept_count[0]),
            np.asarray(plain.accept_count),
        )
        assert tempered.swap.events == 0

    def test_replica_streams_are_chain_slots(self):
        """Replica r's within-segment randomness is chain slot r: with
        swaps disabled by a huge swap_every, replica r == a plain run
        with chain_id=r under the per-replica scaled target."""
        target, init = _mh_target()
        ladder = tempering.Ladder.geometric(3, beta_min=0.5)
        key = jax.random.PRNGKey(11)
        engine = _engine(chunk_steps=8)
        rex = tempering.ReplicaExchange(
            ladder=ladder, engine=engine, swap_every=1000
        )
        tempered = rex.run(key, target, 12, _bcast(init, 3))
        for r, beta in enumerate(ladder.betas):
            solo = engine.run(
                key, tempering.scaled_target(target, beta), 12, init,
                chain_id=r,
            )
            np.testing.assert_array_equal(
                np.asarray(tempered.samples[r]), np.asarray(solo.samples)
            )


class TestSwapCorrectness:
    def test_equal_betas_always_swap(self):
        """delta = 0 => accept prob 1: every active-parity pair must
        exchange (u < exp(0) holds a.s. for u in [0, 1))."""
        target, init = _mh_target()
        rex = tempering.ReplicaExchange(
            ladder=tempering.Ladder((1.0, 1.0, 1.0)),
            engine=_engine(chunk_steps=8),
            swap_every=4,
        )
        result = rex.run(jax.random.PRNGKey(0), target, 16, _bcast(init, 3))
        summary = result.swap.summary()
        assert summary["swap_events"] == 3
        assert summary["swap_accept_rate"] == 1.0

    @pytest.mark.parametrize("randomness", ["host", "cim"])
    def test_swap_acceptance_strictly_inside_unit_interval(self, randomness):
        """The ISSUE-4 diagnostic criterion: on a frustrated glass with a
        real ladder, every pair accepts some and rejects some swaps —
        for both randomness backends (swap uniforms ride the same
        backend stream as the sampling moves)."""
        model, init = _glass(batch=4)
        rex = tempering.ReplicaExchange(
            ladder=tempering.Ladder.geometric(4, beta_min=0.2),
            engine=_engine(update="gibbs", randomness=randomness,
                           chunk_steps=8),
            swap_every=4,
        )
        result = rex.run(
            jax.random.PRNGKey(2), model, 96, _bcast(init, 4)
        )
        for rate in result.swap.summary()["pair_accept_rate"]:
            assert 0.0 < rate < 1.0

    def test_round_trips_counted(self):
        """Equal betas swap deterministically, so walkers shuttle across
        the ladder and complete round trips."""
        target, init = _mh_target(chains=2)
        rex = tempering.ReplicaExchange(
            ladder=tempering.Ladder((1.0, 1.0)),
            engine=_engine(chunk_steps=4),
            swap_every=2,
        )
        result = rex.run(jax.random.PRNGKey(0), target, 20, _bcast(init, 2))
        assert result.swap.summary()["round_trips"] > 0

    def test_init_needs_leading_replica_axis(self):
        target, init = _mh_target()
        rex = tempering.ReplicaExchange(
            ladder=tempering.Ladder.geometric(3), engine=_engine()
        )
        with pytest.raises(ValueError, match="leading"):
            rex.run(jax.random.PRNGKey(0), target, 8, init)

    def test_rejects_multi_chain_engine(self):
        with pytest.raises(ValueError, match="chain-id axis"):
            tempering.ReplicaExchange(
                ladder=tempering.Ladder.geometric(2),
                engine=_engine(num_chains=2),
            )


class TestAnnealing:
    @pytest.mark.parametrize("randomness", ["host", "cim"])
    def test_reaches_exhaustive_ground_state(self, randomness):
        """The ISSUE-4 optimality criterion: on a 4x4 ±J glass the
        annealer's best-ever state hits the exact brute-force ground
        energy, under both randomness backends."""
        model, init = _glass(batch=2)
        ground_e, _ = exhaustive_ground_state(model)
        annealer = tempering.Annealer.geometric(
            8, 32, beta_min=0.4, beta_max=4.0
        )
        engine = _engine(update="gibbs", randomness=randomness,
                         chunk_steps=16)
        result = annealer.run(jax.random.PRNGKey(0), model, init,
                              engine=engine)
        best = float(np.asarray(result.best_energy).min())
        assert best == pytest.approx(ground_e)
        # the tracker's stored words must reproduce the stored energy
        np.testing.assert_allclose(
            np.asarray(model.energy(result.best_words)),
            np.asarray(result.best_energy),
        )

    def test_single_stage_beta_one_is_plain_run(self):
        """Annealing degenerates exactly like the 1-replica ladder."""
        model, init = _glass()
        engine = _engine(update="gibbs", chunk_steps=8)
        annealer = tempering.Annealer((1.0,), 16)
        res = annealer.run(jax.random.PRNGKey(5), model, init, engine=engine)
        plain = engine.run(jax.random.PRNGKey(5), model, 16, init)
        np.testing.assert_array_equal(
            np.asarray(res.final_words), np.asarray(plain.final_words)
        )
        np.testing.assert_array_equal(
            np.asarray(res.accept_count), np.asarray(plain.accept_count)
        )


class TestSpinGlassWorkload:
    def test_registered_and_cli_visible(self):
        from repro import workloads

        assert "spin_glass" in workloads.WORKLOADS
        parser = sample_cli.build_parser()
        action = next(
            a for a in parser._actions if a.dest == "workload"
        )
        assert "spin_glass" in action.choices

    def test_scan_pallas_parity(self):
        """Heterogeneous couplings ride the kernel as fused_consts
        operands; the streams must stay bit-identical to scan."""
        model, init = _glass()
        key = jax.random.PRNGKey(9)
        r_scan = _engine(update="gibbs", execution="scan", chunk_steps=8).run(
            key, model, 20, init
        )
        r_pal = _engine(update="gibbs", execution="pallas", chunk_steps=8).run(
            key, model, 20, init
        )
        np.testing.assert_array_equal(
            np.asarray(r_scan.samples), np.asarray(r_pal.samples)
        )

    def test_energy_consistent_with_conditional(self):
        """Flipping one site changes E by exactly the conditional
        logit's prediction: E(s_i=0) - E(s_i=1) = logit_i."""
        model, init = _glass(batch=1)
        state = init[0]
        logit = np.asarray(model.conditional_logit(state))
        for i, j in ((0, 0), (1, 2), (3, 1)):
            s_up = np.asarray(state).copy()
            s_dn = s_up.copy()
            s_up[i, j], s_dn[i, j] = 1, 0
            de = float(
                model.energy(jnp.asarray(s_dn)) - model.energy(jnp.asarray(s_up))
            )
            assert de == pytest.approx(logit[i, j], abs=1e-4)

    def test_even_lattice_required(self):
        with pytest.raises(ValueError, match="even"):
            SpinGlass.bimodal(jax.random.PRNGKey(0), 3, 4)

    def test_maxcut_cut_value_matches_partition_sum(self):
        model = SpinGlass.maxcut(jax.random.PRNGKey(4), 4, 4)
        state = model.random_init(jax.random.PRNGKey(5), 1)[0]
        s = np.asarray(state)
        w_r = -np.asarray(model.j_right)
        w_d = -np.asarray(model.j_down)
        cut = (
            (w_r * (s != np.roll(s, -1, -1))).sum()
            + (w_d * (s != np.roll(s, -1, -2))).sum()
        )
        assert float(model.cut_value(state)) == pytest.approx(cut)
        with pytest.raises(ValueError, match="MAX-CUT"):
            SpinGlass.bimodal(jax.random.PRNGKey(0), 4, 4).cut_value(state)

    def test_cli_ladder_and_anneal_smoke(self, capsys):
        row = sample_cli.main(
            ["--workload", "spin_glass", "--smoke", "--steps", "24",
             "--ladder", "3", "--swap-every", "6"]
        )
        assert row["mode"] == "ladder"
        assert row["num_replicas"] == 3
        assert "swap_accept_rate" in row and "round_trips" in row
        assert "flip_rate" in row  # gibbs rate labelled as a flip count
        assert "mode=ladder" in capsys.readouterr().out

        row = sample_cli.main(
            ["--workload", "spin_glass", "--smoke", "--steps", "24",
             "--anneal", "4"]
        )
        assert row["mode"] == "anneal"
        assert "best_energy" in row
        assert "best_cut" not in row  # bimodal glass: no cut story

        row = sample_cli.main(
            ["--workload", "spin_glass", "--smoke", "--steps", "24",
             "--anneal", "4", "--maxcut"]
        )
        assert row["best_cut"] >= 0.0  # signed MAX-CUT reduction wired up

    def test_cli_rejects_ladder_with_num_chains(self):
        with pytest.raises(SystemExit):
            sample_cli.main(
                ["--workload", "spin_glass", "--smoke", "--ladder", "2",
                 "--num-chains", "2"]
            )
