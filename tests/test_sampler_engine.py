"""Unified sampler engine: backend parity, chunk invariance, macro metrics.

The engine's three axes (target x randomness x execution, DESIGN.md §2)
must compose without changing the chain:

  * scan and pallas(interpret) executors consume identical randomness and
    mirror each other op-for-op => bit-identical sample streams,
  * chunked randomness streaming is defined per absolute step index =>
    bit-identical to the monolithic materialisation,
  * host and cim randomness differ only by the residual MSXOR debias
    error and u quantisation => acceptance rates agree statistically.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import samplers
from repro.core import metropolis, token_sampler
from repro.core.macro import CIMMacro, MacroConfig
from repro.core.targets import GaussianMixture, GridCodec


def _table_and_init(b=3, v=100, chains=16, seed=0):
    key = jax.random.PRNGKey(seed)
    table = jax.random.normal(key, (b, v), jnp.float32)
    init = jnp.broadcast_to(
        jnp.argmax(table, -1).astype(jnp.uint32)[:, None], (b, chains)
    )
    return table, init


def _engine(**kw):
    return samplers.MHEngine(samplers.EngineConfig(**kw))


class TestExecutionParity:
    def test_scan_and_pallas_bit_identical(self):
        """Same seed + same randomness backend => the two executors emit
        the exact same sample stream and accept counts."""
        table, init = _table_and_init()
        target = samplers.TableTarget(table)
        key = jax.random.PRNGKey(7)
        r_scan = _engine(execution="scan", chunk_steps=16).run(
            key, target, 48, init
        )
        r_pal = _engine(execution="pallas", chunk_steps=16).run(
            key, target, 48, init
        )
        np.testing.assert_array_equal(
            np.asarray(r_scan.samples), np.asarray(r_pal.samples)
        )
        np.testing.assert_array_equal(
            np.asarray(r_scan.accept_count), np.asarray(r_pal.accept_count)
        )

    @pytest.mark.parametrize("execution", ["scan", "pallas"])
    def test_chunked_vs_monolithic_bit_identical(self, execution):
        """Randomness for step t depends only on (key, t): any chunking of
        the stream reproduces the monolithic operand block exactly."""
        table, init = _table_and_init(b=2, v=64, chains=8, seed=1)
        target = samplers.TableTarget(table)
        key = jax.random.PRNGKey(11)
        r_chunked = _engine(execution=execution, chunk_steps=7).run(
            key, target, 50, init
        )
        r_mono = _engine(execution=execution, chunk_steps=1000).run(
            key, target, 50, init
        )
        np.testing.assert_array_equal(
            np.asarray(r_chunked.samples), np.asarray(r_mono.samples)
        )
        np.testing.assert_array_equal(
            np.asarray(r_chunked.accept_count), np.asarray(r_mono.accept_count)
        )

    def test_token_wrappers_scan_pallas_identical(self):
        """The serving-facing wrapper inherits executor parity."""
        key = jax.random.PRNGKey(5)
        logits = jax.random.normal(key, (8, 50), jnp.float32) * 2
        cfg_s = token_sampler.TokenSamplerConfig(
            vocab_size=50, n_steps=48, execution="scan"
        )
        cfg_p = token_sampler.TokenSamplerConfig(
            vocab_size=50, n_steps=48, execution="pallas"
        )
        r_s = token_sampler.sample_tokens(key, logits, cfg_s)
        r_p = token_sampler.sample_tokens(key, logits, cfg_p)
        np.testing.assert_array_equal(
            np.asarray(r_s.tokens), np.asarray(r_p.tokens)
        )
        assert float(r_s.acceptance_rate) == float(r_p.acceptance_rate)


class TestRandomnessBackends:
    def test_host_vs_cim_acceptance_close(self):
        """host (ideal jax.random) and cim (pseudo-read + MSXOR) implement
        the same proposal/accept distribution up to the debias residual."""
        table, init = _table_and_init(b=4, v=64, chains=64, seed=2)
        target = samplers.TableTarget(table)
        key = jax.random.PRNGKey(3)
        n_steps = 400
        acc = {}
        for name in ("host", "cim"):
            res = _engine(execution="scan", randomness=name).run(
                key, target, n_steps, init
            )
            acc[name] = float(res.acceptance_rate)
        assert 0.0 < acc["cim"] < 1.0
        # ~100k accept trials per backend; 3-sigma ~ 0.5%
        assert acc["host"] == pytest.approx(acc["cim"], abs=0.02)

    def test_cim_distribution_matches_softmax(self):
        """End-to-end: cim randomness + scan executor converge to the
        table's softmax (the paper's core claim, engine edition)."""
        key = jax.random.PRNGKey(7)
        logits = jnp.asarray(
            np.random.default_rng(0).normal(size=(1, 32)), jnp.float32
        )
        target = samplers.TableTarget(logits)
        init = jnp.broadcast_to(
            jnp.argmax(logits, -1).astype(jnp.uint32)[:, None], (1, 256)
        )
        res = _engine(execution="scan").run(key, target, 400, init)
        kept = np.asarray(res.samples[200:]).reshape(-1)
        emp = np.bincount(kept, minlength=32) / kept.size
        ref = np.asarray(jax.nn.softmax(logits[0]))
        tv = 0.5 * np.abs(emp - ref).sum()
        assert tv < 0.05, f"TV {tv}"

    def test_backend_factory_rejects_unknown(self):
        with pytest.raises(ValueError):
            samplers.make_randomness_backend("quantum", p_bfr=0.45)


class TestDispatch:
    def test_auto_on_cpu_is_scan(self):
        target = samplers.TableTarget(jnp.zeros((1, 16), jnp.float32))
        resolved = samplers.resolve_execution("auto", target)
        expect = "pallas" if jax.default_backend() == "tpu" else "scan"
        assert resolved == expect

    def test_explicit_override_wins(self):
        target = samplers.TableTarget(jnp.zeros((1, 16), jnp.float32))
        assert samplers.resolve_execution("pallas", target) == "pallas"
        assert samplers.resolve_execution("scan", target) == "scan"

    def test_pallas_requires_table_target(self):
        target = samplers.CallableTarget(
            lambda w: jnp.zeros(w.shape, jnp.float32), nbits=4
        )
        with pytest.raises(ValueError):
            samplers.resolve_execution("pallas", target)

    def test_engine_config_validation(self):
        with pytest.raises(ValueError):
            samplers.EngineConfig(execution="vulkan")
        with pytest.raises(ValueError):
            samplers.EngineConfig(randomness="dice")
        with pytest.raises(ValueError):
            samplers.EngineConfig(chunk_steps=0)

    @pytest.mark.parametrize("field,bad", [
        ("block_c", 0),
        ("block_c", -128),
        ("rng_bit_width", 0),
        ("rng_bit_width", -1),
        ("rng_stages", 0),
        ("rng_stages", -3),
    ])
    def test_engine_config_rejects_nonpositive_knobs(self, field, bad):
        """block_c / rng_bit_width / rng_stages share chunk_steps' >= 1
        contract — a non-positive value raises instead of producing a
        degenerate kernel grid or RNG pipeline."""
        with pytest.raises(ValueError, match=field):
            samplers.EngineConfig(**{field: bad})


class TestEngineValidation:
    """Negative paths: misconfigurations raise with actionable messages
    instead of silently running the wrong program."""

    def test_pallas_rejects_callable_target_with_guidance(self):
        target = samplers.CallableTarget(
            lambda w: jnp.zeros(w.shape, jnp.float32), nbits=4
        )
        with pytest.raises(ValueError, match="table target"):
            samplers.resolve_execution("pallas", target)

    def test_pallas_gibbs_rejects_non_fusable_model(self):
        """A conditional model without a fused checkerboard kernel
        (supports_fused_gibbs) cannot opt into pallas execution."""

        class NoFuse:
            table = None
            nbits = 1

            def conditional_logit(self, state):
                return jnp.zeros(state.shape, jnp.float32)

        with pytest.raises(ValueError, match="supports_fused_gibbs"):
            samplers.resolve_execution("pallas", NoFuse(), "gibbs")
        # auto never fuses gibbs, even for fusable models
        from repro.workloads.ising import IsingModel

        model = IsingModel(height=4, width=4)
        assert samplers.resolve_execution("auto", model, "gibbs") == "scan"

    def test_gibbs_update_needs_conditional_target(self):
        table, init = _table_and_init(b=1, v=16, chains=4)
        engine = _engine(update="gibbs")
        with pytest.raises(ValueError, match="conditional_logit"):
            engine.run(
                jax.random.PRNGKey(0), samplers.TableTarget(table), 4, init
            )

    @pytest.mark.parametrize("execution", ["scan", "pallas"])
    def test_multi_chain_init_requires_leading_axis(self, execution):
        """The PR-3 contract: a multi-chain init without the explicit
        (num_chains,) leading axis raises rather than being broadcast."""
        table, init = _table_and_init(b=2, v=16, chains=4)
        engine = _engine(num_chains=3, execution=execution)
        with pytest.raises(ValueError, match="leading"):
            engine.run(jax.random.PRNGKey(0), samplers.TableTarget(table),
                       4, init)
        # pallas additionally pins the per-chain rank, so a solo init
        # whose first dim collides with num_chains is still caught
        engine = _engine(num_chains=2, execution="pallas")
        with pytest.raises(ValueError, match="num_chains, B, C"):
            engine.run(jax.random.PRNGKey(0), samplers.TableTarget(table),
                       4, init)

    def test_step0_validation_and_resume(self):
        """step0 < 0 raises; a run resumed at step0=s continues the
        monolithic stream exactly (the tempering segment contract)."""
        table, init = _table_and_init(b=2, v=32, chains=8)
        target = samplers.TableTarget(table)
        engine = _engine(chunk_steps=5)
        key = jax.random.PRNGKey(3)
        with pytest.raises(ValueError, match="step0"):
            engine.run(key, target, 4, init, step0=-1)
        mono = engine.run(key, target, 24, init)
        head = engine.run(key, target, 11, init)
        tail = engine.run(key, target, 13, head.final_words, step0=11)
        np.testing.assert_array_equal(
            np.asarray(mono.samples),
            np.concatenate(
                [np.asarray(head.samples), np.asarray(tail.samples)]
            ),
        )


class TestWrapperEquivalence:
    def test_metropolis_wrapper_routes_through_engine(self):
        """run_chain == engine.run + burn-in/thin slicing, bit for bit."""
        logp_table = jnp.asarray(
            np.random.default_rng(4).normal(size=32), jnp.float32
        )

        def log_prob(words):
            safe = jnp.clip(words.astype(jnp.int32), 0, 31)
            return jnp.where(words < 32, logp_table[safe], -jnp.inf)

        cfg = metropolis.MHConfig(nbits=5, burn_in=20, rng_bit_width=16)
        key = jax.random.PRNGKey(13)
        init = jnp.zeros((8,), jnp.uint32)
        res = metropolis.run_chain(
            key, log_prob, cfg, n_samples=30, chain_shape=(8,), init_words=init
        )
        engine = samplers.MHEngine(cfg.engine_config())
        target = samplers.CallableTarget(log_prob, cfg.nbits)
        raw = engine.run(key, target, 50, init)
        np.testing.assert_array_equal(
            np.asarray(res.samples), np.asarray(raw.samples[20:])
        )
        np.testing.assert_array_equal(
            np.asarray(res.final.accept_count), np.asarray(raw.accept_count)
        )


class TestMacroMetrics:
    def test_energy_and_throughput_normalised_by_kept_samples(self):
        """Regression for the Fig. 16 metric definitions: pJ/sample and
        samples/s divide by KEPT samples, not total chain steps (which
        silently deflated pJ/sample and inflated throughput by the
        burn-in + thinning factor)."""
        from repro.core import energy

        macro = CIMMacro(MacroConfig(nbits=8, burn_in=100, thin=2))
        gmm = GaussianMixture.paper_gmm()
        codec = GridCodec(nbits=8, dim=1, lo=(-10.0,), hi=(10.0,))
        pts, stats = macro.sample_points(
            jax.random.PRNGKey(1), gmm, codec, n_samples=640
        )
        assert stats.n_samples == 640
        # ledger still charges every step...
        per_step_pj = (
            energy.energy_per_sample_fj(stats.acceptance_rate, 8) / 1e3
        )
        assert stats.energy_pj == pytest.approx(
            per_step_pj * stats.n_steps, rel=1e-3
        )
        # ...but the user-facing metrics are per kept sample
        assert stats.energy_per_sample_pj == pytest.approx(
            stats.energy_pj / stats.n_samples, rel=1e-6
        )
        assert stats.throughput_samples_per_s == pytest.approx(
            stats.n_samples / stats.modeled_time_s, rel=1e-6
        )
        # burn-in + thinning means each kept sample costs MORE than a step
        assert stats.energy_per_sample_pj > per_step_pj
        assert (
            stats.throughput_samples_per_s
            < stats.n_steps / stats.modeled_time_s
        )
