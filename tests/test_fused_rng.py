"""Fused in-kernel randomness (DESIGN.md §Randomness): stream contract,
bit-parity, and statistical quality.

The contract under test:

  * the counter cipher (kernels/rng) matches the published
    Threefry-2x32-20 known-answer vectors, so the stream is pinned to a
    spec — not to whatever this repo happens to compute;
  * fused runs are **bit-identical** across the full
    {scan, pallas} x {mh, gibbs} x {chunked, monolithic} x step0 matrix
    — the pallas kernels make the draws in-kernel, the scan executor
    materialises them through ``FusedRandomness.chunk``, and both must
    land on the same uint32s;
  * chain c of a multi-chain fused run == a solo run with chain_id=c
    (the chain fold stays jax-side; kernels only ever see per-chain key
    words);
  * ``need_flips=False`` leaves the u stream bit-identical (operand
    salts separate the streams — no key split to diverge);
  * tempering swap draws ride the same backend protocol, so a 1-replica
    fused ladder degenerates to the plain fused run bit-for-bit;
  * slow marks: uniform/flip-plane statistics against the paper's
    <1e-5 bias budget (the conversion ``(bits >> 8) * 2^-24`` is exact,
    so the *analytic* bias is 0 — the empirical checks bound the
    CLT-sized sampling noise on top).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis or skip-stubs

from repro import samplers, tempering
from repro.kernels import rng
from repro.workloads.ising import IsingModel
from repro.workloads.spin_glass import SpinGlass


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_programs():
    """The parity matrix compiles dozens of interpret-mode pallas
    programs; drop them from the process-wide executable cache on module
    exit so the rest of the suite doesn't compile on top of them."""
    yield
    jax.clear_caches()


def _engine(**kw):
    return samplers.MHEngine(
        samplers.EngineConfig(randomness="fused", **kw)
    )


def _mh_case(b=2, v=64, chains=8, seed=0):
    table = jax.random.normal(jax.random.PRNGKey(seed), (b, v), jnp.float32)
    init = jnp.broadcast_to(
        jnp.argmax(table, -1).astype(jnp.uint32)[:, None], (b, chains)
    )
    return samplers.TableTarget(table), init


def _gibbs_case(batch=2):
    model = IsingModel(height=4, width=6)
    return model, model.random_init(jax.random.PRNGKey(3), batch)


def _case(update):
    return _mh_case() if update == "mh" else _gibbs_case()


class TestThreefryKnownAnswers:
    """Random123 test vectors for Threefry-2x32, 20 rounds."""

    def test_zero_key_zero_counter(self):
        x0, x1 = rng.threefry2x32(0, 0, 0, 0)
        assert (int(x0), int(x1)) == (0x6B200159, 0x99BA4EFE)

    def test_all_ones(self):
        ff = 0xFFFFFFFF
        x0, x1 = rng.threefry2x32(ff, ff, ff, ff)
        assert (int(x0), int(x1)) == (0x1CB996FC, 0xBB002BE7)

    def test_pi_digits(self):
        x0, x1 = rng.threefry2x32(
            0x13198A2E, 0x03707344, 0x243F6A88, 0x85A308D3
        )
        assert (int(x0), int(x1)) == (0xC4923A9C, 0x483DF7A0)

    def test_uniform_conversion_range_and_exactness(self):
        u = rng.uniform_at(jnp.uint32(1), jnp.uint32(2), rng.site_index((4096,)))
        u = np.asarray(u)
        assert u.min() >= 0.0 and u.max() < 1.0
        # every value is a multiple of 2^-24 — float32-exact by design
        np.testing.assert_array_equal(u * (1 << 24), np.round(u * (1 << 24)))


class TestFusedParityMatrix:
    """The ISSUE-6 acceptance matrix: one fused stream per key, whatever
    the executor, the chunking, or the stream offset."""

    @pytest.mark.parametrize("update", ["mh", "gibbs"])
    @pytest.mark.parametrize("chunk", [7, 1000])
    @pytest.mark.parametrize("step0", [0, 7])
    def test_scan_pallas_bit_identical(self, update, chunk, step0):
        target, init = _case(update)
        key = jax.random.PRNGKey(11)
        runs = {}
        for execution in ("scan", "pallas"):
            engine = _engine(
                update=update, execution=execution, chunk_steps=chunk
            )
            runs[execution] = engine.run(key, target, 20, init, step0=step0)
        for field in ("samples", "accept_count", "final_words", "final_logp"):
            np.testing.assert_array_equal(
                np.asarray(getattr(runs["scan"], field)),
                np.asarray(getattr(runs["pallas"], field)),
            )

    @pytest.mark.parametrize("update", ["mh", "gibbs"])
    def test_chunked_equals_monolithic(self, update):
        target, init = _case(update)
        key = jax.random.PRNGKey(5)
        mono = _engine(update=update, chunk_steps=1000).run(
            key, target, 23, init
        )
        chunked = _engine(update=update, chunk_steps=6).run(
            key, target, 23, init
        )
        np.testing.assert_array_equal(
            np.asarray(mono.samples), np.asarray(chunked.samples)
        )

    @pytest.mark.parametrize("update", ["mh", "gibbs"])
    @pytest.mark.parametrize("execution", ["scan", "pallas"])
    def test_multichain_matches_solo(self, update, execution):
        target, init = _case(update)
        key = jax.random.PRNGKey(9)
        multi = _engine(
            update=update, execution=execution, num_chains=3
        ).run(key, target, 12, jnp.broadcast_to(init, (3, *init.shape)))
        solo = _engine(update=update, execution=execution)
        for c in range(3):
            r = solo.run(key, target, 12, init, chain_id=c)
            np.testing.assert_array_equal(
                np.asarray(multi.samples[c]), np.asarray(r.samples)
            )

    def test_fused_distinct_from_host_and_cim(self):
        target, init = _mh_case()
        key = jax.random.PRNGKey(2)
        out = {
            name: samplers.MHEngine(
                samplers.EngineConfig(randomness=name)
            ).run(key, target, 16, init).samples
            for name in ("host", "cim", "fused")
        }
        assert not np.array_equal(np.asarray(out["fused"]), np.asarray(out["host"]))
        assert not np.array_equal(np.asarray(out["fused"]), np.asarray(out["cim"]))


class TestFusedBackendProtocol:
    def test_need_flips_false_same_u(self):
        backend = samplers.FusedRandomness(p_bfr=0.45)
        key = jax.random.PRNGKey(4)
        flips, u_full = backend.chunk(key, 3, 5, (2, 7), nbits=6)
        none, u_lean = backend.chunk(
            key, 3, 5, (2, 7), nbits=6, need_flips=False
        )
        assert none is None
        assert flips.dtype == jnp.uint32
        np.testing.assert_array_equal(np.asarray(u_full), np.asarray(u_lean))

    def test_chunk_concatenation_is_stream_slice(self):
        backend = samplers.FusedRandomness()
        key = jax.random.PRNGKey(8)
        _, u_all = backend.chunk(key, 0, 10, (3,), nbits=4)
        _, u_a = backend.chunk(key, 0, 4, (3,), nbits=4)
        _, u_b = backend.chunk(key, 4, 6, (3,), nbits=4)
        np.testing.assert_array_equal(
            np.asarray(u_all), np.concatenate([u_a, u_b])
        )

    def test_make_backend_dispatch(self):
        backend = samplers.make_randomness_backend("fused", p_bfr=0.3)
        assert isinstance(backend, samplers.FusedRandomness)
        assert backend.name == "fused"
        with pytest.raises(ValueError, match="host|cim|fused"):
            samplers.make_randomness_backend("hw", p_bfr=0.3)

    def test_one_replica_tempered_ladder_degenerates(self):
        model = SpinGlass.bimodal(jax.random.PRNGKey(1), 4, 4)
        init = model.random_init(jax.random.PRNGKey(2), 2)
        key = jax.random.PRNGKey(3)
        engine = _engine(update="gibbs", chunk_steps=8)
        rex = tempering.ReplicaExchange(
            ladder=tempering.Ladder((1.0,)), engine=engine, swap_every=7
        )
        tempered = rex.run(key, model, 25, init[None])
        plain = engine.run(key, model, 25, init)
        np.testing.assert_array_equal(
            np.asarray(tempered.samples[0]), np.asarray(plain.samples)
        )


class TestFusedStreamStatistics:
    """Empirical quality of the cipher stream against the paper's <1e-5
    uniformity budget: the fused conversion is analytically unbiased, so
    the checks bound CLT sampling noise around the exact targets."""

    N = 1 << 21  # draws per check; CLT sigma for a bit mean is ~3.5e-4

    def _uniforms(self, seed=0):
        k0, k1 = rng.key_words(jax.random.PRNGKey(seed))
        s0, s1 = rng.step_key(k0, k1, jnp.uint32(0))
        return np.asarray(rng.uniform_at(s0, s1, rng.site_index((self.N,))))

    @pytest.mark.slow
    def test_uniform_mean_and_ks(self):
        u = self._uniforms()
        # mean: exact target 0.5 - 2^-25 (midpoint of the 2^24 grid)
        assert abs(u.mean() - 0.5) < 5 * (1 / np.sqrt(12 * self.N))
        from scipy import stats

        d, p = stats.kstest(u, "uniform")
        assert p > 1e-4, f"KS rejects uniformity: D={d}, p={p}"

    @pytest.mark.slow
    def test_flip_plane_frequencies(self):
        p_bfr = 0.45
        k0, k1 = rng.key_words(jax.random.PRNGKey(1))
        s0, s1 = rng.step_key(k0, k1, jnp.uint32(0))
        words = np.asarray(
            rng.flips_at(
                s0, s1, rng.site_index((self.N,)), 8,
                rng.threshold_u32(p_bfr),
            )
        )
        # threshold_u32 quantises p to 2^-32 — bias < 1e-5 by construction
        assert abs(rng.threshold_u32(p_bfr) / 2**32 - p_bfr) < 1e-5
        sigma = np.sqrt(p_bfr * (1 - p_bfr) / self.N)
        for b in range(8):
            freq = ((words >> b) & 1).mean()
            assert abs(freq - p_bfr) < 5 * sigma, f"plane {b}: {freq}"

    @pytest.mark.slow
    def test_uniform_bit_planes_unbiased(self):
        u = self._uniforms(seed=2)
        bits = (u * (1 << 24)).astype(np.uint32)
        sigma = 0.5 / np.sqrt(self.N)
        for b in range(24):
            freq = ((bits >> b) & 1).mean()
            assert abs(freq - 0.5) < 5 * sigma, f"bit {b}: {freq}"

    @pytest.mark.slow
    @given(st.integers(0, 2**31 - 1), st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_any_key_and_step_stays_uniform(self, seed, step):
        k0, k1 = rng.key_words(jax.random.PRNGKey(seed))
        s0, s1 = rng.step_key(k0, k1, jnp.uint32(step))
        u = np.asarray(
            rng.uniform_at(s0, s1, rng.site_index((1 << 16,)))
        )
        assert u.min() >= 0.0 and u.max() < 1.0
        assert abs(u.mean() - 0.5) < 5 / np.sqrt(12 * (1 << 16))

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_salts_decorrelate_streams(self, seed):
        """The u draw and every flip plane use distinct salts — no site's
        uniform can be reconstructed from its flip word."""
        k0, k1 = rng.key_words(jax.random.PRNGKey(seed))
        s0, s1 = rng.step_key(k0, k1, jnp.uint32(0))
        site = rng.site_index((256,))
        u_bits = np.asarray(rng.raw_draw(s0, s1, site, rng.U_SALT))
        f_bits = np.asarray(rng.raw_draw(s0, s1, site, rng.FLIP_SALT))
        assert not np.array_equal(u_bits, f_bits)
