"""The telemetry subsystem (DESIGN.md §Telemetry): tracing core, metrics
registry, health monitor — and the two contracts the instrumentation
must honour: the sampled stream is bit-identical with telemetry on vs
off, and the exporters emit valid, schema-checked files."""

import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import samplers, telemetry
from repro.checkpoint import run_resumable
from repro.diagnostics import SwapStats
from repro.launch import monitor as monitor_cli
from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.tracing import Tracer
from repro.workloads.ising import IsingModel

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(autouse=True)
def _clean_global_telemetry():
    """Tests share the process-default tracer/registry: leave both off
    and empty regardless of what a test did."""
    yield
    telemetry.disable()
    telemetry.TRACER.reset()
    telemetry.REGISTRY.reset()


def _mh_setup(seed=0):
    table = jax.random.normal(jax.random.PRNGKey(seed), (2, 64), jnp.float32)
    target = samplers.TableTarget(table)
    init = jnp.broadcast_to(
        jnp.argmax(table, -1).astype(jnp.uint32)[:, None], (2, 8)
    )
    return target, init


def _gibbs_setup(seed=1):
    model = IsingModel(height=6, width=6)
    return model, model.random_init(jax.random.PRNGKey(seed), 2)


# --------------------------------------------------------------------------
# tracing core
# --------------------------------------------------------------------------


class TestTracer:
    def test_span_nesting_and_ordering(self):
        tr = Tracer()
        tr.enabled = True
        with tr.span("outer", a=1):
            with tr.span("inner"):
                pass
            with tr.span("inner2"):
                pass
        evs = tr.events()
        # spans record on exit: inner events precede the outer one
        assert [e.name for e in evs] == ["inner", "inner2", "outer"]
        assert [e.depth for e in evs] == [1, 1, 0]
        assert [e.seq for e in evs] == [0, 1, 2]
        assert all(e.dur_us >= 0 for e in evs)
        outer = evs[-1]
        assert outer.meta == {"a": 1}
        # the outer span covers its children in time
        assert outer.ts_us <= evs[0].ts_us
        assert outer.ts_us + outer.dur_us >= evs[1].ts_us + evs[1].dur_us

    def test_disabled_span_is_shared_noop(self):
        tr = Tracer()
        s1 = tr.span("x", big=1)
        s2 = tr.span("y")
        assert s1 is s2  # no allocation on the disabled path
        with s1 as s:
            s.set(late="metadata")  # no-op parity with the live span
        assert tr.events() == []

    def test_late_metadata_via_set(self):
        tr = Tracer()
        tr.enabled = True
        with tr.span("submit") as sp:
            sp.set(jit_cache="miss")
        (ev,) = tr.events()
        assert ev.meta["jit_cache"] == "miss"

    def test_meta_cleaned_to_json_scalars(self):
        tr = Tracer()
        tr.enabled = True
        with tr.span("s", arr=np.arange(3), ok=2.5, flag=True, none=None):
            pass
        (ev,) = tr.events()
        assert ev.meta["ok"] == 2.5 and ev.meta["flag"] is True
        assert ev.meta["none"] is None
        assert isinstance(ev.meta["arr"], str)  # repr()'d, never a crash
        json.dumps(ev.to_json())  # always serialisable

    def test_ring_overflow_drops_oldest(self):
        tr = Tracer(capacity=4)
        tr.enabled = True
        for i in range(7):
            tr.instant(f"e{i}")
        evs = tr.events()
        assert len(evs) == 4
        assert [e.name for e in evs] == ["e3", "e4", "e5", "e6"]
        assert tr.dropped == 3

    def test_reset_restarts_epoch_and_seq(self):
        tr = Tracer()
        tr.enabled = True
        tr.instant("a")
        tr.reset()
        assert tr.events() == [] and tr.dropped == 0
        tr.instant("b")
        assert tr.events()[0].seq == 0

    def test_export_jsonl_roundtrip_and_validate(self, tmp_path):
        tr = Tracer()
        tr.enabled = True
        with tr.span("s", k="v"):
            tr.instant("i", n=2)
        path = str(tmp_path / "out.trace.jsonl")
        n = tr.export_jsonl(path)
        assert n == 2
        assert telemetry.validate_jsonl(path) == []
        lines = [json.loads(line) for line in open(path)]
        assert lines[0]["kind"] == "trace_meta"
        assert lines[0]["schema"] == telemetry.SCHEMA_VERSION
        assert lines[0]["events"] == 2 and lines[0]["dropped"] == 0

    def test_export_chrome_trace_is_valid(self, tmp_path):
        tr = Tracer()
        tr.enabled = True
        with tr.span("seg", step0=4):
            tr.instant("mark")
        path = str(tmp_path / "out.trace.json")
        tr.export_chrome_trace(path)
        with open(path) as f:
            doc = json.load(f)
        evs = doc["traceEvents"]
        assert len(evs) == 2
        span = next(e for e in evs if e["ph"] == "X")
        assert span["name"] == "seg" and span["dur"] >= 0
        assert {"ts", "pid", "tid"} <= span.keys()
        inst = next(e for e in evs if e["ph"] == "i")
        assert inst["name"] == "mark"
        assert doc["otherData"]["schema"] == telemetry.SCHEMA_VERSION

    def test_export_format_by_extension(self, tmp_path):
        tr = Tracer()
        tr.enabled = True
        tr.instant("x")
        chrome = str(tmp_path / "a.json")
        jsonl = str(tmp_path / "a.trace.jsonl")
        tr.export(chrome)
        tr.export(jsonl)
        json.load(open(chrome))  # one JSON object
        assert telemetry.validate_jsonl(jsonl) == []

    def test_validate_rejects_bad_events(self, tmp_path):
        bad = tmp_path / "bad.jsonl"
        bad.write_text(
            json.dumps({"kind": "span", "name": "s", "ts_us": 0.0, "seq": 0})
            + "\nnot json\n"
            + json.dumps({"kind": "mystery", "name": "x"})
            + "\n"
        )
        problems = telemetry.validate_jsonl(str(bad))
        assert len(problems) == 3  # span w/o dur, non-JSON, unknown kind
        empty = tmp_path / "empty.jsonl"
        empty.write_text("")
        assert telemetry.validate_jsonl(str(empty)) == ["empty trace file"]

    def test_log_records_instant_only_when_enabled(self):
        tr = Tracer()
        tr.log("quiet", a=1)
        assert tr.events() == []
        tr.enabled = True
        tr.log("loud", a=1)
        (ev,) = tr.events()
        assert ev.kind == "instant" and ev.meta == {"a": 1}


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------


class TestMetrics:
    def test_counter_label_aggregation(self):
        reg = MetricsRegistry()
        c = reg.counter("requests_total")
        c.inc(workload="ising")
        c.inc(2, workload="ising")
        c.inc(workload="gmm")
        c.inc()  # label-less series is its own bucket
        assert c.value(workload="ising") == 3
        assert c.value(workload="gmm") == 1
        assert c.value() == 1
        snap = reg.snapshot()["requests_total"]
        assert snap["type"] == "counter"
        assert snap["values"]["workload=ising"] == 3

    def test_counter_rejects_negative(self):
        with pytest.raises(ValueError):
            MetricsRegistry().counter("c").inc(-1)

    def test_gauge_overwrites(self):
        g = MetricsRegistry().gauge("depth")
        g.set(3)
        g.set(1)
        assert g.value() == 1

    def test_histogram_buckets_and_stats(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat", buckets=(0.01, 0.1, 1.0))
        for v in (0.005, 0.05, 0.5, 5.0):
            h.observe(v, workload="ising")
        stats = h.snapshot()["workload=ising"]
        assert stats["count"] == 4
        assert stats["sum"] == pytest.approx(5.555)
        assert stats["buckets"] == {
            "le_0.01": 1, "le_0.1": 1, "le_1": 1, "le_inf": 1
        }

    def test_registry_typechecks_reuse(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_prometheus_text(self):
        reg = MetricsRegistry()
        reg.counter("req_total", "requests").inc(2, workload="ising")
        reg.histogram("lat_s", buckets=(0.1, 1.0)).observe(0.05)
        text = reg.prometheus_text()
        assert '# TYPE req_total counter' in text
        assert 'req_total{workload="ising"} 2' in text
        # cumulative le buckets + sum/count series
        assert 'lat_s_bucket{le="0.1"} 1' in text
        assert 'lat_s_bucket{le="+Inf"} 1' in text
        assert 'lat_s_count 1' in text

    def test_flush_jsonl_appends_snapshots(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("n").inc()
        path = str(tmp_path / "metrics.jsonl")
        reg.flush_jsonl(path)
        reg.counter("n").inc()
        reg.flush_jsonl(path)
        lines = [json.loads(line) for line in open(path)]
        assert len(lines) == 2
        assert lines[0]["metrics"]["n"]["values"][""] == 1
        assert lines[1]["metrics"]["n"]["values"][""] == 2

    def test_jsonl_flusher_rate_limits(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("n").inc()
        path = str(tmp_path / "m.jsonl")
        fl = telemetry.JsonlFlusher(reg, path, interval_s=3600.0)
        assert fl.maybe_flush() is True
        assert fl.maybe_flush() is False  # within the interval
        fl.close()  # final snapshot is unconditional
        assert len(open(path).readlines()) == 2


# --------------------------------------------------------------------------
# health monitor
# --------------------------------------------------------------------------


class TestHealth:
    def test_acceptance_collapse_warns(self):
        mon = telemetry.HealthMonitor()
        with pytest.warns(telemetry.SamplerHealthWarning, match="collapse"):
            alerts = mon.check_acceptance(0.0, where="ising")
        assert [a.kind for a in alerts] == ["acceptance_collapse"]
        assert alerts[0].severity == "critical"
        assert alerts[0].data["rate"] == 0.0
        assert mon.alerts == alerts

    def test_healthy_rate_is_silent(self):
        mon = telemetry.HealthMonitor()
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert mon.check_acceptance(0.3) == []

    def test_acceptance_saturation_threshold(self):
        mon = telemetry.HealthMonitor(
            telemetry.HealthThresholds(max_acceptance=0.99), warn=False
        )
        assert [a.kind for a in mon.check_acceptance(0.999)] == [
            "acceptance_saturated"
        ]

    def test_rhat_divergence_from_dict_and_nonfinite(self):
        mon = telemetry.HealthMonitor(warn=False)
        assert mon.check_chain_stats({"split_rhat": 1.01}) == []
        (a,) = mon.check_chain_stats({"split_rhat": 2.5}, where="gmm")
        assert a.kind == "rhat_divergence" and "gmm" in a.message
        (b,) = mon.check_chain_stats({"split_rhat": float("nan")})
        assert b.kind == "rhat_divergence"

    def test_swap_bottleneck_and_stalled_walkers(self):
        stats = SwapStats(3, ())
        attempted = np.array([True, False])
        rejected = np.zeros((2,), bool)
        for _ in range(10):  # ≥ stall_events rejected swap events
            stats.record(attempted, rejected)
        mon = telemetry.HealthMonitor(warn=False)
        kinds = [a.kind for a in mon.check_swap_stats(stats)]
        assert kinds == ["swap_bottleneck", "stalled_walkers"]
        pair0 = mon.alerts[0]
        assert pair0.data["pair"] == 0 and pair0.data["rate"] == 0.0

    def test_untried_pair_is_not_a_bottleneck(self):
        stats = SwapStats(3, ())  # no events at all: rates are NaN
        mon = telemetry.HealthMonitor(warn=False)
        assert mon.check_swap_stats(stats) == []

    def test_serving_slo_breaches(self):
        mon = telemetry.HealthMonitor(
            telemetry.HealthThresholds(
                p99_latency_slo_s=1.0, max_wait_slo_s=0.5
            ),
            warn=False,
        )
        summary = {"p99_latency_s": 2.0, "p99_wait_s": 0.7}
        kinds = [a.kind for a in mon.check_serving(summary)]
        assert kinds == ["latency_slo_breach", "wait_slo_breach"]
        assert mon.alerts[0].severity == "critical"
        # within SLO: silent
        assert (
            mon.check_serving({"p99_latency_s": 0.5, "p99_wait_s": 0.1}) == []
        )

    def test_alerts_counted_in_metrics(self):
        mon = telemetry.HealthMonitor(warn=False)
        mon.check_acceptance(0.0)
        c = telemetry.REGISTRY.counter("sampler_health_alerts_total")
        assert c.value(kind="acceptance_collapse") == 1


# --------------------------------------------------------------------------
# instrumented layers: bit-parity + emitted events
# --------------------------------------------------------------------------


class TestInstrumentation:
    @pytest.mark.parametrize("update", ["mh", "gibbs"])
    def test_submit_bit_parity_tracing_on_vs_off(self, update):
        """The overhead contract's numerical half: tracing must never
        touch the sampled stream."""
        target, init = _gibbs_setup() if update == "gibbs" else _mh_setup()
        engine = samplers.MHEngine(
            samplers.EngineConfig(update=update, chunk_steps=8)
        )
        plan = samplers.RunPlan(
            target=target, n_steps=20, init_words=init, seed=5
        )
        off = engine.submit(plan).result
        telemetry.enable()
        on = engine.submit(plan).result
        telemetry.disable()
        np.testing.assert_array_equal(
            np.asarray(off.samples), np.asarray(on.samples)
        )
        np.testing.assert_array_equal(
            np.asarray(off.final_words), np.asarray(on.final_words)
        )
        np.testing.assert_array_equal(
            np.asarray(off.final_logp), np.asarray(on.final_logp)
        )

    def test_submit_span_carries_plan_metadata(self):
        target, init = _mh_setup()
        engine = samplers.MHEngine(samplers.EngineConfig(chunk_steps=8))
        plan = samplers.RunPlan(
            target=target, n_steps=12, init_words=init, seed=2
        )
        tr = telemetry.enable()
        engine.submit(plan)
        spans = [e for e in tr.events() if e.name == "engine.submit"]
        assert len(spans) == 1
        meta = spans[0].meta
        assert meta["n_steps"] == 12 and meta["update"] == "mh"
        assert meta["compiled"] is False

    def test_compiled_submit_records_jit_cache_verdict(self):
        target, init = _mh_setup()
        engine = samplers.MHEngine(samplers.EngineConfig(chunk_steps=8))
        plan = samplers.RunPlan(
            target=target, n_steps=12, init_words=init, seed=2
        )
        tr = telemetry.enable()
        engine.submit(plan, compiled=True)
        engine.submit(plan, compiled=True)
        verdicts = [
            e.meta.get("jit_cache")
            for e in tr.events()
            if e.name == "engine.submit"
        ]
        assert verdicts == ["miss", "hit"]

    def test_run_resumable_emits_segment_logs(self, tmp_path):
        target, init = _mh_setup()
        engine = samplers.MHEngine(samplers.EngineConfig(chunk_steps=8))
        plan = samplers.RunPlan(
            target=target, n_steps=16, init_words=init, seed=7
        )
        tr = telemetry.enable()
        run_resumable(engine, plan, directory=str(tmp_path), every=8)
        segs = [e for e in tr.events() if e.name == "run_resumable.segment"]
        assert len(segs) == 2
        assert [e.meta["segment"] for e in segs] == [0, 1]
        assert [e.meta["done"] for e in segs] == [8, 16]
        for e in segs:
            assert e.meta["bytes"] > 0
            assert len(e.meta["fingerprint"]) == 12  # sha256 digest prefix
        saves = [e for e in tr.events() if e.name == "checkpoint.save"]
        assert len(saves) == 2 and all(e.meta["bytes"] > 0 for e in saves)

    def test_run_resumable_restore_log_and_parity(self, tmp_path):
        target, init = _mh_setup()
        engine = samplers.MHEngine(samplers.EngineConfig(chunk_steps=8))
        plan = samplers.RunPlan(
            target=target, n_steps=16, init_words=init, seed=7
        )
        ref = engine.submit(plan).result
        boom = RuntimeError("preempted")

        def die_once(done, total, handle):
            if done == 8:
                raise boom

        with pytest.raises(RuntimeError):
            run_resumable(
                engine, plan, directory=str(tmp_path), every=8,
                on_segment=die_once,
            )
        tr = telemetry.enable()
        handle = run_resumable(engine, plan, directory=str(tmp_path), every=8)
        restores = [
            e for e in tr.events() if e.name == "run_resumable.restore"
        ]
        assert len(restores) == 1 and restores[0].meta["done"] == 8
        np.testing.assert_array_equal(
            np.asarray(handle.result.final_words), np.asarray(ref.final_words)
        )

    def test_serving_emits_segment_spans_and_latency_split(self):
        from repro.serving import Scheduler, ServeRequest, latency_summary

        tr = telemetry.enable()
        sched = Scheduler(n_slots=2, smoke=True, workload_kwargs={})
        reqs = [
            ServeRequest(rid=i, workload="gmm", n_steps=8, seed=i)
            for i in range(2)
        ]
        done = sched.serve(reqs)
        assert all(r.t_done is not None for r in done)
        for r in done:
            assert r.service_s is not None and r.service_s >= 0
            assert abs(r.wait_s + r.service_s - r.latency_s) < 1e-9
        summary = latency_summary(done)
        for k in (
            "p99_wait_s", "mean_service_s", "p50_service_s", "p99_service_s"
        ):
            assert k in summary
        names = {e.name for e in tr.events()}
        assert "serving.segment" in names and "serving.finalize" in names
        reg = telemetry.REGISTRY
        assert reg.counter("serving_requests_admitted_total").value(
            workload="gmm"
        ) == 2
        assert reg.counter("serving_requests_retired_total").value() == 2

    def test_tempering_emits_swap_spans(self):
        from repro import tempering

        model, init1 = _gibbs_setup()
        engine = samplers.MHEngine(
            samplers.EngineConfig(update="gibbs", chunk_steps=8)
        )
        ladder = tempering.Ladder.geometric(2, beta_min=0.5)
        rex = tempering.ReplicaExchange(
            ladder=ladder, engine=engine, swap_every=8
        )
        init = jnp.broadcast_to(init1, (2, *init1.shape))
        tr = telemetry.enable()
        rex.run(jax.random.PRNGKey(0), model, 24, init)
        names = [e.name for e in tr.events()]
        assert names.count("tempering.segment") == 3
        assert names.count("tempering.swap") == 2


# --------------------------------------------------------------------------
# monitor CLI
# --------------------------------------------------------------------------


class TestMonitorCLI:
    def _write_trace(self, tmp_path) -> str:
        tr = telemetry.enable()
        with tr.span("engine.submit", n_steps=4):
            pass
        tr.log("health.rhat_divergence", split_rhat=2.0)
        path = str(tmp_path / "out.trace.jsonl")
        tr.export_jsonl(path)
        telemetry.disable()
        return path

    def test_check_valid_trace(self, tmp_path, capsys):
        path = self._write_trace(tmp_path)
        assert monitor_cli.main(["--check", path]) == 0
        assert "valid trace" in capsys.readouterr().out

    def test_check_invalid_trace_exits_nonzero(self, tmp_path, capsys):
        bad = tmp_path / "bad.trace.jsonl"
        bad.write_text('{"kind": "span", "name": ""}\n')
        assert monitor_cli.main(["--check", str(bad)]) == 1
        assert "INVALID" in capsys.readouterr().out

    def test_summary_aggregates_spans(self, tmp_path, capsys):
        path = self._write_trace(tmp_path)
        assert monitor_cli.main([path]) == 0
        out = capsys.readouterr().out
        assert "span=engine.submit" in out and "count=1" in out
        assert "health.rhat_divergence" in out

    def test_summarize_events_shares(self):
        events = [
            {"kind": "span", "name": "a", "dur_us": 30.0},
            {"kind": "span", "name": "a", "dur_us": 10.0},
            {"kind": "span", "name": "b", "dur_us": 60.0},
        ]
        rows = monitor_cli.summarize_events(events)
        assert rows[0]["span"] == "b" and rows[0]["share"] == 0.6
        assert rows[1]["span"] == "a" and rows[1]["count"] == 2
