"""End-to-end behaviour: training learns, serving serves, the paper's
sampler samples faithfully in decode position."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import token_sampler
from repro.data import DataConfig, MarkovSource
from repro.launch.serve import BatchedServer, Request, ServeConfig
from repro.launch.train import TrainRun, run_training


class TestTrainingLearns:
    def test_loss_decreases_on_markov_data(self):
        """A tiny dense LM must learn bigram structure: final loss well
        below initial and approaching the chain's entropy floor."""
        cfg = configs.get_smoke_config("granite3_8b")
        run = TrainRun(
            cfg=cfg, steps=120, global_batch=16, seq_len=64, lr=1e-2,
            warmup=10, log_every=1000,
        )
        _, _, losses = run_training(run)
        first = float(np.mean(losses[:5]))
        last = float(np.mean(losses[-5:]))
        floor = MarkovSource(
            DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=16)
        ).entropy_per_token()
        assert last < first - 0.5, (first, last)
        assert last > floor * 0.5  # sanity: can't beat the entropy floor by 2x

    def test_moe_trains(self):
        cfg = configs.get_smoke_config("phi35_moe_42b")
        run = TrainRun(
            cfg=cfg, steps=40, global_batch=8, seq_len=32, lr=3e-3,
            warmup=10, log_every=1000,
        )
        _, _, losses = run_training(run)
        assert float(np.mean(losses[-5:])) < float(np.mean(losses[:5]))

    def test_microbatched_equals_full_batch(self):
        """Gradient accumulation must match the single-batch step."""
        from repro.models import lm
        from repro.optim import AdamWConfig, adamw_init
        from repro.training.step import TrainStepConfig, make_train_step

        cfg = configs.get_smoke_config("minitron_4b")
        vals, axes = lm.init_lm_values(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        opt_cfg = AdamWConfig(lr=1e-3)

        outs = {}
        for n_micro in (1, 4):
            step = jax.jit(
                make_train_step(
                    cfg, axes, opt_cfg, step_cfg=TrainStepConfig(n_micro=n_micro)
                )
            )
            v2, _, m = step(vals, adamw_init(vals, opt_cfg), batch)
            outs[n_micro] = (float(m["loss"]), v2)
        assert outs[1][0] == pytest.approx(outs[4][0], rel=1e-5)
        # accumulation reorders float sums; Adam's rsqrt amplifies the ulps —
        # parameters agree to 1e-3 after one update (loss agrees to 1e-5)
        for a, b in zip(jax.tree.leaves(outs[1][1]), jax.tree.leaves(outs[4][1])):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-3
            )


class TestServing:
    def test_batched_mcmc_serving(self):
        cfg = configs.get_smoke_config("granite3_8b")
        scfg = ServeConfig(n_slots=3, max_len=48, gen_tokens=6, sampler="mcmc")
        server = BatchedServer(cfg, scfg)
        rng = np.random.default_rng(0)
        for rid in range(3):
            server.submit(
                rid, Request(rid=rid, prompt=rng.integers(0, cfg.vocab_size, 8))
            )
        finished = []
        while server.active():
            finished.extend(server.step())  # step() frees retired slots
        assert len(finished) == 3
        for r in finished:
            assert len(r.out_tokens) == 7  # first + 6 generated
            assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)
        assert server.acceptance, "MCMC sampler must report acceptance"

    def test_greedy_serving(self):
        cfg = configs.get_smoke_config("mamba2_1p3b")
        scfg = ServeConfig(n_slots=1, max_len=32, gen_tokens=4, sampler="greedy")
        server = BatchedServer(cfg, scfg)
        server.submit(0, Request(rid=0, prompt=np.arange(6) % cfg.vocab_size))
        finished = []
        while server.active():
            finished.extend(server.step())
        assert len(finished[0].out_tokens) == 5


class TestTokenSamplerFidelity:
    def test_matches_softmax_distribution(self):
        """The paper's softmax-free chain must converge to the same
        distribution as explicit softmax sampling."""
        key = jax.random.PRNGKey(0)
        vocab = 64
        logits = jnp.asarray(
            np.random.default_rng(1).normal(size=(1, vocab)) * 1.5, jnp.float32
        )
        cfg = token_sampler.TokenSamplerConfig(vocab_size=vocab, n_steps=300)
        counts = np.zeros(vocab)
        n_runs = 400
        keys = jax.random.split(key, n_runs)
        sample = jax.jit(lambda k: token_sampler.sample_tokens(k, logits, cfg).tokens)
        for k in keys:
            counts[int(sample(k)[0])] += 1
        emp = counts / counts.sum()
        ref = np.asarray(jax.nn.softmax(logits[0]))
        tv = 0.5 * np.abs(emp - ref).sum()
        assert tv < 0.15, f"TV {tv}"

    def test_never_out_of_vocab(self):
        """Vocab 100 < 2^7 = 128: detailed balance on the valid set."""
        key = jax.random.PRNGKey(2)
        logits = jax.random.normal(key, (16, 100))
        cfg = token_sampler.TokenSamplerConfig(vocab_size=100, n_steps=50)
        res = token_sampler.sample_tokens(key, logits, cfg)
        assert int(jnp.max(res.tokens)) < 100

    def test_top_k_restriction(self):
        key = jax.random.PRNGKey(3)
        logits = jnp.asarray(np.linspace(0, 10, 32)[None, :], jnp.float32)
        cfg = token_sampler.TokenSamplerConfig(vocab_size=32, n_steps=64, top_k=4)
        res = token_sampler.sample_tokens(key, logits, cfg)
        assert int(res.tokens[0]) >= 28  # only the top-4 ids are reachable

    def test_greedy_limit_low_temperature(self):
        key = jax.random.PRNGKey(4)
        logits = jax.random.normal(key, (8, 50)) * 0.1
        logits = logits.at[:, 17].set(5.0)
        cfg = token_sampler.TokenSamplerConfig(
            vocab_size=50, n_steps=128, temperature=0.05
        )
        res = token_sampler.sample_tokens(key, logits, cfg)
        assert np.mean(np.asarray(res.tokens) == 17) > 0.9
