"""Model-layer correctness: attention oracle, SSD equivalence, MoE combine,
prefill/decode consistency, scan/unroll equality."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.layers import activation

COMMON = dict(
    dtype="float32",
    param_dtype_str="float32",
    cache_dtype_str="float32",
    attn_block_q=8,
    attn_block_kv=8,
    logits_chunk=16,
    remat_policy="none",
)


def naive_attention(q, k, v, causal, window, sk_valid=None):
    """Dense-softmax oracle. q: (B,S,KV,R,dh), k/v: (B,S,KV,dh)."""
    b, sq, kvh, r, dh = q.shape
    sk = k.shape[1]
    s = jnp.einsum("bqkrd,bskd->bkrqs", q, k) / jnp.sqrt(dh)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if sk_valid is not None:
        mask &= k_pos < sk_valid
    if causal:
        mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkrqs,bskd->bqkrd", p, v)


class TestFlashAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("sq,sk", [(16, 16), (16, 32), (20, 20), (8, 24)])
    def test_matches_naive(self, causal, sq, sk):
        key = jax.random.PRNGKey(0)
        b, kvh, r, dh = 2, 2, 2, 8
        q = jax.random.normal(key, (b, sq, kvh, r, dh))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, sk, kvh, dh))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, sk, kvh, dh))
        out = attn_mod.flash_attention(
            q, k, v, causal=causal, window=None, q_offset=0, block_q=8, block_kv=8
        )
        ref = naive_attention(q, k, v, causal, None)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    def test_sliding_window(self):
        key = jax.random.PRNGKey(3)
        b, s, kvh, r, dh = 1, 32, 1, 1, 8
        q = jax.random.normal(key, (b, s, kvh, r, dh))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, dh))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, dh))
        out = attn_mod.flash_attention(
            q, k, v, causal=True, window=jnp.int32(8), q_offset=0,
            block_q=8, block_kv=8,
        )
        ref = naive_attention(q, k, v, True, 8)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    def test_causal_skip_variant_matches(self):
        key = jax.random.PRNGKey(4)
        b, s, kvh, r, dh = 1, 64, 2, 1, 8
        q = jax.random.normal(key, (b, s, kvh, r, dh))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, dh))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, dh))
        base = attn_mod.flash_attention(
            q, k, v, causal=True, window=None, q_offset=0, block_q=16, block_kv=16
        )
        skip = attn_mod.flash_attention(
            q, k, v, causal=True, window=None, q_offset=0, block_q=16,
            block_kv=16, unroll_causal_skip=True,
        )
        np.testing.assert_allclose(np.asarray(base), np.asarray(skip), atol=2e-5)


class TestSSM:
    def _cfg(self):
        return ModelConfig(
            name="s", family="ssm", n_layers=1, d_model=32, vocab_size=64,
            ssm_heads=4, ssm_head_dim=8, ssm_state=8, ssm_chunk=8, **COMMON,
        )

    def test_chunked_matches_recurrence(self):
        cfg = self._cfg()
        params = ssm_mod.init_mamba2(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32))
        y_chunk, _ = ssm_mod.mamba2_full(params, x, cfg)
        y_ref = ssm_mod.mamba2_reference(params, x, cfg)
        np.testing.assert_allclose(
            np.asarray(y_chunk), np.asarray(y_ref), atol=3e-5
        )

    def test_prefill_then_decode_matches_full(self):
        cfg = self._cfg()
        params = ssm_mod.init_mamba2(jax.random.PRNGKey(2), cfg)
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 32))
        y_full, _ = ssm_mod.mamba2_full(params, x, cfg)
        cache = ssm_mod.init_ssm_cache(cfg, 1, dtype=jnp.float32)
        y_pre, cache = ssm_mod.mamba2_full(params, x[:, :8], cfg, cache)
        outs = [y_pre]
        for t in range(8, 16):
            o, cache = ssm_mod.mamba2_decode(params, x[:, t : t + 1], cfg, cache)
            outs.append(o)
        y_inc = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(y_full), np.asarray(y_inc), atol=3e-5
        )


class TestMoE:
    def _cfg(self, cap_factor=8.0):
        return ModelConfig(
            name="m", family="moe", n_layers=1, d_model=32, n_heads=2,
            n_kv_heads=2, d_head=16, d_ff=16, vocab_size=64, n_experts=4,
            moe_top_k=2, moe_capacity_factor=cap_factor, **COMMON,
        )

    def test_capacity_dispatch_matches_dense(self):
        """With capacity high enough to drop nothing, the sorted-dispatch
        path must equal the dense all-experts oracle exactly."""
        cfg = self._cfg(cap_factor=8.0)
        params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        out, _ = moe_mod.moe_ffn_local(params, x, cfg, activation("silu"))
        ref = moe_mod.moe_dense_reference(params, x, cfg, activation("silu"))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

    def test_overflow_drops_are_bounded(self):
        """Tight capacity drops tokens but output stays finite & bounded."""
        cfg = self._cfg(cap_factor=0.5)
        params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        out, aux = moe_mod.moe_ffn_local(params, x, cfg, activation("silu"))
        assert bool(jnp.all(jnp.isfinite(out)))
        assert float(aux) > 0

    def test_aux_loss_uniform_routing_floor(self):
        """Perfectly uniform routing gives aux ~= 1 (Switch normalisation)."""
        cfg = self._cfg()
        params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 64, 32))
        _, _, aux = moe_mod.route(
            np.zeros((32, 4), np.float32) + params["router"].value * 0,
            x.reshape(-1, 32),
            2,
        )
        assert float(aux) == pytest.approx(1.0, abs=0.3)


class TestLMConsistency:
    def _dense_cfg(self):
        return ModelConfig(
            name="d", family="dense", n_layers=2, d_model=32, n_heads=2,
            n_kv_heads=1, d_head=16, d_ff=64, vocab_size=100, **COMMON,
        )

    def test_prefill_decode_matches_full_forward(self):
        """Teacher-forced incremental decode must reproduce the full
        forward logits (KV cache correctness)."""
        cfg = self._dense_cfg()
        vals, _ = lm.init_lm_values(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 100)

        hidden, _, _ = lm.lm_forward(vals, cfg, {"tokens": tokens}, mode="train")
        full_logits = lm.head_logits(vals, cfg, hidden)

        cache = lm.init_cache(cfg, 2, 16)
        logits_p, cache = lm.prefill(vals, cfg, {"tokens": tokens[:, :8]}, cache)
        np.testing.assert_allclose(
            np.asarray(logits_p), np.asarray(full_logits[:, 7]), atol=2e-4
        )
        for t in range(8, 12):
            logits_d, cache = lm.decode_step(vals, cfg, tokens[:, t : t + 1], cache)
            np.testing.assert_allclose(
                np.asarray(logits_d),
                np.asarray(full_logits[:, t]),
                atol=2e-4,
                err_msg=f"decode step {t}",
            )

    def test_scan_equals_unroll(self):
        cfg = self._dense_cfg()
        vals, _ = lm.init_lm_values(jax.random.PRNGKey(2), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, 100)
        batch = {"tokens": tokens, "labels": tokens}
        l1, _ = lm.train_loss(vals, cfg, batch)
        l2, _ = lm.train_loss(
            vals, dataclasses.replace(cfg, scan_layers=False), batch
        )
        assert float(l1) == pytest.approx(float(l2), abs=1e-5)

    def test_remat_does_not_change_loss(self):
        cfg = self._dense_cfg()
        vals, _ = lm.init_lm_values(jax.random.PRNGKey(4), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0, 100)
        batch = {"tokens": tokens, "labels": tokens}

        def loss_for(policy):
            c = dataclasses.replace(cfg, remat_policy=policy)
            val_, grads = jax.value_and_grad(
                lambda v: lm.train_loss(v, c, batch)[0]
            )(vals)
            return float(val_), grads

        l_none, g_none = loss_for("none")
        l_full, g_full = loss_for("nothing")
        assert l_none == pytest.approx(l_full, abs=1e-5)
        gn = jax.tree.leaves(g_none)[0]
        gf = jax.tree.leaves(g_full)[0]
        np.testing.assert_allclose(np.asarray(gn), np.asarray(gf), atol=1e-5)

    def test_vocab_padding_masked(self):
        """Padded vocab columns must never receive probability mass."""
        cfg = self._dense_cfg()  # vocab 100 -> padded 256
        assert cfg.padded_vocab == 256
        vals, _ = lm.init_lm_values(jax.random.PRNGKey(6), cfg)
        hidden = jax.random.normal(jax.random.PRNGKey(7), (1, 4, 32))
        logits = lm.head_logits(vals, cfg, hidden)
        assert logits.shape[-1] == 256
        assert float(logits[..., 100:].max()) <= -1e29

    def test_chunked_ce_matches_direct(self):
        cfg = self._dense_cfg()
        vals, _ = lm.init_lm_values(jax.random.PRNGKey(8), cfg)
        hidden = jax.random.normal(jax.random.PRNGKey(9), (2, 16, 32))
        labels = jax.random.randint(jax.random.PRNGKey(10), (2, 16), 0, 100)
        loss_c, count = lm.chunked_ce_loss(vals, cfg, hidden, labels)
        logits = lm.head_logits(vals, cfg, hidden)
        logz = jax.scipy.special.logsumexp(logits, -1)
        ll = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        loss_ref = jnp.mean(logz - ll)
        assert float(loss_c) == pytest.approx(float(loss_ref), abs=1e-5)
        assert float(count) == 32


class TestPerRowCacheIndex:
    """The (B,)-shaped decode index (cache contract, models/lm.py):
    slots decoding at different positions must each reproduce the
    scalar-index solo decode exactly — the substrate that lets
    launch.serve pack heterogeneous prompt lengths."""

    def _cfg(self):
        return ModelConfig(
            name="d", family="dense", n_layers=2, d_model=32, n_heads=2,
            n_kv_heads=1, d_head=16, d_ff=64, vocab_size=100, **COMMON,
        )

    def test_heterogeneous_decode_matches_scalar_index(self):
        cfg = self._cfg()
        vals, _ = lm.init_lm_values(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 100)
        lens = (5, 9)
        max_len = 20

        # scalar-index solo references, one row at a time
        refs = {0: [], 1: []}
        for r, plen in enumerate(lens):
            cache = lm.init_cache(cfg, 1, max_len)
            _, cache = lm.prefill(
                vals, cfg, {"tokens": tokens[r : r + 1, :plen]}, cache
            )
            for t in range(3):
                logits, cache = lm.decode_step(
                    vals, cfg, tokens[r : r + 1, plen + t : plen + t + 1],
                    cache,
                )
                refs[r].append(np.asarray(logits[0]))

        # packed: splice per-row prefills into one cache, (B,) index
        shared = lm.init_cache(cfg, 2, max_len)
        shared["index"] = jnp.zeros((2,), jnp.int32)
        for r, plen in enumerate(lens):
            row = lm.init_cache(cfg, 1, max_len)
            _, row = lm.prefill(
                vals, cfg, {"tokens": tokens[r : r + 1, :plen]}, row
            )
            shared["layers"] = jax.tree.map(
                lambda s, x: s.at[:, r : r + 1].set(x),
                shared["layers"], row["layers"],
            )
            shared["index"] = shared["index"].at[r].set(
                jnp.asarray(row["index"], jnp.int32)
            )

        for t in range(3):
            step = jnp.stack(
                [tokens[r, lens[r] + t] for r in range(2)]
            )[:, None]
            logits, shared = lm.decode_step(vals, cfg, step, shared)
            for r in range(2):
                np.testing.assert_allclose(
                    np.asarray(logits[r]), refs[r][t], atol=2e-4,
                    err_msg=f"row {r} step {t}",
                )
