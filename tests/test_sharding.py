"""Sharding-rule engine tests (AbstractMesh — no devices needed)."""

import jax
import pytest

if not hasattr(jax.sharding, "AxisType"):
    pytest.skip(
        "jax.sharding.AxisType unavailable on this jax version",
        allow_module_level=True,
    )
from jax.sharding import AbstractMesh, AxisType, PartitionSpec as P

from repro.distributed.sharding import (
    ShardingRules,
    add_zero_axes,
    rules_for_config,
    rules_with_zero,
    spec_for,
)

MESH = AbstractMesh((16, 16), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
MESH3 = AbstractMesh(
    (2, 16, 16), ("pod", "data", "model"), axis_types=(AxisType.Auto,) * 3
)


class TestSpecFor:
    def test_batch_over_pod_data(self):
        spec = spec_for(("batch", "seq"), shape=(256, 4096), mesh=MESH3)
        assert spec == P(("pod", "data"))

    def test_divisibility_fallback(self):
        # 25 heads don't divide 16 -> replicated
        spec = spec_for(
            ("embed", "heads", "head_dim"), shape=(1600, 25, 64), mesh=MESH
        )
        assert spec == P()

    def test_divisible_heads_shard(self):
        spec = spec_for(
            ("embed", "heads", "head_dim"), shape=(4096, 32, 128), mesh=MESH
        )
        assert spec == P(None, "model")

    def test_partial_compound_axis(self):
        # batch=1 can't use pod/data; cache_seq override picks up all three
        rules = ShardingRules().replace(cache_seq=("pod", "data", "model"))
        spec = spec_for(
            ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
            rules,
            shape=(32, 1, 524288, 5, 64),
            mesh=MESH3,
        )
        assert spec == P(None, None, ("pod", "data", "model"))

    def test_used_axis_skipped_not_dropped(self):
        # batch claims pod+data; cache_seq still gets model
        rules = ShardingRules().replace(cache_seq=("pod", "data", "model"))
        spec = spec_for(
            ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
            rules,
            shape=(88, 128, 32768, 1, 128),
            mesh=MESH3,
        )
        assert spec == P(None, ("pod", "data"), "model")

    def test_no_mesh_returns_none(self):
        assert spec_for(("batch",), shape=(8,), mesh=None) is None

    def test_vocab_sharding(self):
        spec = spec_for(("embed", "vocab"), shape=(4096, 49408), mesh=MESH)
        assert spec == P(None, "model")

    def test_odd_vocab_padded_divisible(self):
        # 49155 -> padded 49408 = 256*193; raw odd vocab would replicate
        raw = spec_for(("vocab",), shape=(49155,), mesh=MESH)
        padded = spec_for(("vocab",), shape=(49408,), mesh=MESH)
        assert raw == P()
        assert padded == P("model")


class TestZeroAxes:
    def test_zero_extends_replicated_dim(self):
        axes = add_zero_axes(
            ("embed", "heads", "head_dim"), (4096, 32, 128), mesh=MESH
        )
        assert axes == ("_zero", "heads", "head_dim")
        spec = spec_for(axes, rules_with_zero(), shape=(4096, 32, 128), mesh=MESH)
        assert spec == P(("data",), "model") or spec == P("data", "model")

    def test_zero_skips_indivisible(self):
        axes = add_zero_axes(("heads",), (25,), mesh=MESH)
        assert axes == ("heads",)

    def test_zero_on_3d_mesh(self):
        axes = add_zero_axes(("embed", "ffn"), (4096, 12800), mesh=MESH3)
        assert axes == ("_zero", "ffn")
        spec = spec_for(axes, rules_with_zero(), shape=(4096, 12800), mesh=MESH3)
        assert spec == P(("pod", "data"), "model")


class TestConfigOverrides:
    def test_rules_for_config(self):
        from repro import configs

        cfg = configs.get_config("granite_34b")
        rules = rules_for_config(cfg)
        assert rules.as_dict()["cache_seq"] == ("pod", "data", "model")

    def test_default_rules_unpolluted(self):
        assert ShardingRules().as_dict()["cache_seq"] is None
