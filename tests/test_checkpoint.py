"""Bit-exact resumable runs (checkpoint/resume.py): segmented +
checkpointed == unsegmented, across every update x randomness cell, with
kill/restart, fingerprint refusal, and the collection axis riding along."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import samplers, workloads
from repro.checkpoint import latest_step, run_resumable
from repro.workloads.ising import IsingModel

jax.config.update("jax_platform_name", "cpu")


def _mh_setup(seed=0):
    table = jax.random.normal(jax.random.PRNGKey(seed), (2, 64), jnp.float32)
    target = samplers.TableTarget(table)
    init = jnp.broadcast_to(
        jnp.argmax(table, -1).astype(jnp.uint32)[:, None], (2, 8)
    )
    return target, init


def _gibbs_setup(seed=1):
    model = IsingModel(height=6, width=6)
    return model, model.random_init(jax.random.PRNGKey(seed), 2)


def _assert_bit_identical(got, ref):
    np.testing.assert_array_equal(
        np.asarray(got.samples), np.asarray(ref.samples)
    )
    np.testing.assert_array_equal(
        np.asarray(got.accept_count), np.asarray(ref.accept_count)
    )
    np.testing.assert_array_equal(
        np.asarray(got.acceptance_rate), np.asarray(ref.acceptance_rate)
    )
    np.testing.assert_array_equal(
        np.asarray(got.final_words), np.asarray(ref.final_words)
    )
    np.testing.assert_array_equal(
        np.asarray(got.final_logp), np.asarray(ref.final_logp)
    )


class TestRoundTrip:
    @pytest.mark.parametrize("update", ["mh", "gibbs"])
    @pytest.mark.parametrize("randomness", ["host", "cim", "fused"])
    def test_segmented_equals_unsegmented(self, tmp_path, update, randomness):
        target, init = _gibbs_setup() if update == "gibbs" else _mh_setup()
        engine = samplers.MHEngine(
            samplers.EngineConfig(
                update=update, randomness=randomness, chunk_steps=8
            )
        )
        key = jax.random.PRNGKey(3)
        plan = samplers.RunPlan(
            target=target, n_steps=28, init_words=init, key=key
        )
        ref = engine.submit(plan).result
        handle = run_resumable(
            engine, plan, directory=str(tmp_path), every=10
        )
        _assert_bit_identical(handle.result, ref)

    @pytest.mark.parametrize("collect", [None, "last"])
    def test_multi_chain_round_trip(self, tmp_path, collect):
        """Multi-chain results are chain-major (C, T, *state): segment
        streams must concatenate on the time axis, not the chain axis."""
        target, init = _mh_setup()
        cinit = jnp.broadcast_to(init, (4, *init.shape))
        engine = samplers.MHEngine(
            samplers.EngineConfig(num_chains=4, chunk_steps=8)
        )
        plan = samplers.RunPlan(
            target=target, n_steps=24, init_words=cinit, seed=6,
            collect=collect,
        )
        ref = engine.submit(plan).result
        handle = run_resumable(
            engine, plan, directory=str(tmp_path), every=8
        )
        _assert_bit_identical(handle.result, ref)

    @pytest.mark.parametrize("collect", ["thin:4", "last"])
    def test_collection_axis_round_trip(self, tmp_path, collect):
        target, init = _mh_setup()
        engine = samplers.MHEngine(
            samplers.EngineConfig(chunk_steps=8, collect=collect)
        )
        key = jax.random.PRNGKey(4)
        plan = samplers.RunPlan(
            target=target, n_steps=24, init_words=init, key=key
        )
        ref = engine.submit(plan).result
        handle = run_resumable(engine, plan, directory=str(tmp_path), every=8)
        _assert_bit_identical(handle.result, ref)


class TestKillAndResume:
    def test_killed_run_resumes_bit_exactly(self, tmp_path):
        target, init = _mh_setup()
        engine = samplers.MHEngine(samplers.EngineConfig(chunk_steps=8))
        key = jax.random.PRNGKey(7)
        plan = samplers.RunPlan(
            target=target, n_steps=32, init_words=init, key=key
        )
        ref = engine.submit(plan).result

        class Die(RuntimeError):
            pass

        def die_after_two(done, total, handle):
            if done >= 16:
                raise Die

        with pytest.raises(Die):
            run_resumable(
                engine, plan, directory=str(tmp_path), every=8,
                on_segment=die_after_two,
            )
        # the kill landed after the step-16 checkpoint committed
        assert latest_step(str(tmp_path)) == 16
        handle = run_resumable(engine, plan, directory=str(tmp_path), every=8)
        _assert_bit_identical(handle.result, ref)

    def test_resume_under_retuned_engine(self, tmp_path):
        """chunk_steps/execution are excluded from the resume
        fingerprint: a run checkpointed under one tuning resumes
        bit-exactly under another (the autotuner contract)."""
        target, init = _mh_setup()
        key = jax.random.PRNGKey(8)
        a = samplers.MHEngine(samplers.EngineConfig(chunk_steps=8))
        b = samplers.MHEngine(
            samplers.EngineConfig(chunk_steps=16, execution="scan")
        )
        plan = samplers.RunPlan(
            target=target, n_steps=24, init_words=init, key=key
        )
        ref = a.submit(plan).result

        class Die(RuntimeError):
            pass

        def die_once(done, total, handle):
            if done >= 8:
                raise Die

        with pytest.raises(Die):
            run_resumable(
                a, plan, directory=str(tmp_path), every=8,
                on_segment=die_once,
            )
        handle = run_resumable(b, plan, directory=str(tmp_path), every=8)
        _assert_bit_identical(handle.result, ref)

    def test_completed_run_replays_from_checkpoint(self, tmp_path):
        target, init = _mh_setup()
        engine = samplers.MHEngine(samplers.EngineConfig(chunk_steps=8))
        plan = samplers.RunPlan(
            target=target, n_steps=16, init_words=init, seed=5
        )
        first = run_resumable(engine, plan, directory=str(tmp_path), every=8)
        again = run_resumable(engine, plan, directory=str(tmp_path), every=8)
        _assert_bit_identical(again.result, first.result)


class TestFingerprint:
    def test_mismatched_stream_refused(self, tmp_path):
        target, init = _mh_setup()
        engine = samplers.MHEngine(samplers.EngineConfig(chunk_steps=8))
        plan = samplers.RunPlan(
            target=target, n_steps=16, init_words=init, seed=0
        )
        run_resumable(engine, plan, directory=str(tmp_path), every=8)
        other = plan.replace(seed=1)
        with pytest.raises(ValueError, match="different run"):
            run_resumable(engine, other, directory=str(tmp_path), every=8)

    def test_mismatched_engine_axes_refused(self, tmp_path):
        target, init = _mh_setup()
        a = samplers.MHEngine(samplers.EngineConfig(randomness="cim"))
        b = samplers.MHEngine(samplers.EngineConfig(randomness="host"))
        plan = samplers.RunPlan(
            target=target, n_steps=16, init_words=init, seed=0
        )
        run_resumable(a, plan, directory=str(tmp_path), every=8)
        with pytest.raises(ValueError, match="different run"):
            run_resumable(b, plan, directory=str(tmp_path), every=8)

    def test_handle_save_records_fingerprint(self, tmp_path):
        from repro.checkpoint import load_checkpoint_tree

        target, init = _mh_setup()
        engine = samplers.MHEngine(samplers.EngineConfig(chunk_steps=8))
        plan = samplers.RunPlan(
            target=target, n_steps=8, init_words=init, seed=2
        )
        handle = engine.submit(plan)
        handle.save(str(tmp_path))
        tree, manifest = load_checkpoint_tree(str(tmp_path), handle.progress)
        assert manifest["extra"]["fingerprint"] == plan.fingerprint(engine)
        np.testing.assert_array_equal(
            tree["words"], np.asarray(handle.final_words)
        )


class TestWorkloadResume:
    def test_workload_diagnostics_survive_resume(self, tmp_path):
        """The full production recipe: a workload's RunPlan driven by
        run_resumable yields the same diagnostics as the direct run."""
        k_init, k_run = jax.random.split(jax.random.PRNGKey(0))
        wl = workloads.build("ising", k_init, smoke=True, backend="scan")
        ref = wl.run(k_run)
        handle = run_resumable(
            wl.engine, wl.plan(k_run), directory=str(tmp_path), every=16
        )
        _assert_bit_identical(handle.result, ref)
        assert wl.diagnostics(handle.result) == wl.diagnostics(ref)
