"""Data pipeline, optimizer, checkpoint, fault-tolerance, straggler tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointConfig,
    CheckpointManager,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)
from repro.data import DataConfig, MarkovSource, SyntheticTokenPipeline
from repro.distributed.fault import PreemptionHandler
from repro.distributed.straggler import StragglerWatchdog
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


class TestDataPipeline:
    def test_deterministic_in_seed_step(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
        p1 = SyntheticTokenPipeline(cfg)
        p2 = SyntheticTokenPipeline(cfg)
        b1, b2 = p1.global_batch(3), p2.global_batch(3)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = p1.global_batch(4)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_host_sharding_invariance(self):
        """Concatenated per-host slices == the global batch, for any host
        count — the elasticity property restarts rely on."""
        base = dict(vocab_size=50, seq_len=8, global_batch=8, seed=1)
        global_b = SyntheticTokenPipeline(DataConfig(**base)).global_batch(5)
        for n_hosts in (2, 4):
            parts = [
                SyntheticTokenPipeline(
                    DataConfig(**base, n_hosts=n_hosts, host_id=h)
                ).host_batch(5)["tokens"]
                for h in range(n_hosts)
            ]
            np.testing.assert_array_equal(
                np.concatenate(parts, axis=0), global_b["tokens"]
            )

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
        b = SyntheticTokenPipeline(cfg).global_batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_markov_structure_learnable(self):
        """Tokens actually follow the chain: every transition must be one of
        the state's allowed successors."""
        cfg = DataConfig(vocab_size=64, seq_len=128, global_batch=2, branching=4)
        src = MarkovSource(cfg)
        rows = np.asarray(src.batch_rows(0, 0, 2))
        succ = np.asarray(src.successors)
        for row in rows:
            for t in range(len(row) - 1):
                assert row[t + 1] in succ[row[t]]
        # entropy floor well below log V
        assert src.entropy_per_token() < np.log(64) * 0.75


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw_init(params, cfg)
        loss = lambda p: jnp.sum(p["w"] ** 2)  # noqa: E731
        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state, _ = adamw_update(g, state, params, cfg)
        assert float(loss(params)) < 1e-3

    def test_grad_clipping(self):
        cfg = AdamWConfig(lr=1e-3, clip_norm=1.0)
        params = {"w": jnp.zeros(3)}
        state = adamw_init(params, cfg)
        huge = {"w": jnp.full(3, 1e9)}
        _, _, metrics = adamw_update(huge, state, params, cfg)
        assert float(metrics["grad_norm"]) > 1e8  # reported pre-clip

    def test_master_weights(self):
        cfg = AdamWConfig(lr=0.01, use_master=True, weight_decay=0.0)
        params = {"w": jnp.ones(4, jnp.bfloat16)}
        state = adamw_init(params, cfg)
        g = {"w": jnp.full(4, 1e-4, jnp.float32)}
        p2, s2, _ = adamw_update(g, state, params, cfg)
        # master tracks sub-bf16 updates
        assert s2["master"]["w"].dtype == jnp.float32
        assert float(jnp.abs(s2["master"]["w"] - 1.0).max()) > 0

    def test_schedule_shape(self):
        s0 = float(cosine_schedule(0, 10, 100))
        s_peak = float(cosine_schedule(10, 10, 100))
        s_end = float(cosine_schedule(100, 10, 100))
        assert s0 < s_peak
        assert s_peak == pytest.approx(1.0, abs=0.01)
        assert s_end == pytest.approx(0.1, abs=0.01)


class TestCheckpoint:
    def _tree(self, key=0):
        k = jax.random.PRNGKey(key)
        return {
            "params": {"w": jax.random.normal(k, (4, 8)), "b": jnp.zeros(8)},
            "opt": {"step": jnp.int32(7), "m": {"w": jnp.ones((4, 8))}},
        }

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        save_checkpoint(str(tmp_path), 42, tree)
        restored, manifest = load_checkpoint(str(tmp_path), 42, tree)
        assert manifest["step"] == 42
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_integrity_detection(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, self._tree())
        ck = os.path.join(tmp_path, "step_00000001")
        victim = sorted(f for f in os.listdir(ck) if f.endswith(".npy"))[0]
        with open(os.path.join(ck, victim), "r+b") as f:
            f.seek(-1, 2)
            f.write(b"\xff")
        with pytest.raises(IOError):
            load_checkpoint(str(tmp_path), 1, self._tree())

    def test_atomicity_tmp_ignored(self, tmp_path):
        os.makedirs(tmp_path / "step_00000009.tmp")
        save_checkpoint(str(tmp_path), 3, self._tree())
        assert latest_step(str(tmp_path)) == 3

    def test_shape_mismatch_rejected(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            load_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((3, 3))})

    def test_manager_retention_and_resume(self, tmp_path):
        mgr = CheckpointManager(
            CheckpointConfig(directory=str(tmp_path), retention=2, async_save=False)
        )
        for s in (10, 20, 30):
            mgr.save(s, self._tree(s))
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
        )
        assert steps == [20, 30]
        restored, step = mgr.restore_latest(self._tree())
        assert step == 30

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(
            CheckpointConfig(directory=str(tmp_path), retention=3, async_save=True)
        )
        mgr.save(5, self._tree())
        mgr.wait()
        assert latest_step(str(tmp_path)) == 5


class TestFaultTolerance:
    def test_preemption_flag(self):
        h = PreemptionHandler()
        assert not h.preemption_requested
        h.simulate_preemption()
        assert h.preemption_requested
        h.clear()
        assert not h.preemption_requested

    def test_preempt_resume_bit_exact(self, tmp_path):
        """Train 6 steps straight vs train 3 + preempt + resume 3: the loss
        trajectories must match exactly (checkpoint + deterministic data)."""
        from repro import configs
        from repro.launch.train import TrainRun, run_training

        cfg = configs.get_smoke_config("granite3_8b")
        base = dict(
            cfg=cfg, global_batch=4, seq_len=16, lr=1e-3,
            ckpt_dir=str(tmp_path / "ck"), ckpt_every=3, log_every=100,
        )
        # uninterrupted reference
        _, _, losses_ref = run_training(TrainRun(steps=6, **{**base, "ckpt_dir": str(tmp_path / "ref")}))

        handler = PreemptionHandler()
        run = TrainRun(steps=6, **base)

        # interrupt exactly after step 2 (checkpoint lands at step 3)
        import repro.launch.train as train_mod

        orig = train_mod.SyntheticTokenPipeline.host_batch
        calls = {"n": 0}

        def counting(self, step):
            calls["n"] += 1
            if calls["n"] == 3:
                handler.simulate_preemption()
            return orig(self, step)

        train_mod.SyntheticTokenPipeline.host_batch = counting
        try:
            _, _, losses_a = run_training(run, preemption=handler)
        finally:
            train_mod.SyntheticTokenPipeline.host_batch = orig

        assert len(losses_a) == 3  # stopped after step index 2
        _, _, losses_b = run_training(TrainRun(steps=6, **base))
        combined = losses_a + losses_b
        np.testing.assert_allclose(combined, losses_ref, rtol=1e-6)


class TestStraggler:
    def test_flags_slow_host(self):
        flagged = []
        wd = StragglerWatchdog(
            n_hosts=4, threshold=1.5, min_steps=3,
            on_flag=lambda h, e, m: flagged.append(h),
        )
        for _ in range(6):
            for h in range(4):
                wd.record(h, 1.0 if h != 2 else 3.0)
            wd.check()
        assert wd.flagged == [2]
        assert flagged == [2]

    def test_global_slowdown_flags_nobody(self):
        wd = StragglerWatchdog(n_hosts=4, min_steps=2)
        for t in (1.0, 2.0, 4.0):  # fleet-wide slowdown
            for h in range(4):
                wd.record(h, t)
            wd.check()
        assert wd.flagged == []

    def test_recovery_unflags(self):
        wd = StragglerWatchdog(n_hosts=2, min_steps=2, ema_alpha=1.0)
        for _ in range(4):
            wd.record(0, 1.0)
            wd.record(1, 5.0)
        wd.check()
        assert wd.flagged == [1]
        for _ in range(4):
            wd.record(0, 1.0)
            wd.record(1, 1.0)
        wd.check()
        assert wd.flagged == []
