"""Validation of the paper's quantitative claims (§3, §4.2, §6, App. A)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitcell, energy, msxor


class TestBitFlipRate:
    def test_paper_anchor_05v(self):
        # §3.1: "BFR is around 45% when CVDD lowers to 0.5 V"
        assert float(bitcell.bit_flip_rate(0.5)) == pytest.approx(0.45, abs=0.01)

    def test_paper_anchor_06v(self):
        # §4.2: "p_BFR >= 0.4 corresponding to ... 0.6V"
        assert float(bitcell.bit_flip_rate(0.6)) == pytest.approx(0.40, abs=0.01)

    def test_nominal_supply_is_stable(self):
        assert float(bitcell.bit_flip_rate(0.8)) < 0.01

    def test_monotone_in_cvdd(self):
        vs = np.linspace(0.3, 0.8, 26)
        bfr = np.array([float(bitcell.bit_flip_rate(v)) for v in vs])
        assert np.all(np.diff(bfr) <= 1e-9)

    def test_thermal_fig15(self):
        # Fig. 15: p_BFR ~45% flat over 0-70 C; decreases below -20 C
        for t in (0.0, 25.0, 70.0):
            assert float(bitcell.bit_flip_rate(0.5, t)) == pytest.approx(
                0.45, abs=0.012
            )
        assert float(bitcell.bit_flip_rate(0.5, -40.0)) < float(
            bitcell.bit_flip_rate(0.5, 25.0)
        )

    def test_pseudo_read_statistics(self):
        key = jax.random.PRNGKey(0)
        bits = bitcell.pseudo_read_fresh(key, 0.45, shape=(200_000,))
        assert float(bits.mean()) == pytest.approx(0.45, abs=0.005)

    def test_pseudo_read_flip_is_xor(self):
        key = jax.random.PRNGKey(1)
        stored = jnp.ones(10_000, jnp.uint8)
        flipped = bitcell.pseudo_read_flip(key, stored, 0.45)
        # every flipped position is 0 where a flip event occurred
        assert float((flipped == 0).mean()) == pytest.approx(0.45, abs=0.02)


class TestMSXOR:
    def test_lambda3_exact_paper_value(self):
        # §4.2: "Take p_BFR = 0.4 as an example, lambda_3 = 0.49999872"
        assert msxor.lambda_recursion(0.4, 3) == pytest.approx(
            0.49999872, abs=1e-9
        )

    def test_error_below_1e5_for_p04(self):
        # abstract: "probability error ... suppressed under 1e-5"
        assert msxor.debias_error(0.4, 3) < 1e-5

    def test_three_stages_adequate_above_04(self):
        # §4.2: "when p_BFR >= 0.4 ... 3-stage XOR-gates is adequate"
        for p in np.linspace(0.40, 0.50, 11):
            assert msxor.required_stages(float(p), tol=1e-5) <= 3

    def test_corner_case_bound(self):
        # §4.2 corner simulation: lambda_3 >= 0.4999993981
        assert msxor.lambda_recursion(0.42, 3) >= 0.4999993981 - 6e-7

    def test_appendix_a_convergence(self):
        # Appendix A: lim lambda_n = 0.5 for any lambda_0 in (0, 0.5)
        for p0 in (0.01, 0.1, 0.25, 0.45):
            assert msxor.lambda_recursion(p0, 32) == pytest.approx(0.5, abs=1e-9)


class TestEnergyModel:
    def test_accepted_sample_energy(self):
        # §6.4: 0.5065 pJ / accepted sample
        assert energy.energy_accepted_fj(4) == pytest.approx(506.5, abs=0.1)

    def test_rejected_sample_energy(self):
        # §6.4: 0.5547 pJ / rejected sample
        assert energy.energy_rejected_fj(4) == pytest.approx(554.7, abs=0.1)

    def test_energy_band_at_30_40pct_acceptance(self):
        # §6.4: 0.5331 - 0.5402 pJ at 30-40 % acceptance
        for ar in (0.30, 0.35, 0.40):
            e_pj = energy.energy_per_sample_fj(ar, 4) / 1000.0
            assert 0.530 <= e_pj <= 0.541

    def test_throughput_headline(self):
        # §6.5 / abstract: 166.7 M samples/s at 4-bit (6 ns loop)
        assert energy.iteration_time_ns(4) == pytest.approx(6.0)
        assert energy.throughput_per_chain(4) == pytest.approx(166.7e6, rel=1e-3)

    def test_throughput_above_1e7_up_to_32bit(self):
        # Fig. 16(b): throughput stays above 1e7 samples/s
        for nbits in (4, 8, 16, 32):
            assert energy.throughput_per_chain(nbits) > 1e7

    def test_sub_2x_slowdown_per_bit_doubling(self):
        # §6.5: "it takes less than twice the time to generate a sample of
        # double number of bits"
        for nbits in (4, 8, 16):
            t1 = energy.iteration_time_ns(nbits)
            t2 = energy.iteration_time_ns(2 * nbits)
            assert t2 < 2.0 * t1

    def test_fig17_macro_timing(self):
        # Fig. 17(c): 1e6 32-bit samples within 1e-3 s
        assert energy.time_for_samples_s(1_000_000, nbits=32) < 1e-3

    def test_power_matches_gpu_comparison(self):
        # §6.6: 0.157 mW (GMM) / 1.52e-4 W (MGD) at 32-bit scale
        p = energy.power_w(nbits=32, accept_ratio=0.35)
        assert 1e-4 < p < 3e-4
