"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles.

Kernels run in interpret mode on CPU (the TPU lowering is exercised by the
same pallas_call with interpret=False on device).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis or skip-stubs

from repro.core import bitcell
from repro.kernels.mh import ops as mh_ops
from repro.kernels.mh.ref import mh_chain_ref
from repro.kernels.msxor import ops as msxor_ops
from repro.kernels.msxor.ref import msxor_fold_ref, msxor_uniform_ref


class TestMSXORKernel:
    @pytest.mark.parametrize("n_stages", [1, 2, 3, 4])
    @pytest.mark.parametrize("m", [128, 500, 512, 1000, 4096])
    def test_fold_matches_ref(self, n_stages, m):
        key = jax.random.PRNGKey(n_stages * 1000 + m)
        raw = jax.random.bits(key, (1 << n_stages, m), dtype=jnp.uint32)
        out = msxor_ops.msxor_fold(raw, n_stages=n_stages)
        ref = msxor_fold_ref(raw, n_stages)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    @pytest.mark.parametrize("m", [128, 777, 2048])
    def test_uniform_matches_ref(self, m):
        key = jax.random.PRNGKey(m)
        raw = jax.random.bits(key, (8, m), dtype=jnp.uint32)
        out = msxor_ops.msxor_uniform(raw)
        ref = msxor_uniform_ref(raw, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=0)

    def test_uniform_values_in_range(self):
        raw = jax.random.bits(jax.random.PRNGKey(0), (8, 4096), dtype=jnp.uint32)
        u = np.asarray(msxor_ops.msxor_uniform(raw))
        assert u.min() >= 0.0 and u.max() < 1.0

    @given(st.integers(1, 4), st.integers(1, 300))
    @settings(max_examples=12, deadline=None)
    def test_fold_hypothesis_shapes(self, n_stages, m):
        key = jax.random.PRNGKey(m)
        raw = jax.random.bits(key, (1 << n_stages, m), dtype=jnp.uint32)
        out = msxor_ops.msxor_fold(raw, n_stages=n_stages)
        ref = msxor_fold_ref(raw, n_stages)
        assert out.shape == (m,)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_statistical_debias_property(self):
        """Kernel output bits are unbiased even from biased inputs."""
        raw = bitcell.raw_random_words(
            jax.random.PRNGKey(1), 0.4, (8, 100_000), nbits=32
        )
        out = np.asarray(msxor_ops.msxor_fold(raw))
        for b in range(0, 32, 5):
            frac = ((out >> b) & 1).mean()
            assert frac == pytest.approx(0.5, abs=0.01)


class TestMHKernel:
    @pytest.mark.parametrize(
        "b,v,c,k,nbits",
        [
            (1, 16, 64, 8, 4),
            (2, 256, 128, 32, 8),
            (3, 100, 256, 16, 7),   # non-power-of-two vocab
            (2, 1024, 300, 8, 10),  # padded chain axis
        ],
    )
    def test_fused_chain_matches_ref(self, b, v, c, k, nbits):
        key = jax.random.PRNGKey(b * 7 + v)
        table = jax.random.normal(key, (b, v), jnp.float32)
        init = jnp.broadcast_to(
            jnp.argmax(table, -1).astype(jnp.uint32)[:, None], (b, c)
        )
        rnd = mh_ops.generate_randomness(key, k, b, c, p_bfr=0.45)
        s_kernel, a_kernel = mh_ops.mh_sample(
            table, init, rnd.flips, rnd.u, nbits=nbits
        )
        s_ref, a_ref = mh_chain_ref(table, init, rnd.flips, rnd.u, nbits)
        np.testing.assert_array_equal(np.asarray(s_kernel), np.asarray(s_ref))
        np.testing.assert_array_equal(np.asarray(a_kernel), np.asarray(a_ref))

    def test_out_of_vocab_never_sampled(self):
        """V=100 < 2^7: out-of-support proposals must always be rejected."""
        key = jax.random.PRNGKey(42)
        table = jax.random.normal(key, (4, 100), jnp.float32)
        samples, _ = mh_ops.mh_sample_with_rng(key, table, n_steps=64, chains=32)
        assert int(np.asarray(samples).max()) < 100

    def test_kernel_distribution_matches_table(self):
        """Fused kernel chains converge to the softmax of the table."""
        key = jax.random.PRNGKey(7)
        logits = jnp.asarray(
            np.random.default_rng(0).normal(size=(1, 32)), jnp.float32
        )
        samples, accept = mh_ops.mh_sample_with_rng(
            key, logits, n_steps=400, chains=256
        )
        kept = np.asarray(samples[200:]).reshape(-1)
        emp = np.bincount(kept, minlength=32) / kept.size
        ref = np.asarray(jax.nn.softmax(logits[0]))
        tv = 0.5 * np.abs(emp - ref).sum()
        assert tv < 0.05, f"TV {tv}"

    def test_acceptance_counts_bounded(self):
        key = jax.random.PRNGKey(3)
        table = jax.random.normal(key, (2, 64), jnp.float32)
        _, accept = mh_ops.mh_sample_with_rng(key, table, n_steps=32, chains=16)
        a = np.asarray(accept)
        assert a.min() >= 0 and a.max() <= 32


class TestTokenSamplerFused:
    def test_serving_entry(self):
        key = jax.random.PRNGKey(11)
        logits = jax.random.normal(key, (8, 50), jnp.float32) * 3
        tokens, acc = mh_ops.sample_tokens_fused(key, logits, n_steps=64)
        assert tokens.shape == (8,)
        assert int(np.asarray(tokens).max()) < 50
        assert 0.0 <= float(acc) <= 1.0
