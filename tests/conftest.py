import os
import sys

# Tests see ONE cpu device (the dry-run sets 512 itself, in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# hypothesis is a dev extra; when absent, only the property-based tests
# skip — plain tests in the same modules keep running.
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    import pytest

    _skip = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_args, **_kwargs):
        return lambda f: _skip(f)

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _StrategyStub:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()
